"""Distributed clique counting: one engine session over a workers mesh,
plus fault-tolerant rounds.

Run with several fake devices to exercise the real shard_map path:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_counting.py
"""
import jax

from repro.core import clique_count_bruteforce
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import rmat
from repro.runtime.faults import FaultDomain, RoundScheduler

g = rmat(10, 12, seed=3)
print(f"graph: n={g.n} m={g.m}; devices={jax.device_count()}")

# one session over all local devices: CSR replicated once, the
# jit(shard_map(...)) executables compiled once per capacity class
eng = CliqueEngine(g, backend="shard_map")

# --- exact, distributed over all local devices ---------------------------
res = eng.submit(CountRequest(k=4))
print(f"q_4 = {res.count} on {res.n_workers} workers "
      f"(LPT imbalance {res.balance['imbalance']:.3f})")

# --- §6 split round: cap the heaviest reducer -----------------------------
res_split = eng.submit(CountRequest(k=4, split_threshold=64))
assert res_split.count == res.count
print("split round (threshold 64): same count, "
      "heavy subgraphs rerouted as (node, pivot) units")

# --- sampled, bit-identical under any worker count ------------------------
e = eng.submit(CountRequest(k=5, method="color_smooth", colors=8, seed=5))
print(f"SIC_5 estimate = {e.estimate:.0f} "
      f"(per-round bytes: {e.per_round_bytes})")

# --- fault-tolerant round execution ---------------------------------------
# retried units resubmit against the same session: the plan and compiled
# executables are already cached, so a retry costs only the count itself
faults = FaultDomain(fail_at=(1,), max_retries=2)   # unit 1 fails once
sched = RoundScheduler(faults=faults)
units = [(f"k{k}", (lambda kk: (lambda:
          eng.submit(CountRequest(k=kk)).count))(k)) for k in (3, 4)]
out = sched.run_round(units)
print("fault-injected round results:", out,
      f"(calls incl. retries: {faults.calls})")
bf = clique_count_bruteforce(g, 3)
assert out["k3"] == bf
print("verified against brute force:", bf)

stats = eng.session_stats()
print(f"session: {stats['n_queries']} queries, "
      f"executables {stats['executables']['hits']} hits / "
      f"{stats['executables']['misses']} builds")
