"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.models import init_params
from repro.serving.engine import Engine

for arch in ("mamba2-370m", "mixtral-8x7b"):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params)
    shape = ShapeConfig("serve", 32, 4, "train")
    batch = next(make_pipeline(cfg, shape, seed=7))
    batch = {k: v for k, v in batch.items()
             if k not in ("targets", "mask")}
    t0 = time.perf_counter()
    out = eng.generate(batch, max_new_tokens=12)
    dt = time.perf_counter() - t0
    print(f"{arch}: generated {out.shape} in {dt:.2f}s; "
          f"greedy tokens of seq 0: {out[0].tolist()}")
    out2 = eng.generate(batch, max_new_tokens=12)
    assert np.array_equal(out, out2), "greedy decode must be deterministic"
print("serving OK")
