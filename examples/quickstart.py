"""Quickstart: count k-cliques exactly and approximately, single host.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import clique_count_bruteforce, count_cliques
from repro.core.mrc import theorem3_max_colors
from repro.graphs import barabasi_albert

# a small scale-free graph (heavy-tailed degrees, like the paper's data)
g = barabasi_albert(2000, 10, seed=1)
print(f"graph: n={g.n} m={g.m}")

# --- exact counting (algorithm SI_k, all three rounds) -------------------
for k in (3, 4, 5):
    res = count_cliques(g, k)
    print(f"q_{k} = {res.count:>10d}   "
          f"(plan: {res.plan_summary['n_units']} units, "
          f"pad waste {res.plan_summary['pad_frac']:.1%}, "
          f"{res.timings['total_s']:.2f}s)")

# --- sampled counting (SIC_k, color sampling with smoothing) -------------
exact = count_cliques(g, 4).count
for colors in (2, 4, 8):
    res = count_cliques(g, 4, method="color_smooth", colors=colors, seed=0)
    err = abs(res.estimate - exact) / exact
    print(f"SIC_4 c={colors}: estimate={res.estimate:12.0f} "
          f"err={err:.2%}  (round-3 volume ×{res.mrc.sample_factor:.2f})")

# --- how aggressively may we sample? (Theorem 3) --------------------------
c_max = theorem3_max_colors(g.m, exact, k=4, eps=0.1)
print(f"Theorem 3: with q_4={exact}, up to c={c_max} colors keeps "
      f"ε=0.1 concentration w.h.p.")

# --- per-node outputs (the exact engine attributes cliques to nodes) ------
res = count_cliques(g, 3, return_per_node=True)
top = res.per_node.argsort()[-3:][::-1]
print("top triangle-responsible nodes:", top.tolist())

# --- the same counts via the Pallas kernel path ---------------------------
res_k = count_cliques(g, 3, engine="pallas")
assert res_k.count == res.count
print("pallas kernel path agrees:", res_k.count)
