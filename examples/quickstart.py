"""Quickstart: one CliqueEngine session, many queries, single host.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import clique_count_bruteforce
from repro.core.mrc import theorem3_max_colors
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import barabasi_albert

# a small scale-free graph (heavy-tailed degrees, like the paper's data)
g = barabasi_albert(2000, 10, seed=1)
print(f"graph: n={g.n} m={g.m}")

# one session: the oriented CSR is built and uploaded exactly once;
# plans and compiled tile executables are cached across every query
eng = CliqueEngine(g)

# --- exact counting (algorithm SI_k, all three rounds) -------------------
for rep in eng.submit_many([CountRequest(k=k) for k in (3, 4, 5)]):
    print(f"q_{rep.k} = {rep.count:>10d}   "
          f"(plan: {rep.plan_summary['n_units']} units, "
          f"pad waste {rep.plan_summary['pad_frac']:.1%}, "
          f"{rep.timings['total_s']:.2f}s, plan cache {rep.cache['plan']})")

# --- sampled counting (SIC_k, color sampling with smoothing) -------------
# reuses the cached k=4 plan AND the compiled executables: note the hits
exact = eng.submit(CountRequest(k=4)).count
for colors in (2, 4, 8):
    rep = eng.submit(CountRequest(k=4, method="color_smooth",
                                  colors=colors, seed=0))
    err = abs(rep.estimate - exact) / exact
    print(f"SIC_4 c={colors}: estimate={rep.estimate:12.0f} "
          f"err={err:.2%}  (round-3 volume ×{rep.mrc.sample_factor:.2f}, "
          f"exec cache {rep.cache['exec_hits']} hits)")

# --- how aggressively may we sample? (Theorem 3) --------------------------
c_max = theorem3_max_colors(g.m, exact, k=4, eps=0.1)
print(f"Theorem 3: with q_4={exact}, up to c={c_max} colors keeps "
      f"ε=0.1 concentration w.h.p.")

# --- per-node outputs (the exact engine attributes cliques to nodes) ------
rep = eng.submit(CountRequest(k=3, return_per_node=True))
top = rep.per_node.argsort()[-3:][::-1]
print("top triangle-responsible nodes:", top.tolist())

# --- the same counts via the Pallas kernel backend, same session ----------
rep_k = eng.submit(CountRequest(k=3, backend="pallas"))
assert rep_k.count == rep.count
print("pallas kernel backend agrees:", rep_k.count)

# --- sanity vs brute force + session telemetry ----------------------------
assert rep.count == clique_count_bruteforce(g, 3)
stats = eng.session_stats()
print(f"session: {stats['n_queries']} queries, "
      f"plan cache {stats['plans']['hits']} hits / "
      f"{stats['plans']['misses']} misses, "
      f"executables {stats['executables']['hits']} hits / "
      f"{stats['executables']['misses']} builds")
