"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: d_model 512, 8 layers, vocab 32000 — a scaled tinyllama;
on this 1-core CPU container expect ~1-2 steps/s at seq 256.)
"""
import argparse
import dataclasses
import json
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.models import init_params
from repro.training.loop import Trainer
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--workdir", default=None)
args = ap.parse_args()

cfg = dataclasses.replace(
    get_smoke_config("tinyllama-1.1b"), name="llama-100m",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=32000)
print(f"model: {cfg.name}, params ≈ {cfg.param_count() / 1e6:.0f}M")

params = init_params(cfg, jax.random.PRNGKey(0))
oc = OptConfig(lr=3e-4, warmup_steps=args.steps // 10,
               total_steps=args.steps)
step = jax.jit(make_train_step(cfg, oc, remat="none"))
shape = ShapeConfig("ex", args.seq, args.batch, "train")
workdir = args.workdir or tempfile.mkdtemp(prefix="repro_train_")
tr = Trainer(cfg, step, make_pipeline(cfg, shape, seed=0), workdir,
             ckpt_every=100)

params2, opt2, start = tr.resume(params, init_opt_state(params))
if start:
    print(f"resuming from checkpoint at step {start}")
params2, opt2, end = tr.fit(params2, opt2, args.steps, start_step=start)

losses = [json.loads(line)["loss"] for line in open(tr.metrics_path)]
print(f"steps {start}..{end}: loss {losses[0]:.3f} → {losses[-1]:.3f}")
print(f"checkpoints + metrics under {workdir}")
assert losses[-1] < losses[0], "loss should decrease"
