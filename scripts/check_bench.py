#!/usr/bin/env python
"""Perf regression gate over the benchmark trajectories.

``benchmarks/kernels_bench.py`` appends one record per run (rows keyed
by (D, r) with wall times and per-tile bytes for the dense f32 and
packed uint32 paths) to ``BENCH_kernels.json``;
``benchmarks/allk_profile.py`` appends ``bench="allk_profile"``-tagged
records (one-pass all-k profile vs the equivalent per-k sweep) to the
same file; ``benchmarks/fig6_stragglers.py --scheduler`` appends the
out-of-core scheduler's speculation-recovery and memory-footprint
record to ``BENCH_scheduler.json``; ``benchmarks/gateway_load.py``
appends the serving gateway's store-hit latency record to
``BENCH_serving.json``; ``benchmarks/estimator_accuracy.py`` appends
the per-method time-vs-accuracy frontier on the degree-skewed corpus
graph to ``BENCH_estimator.json``. This script turns those logs into
gates:

  PYTHONPATH=src python scripts/check_bench.py --run     # nightly CI
  PYTHONPATH=src python scripts/check_bench.py           # compare last 2
  PYTHONPATH=src python scripts/check_bench.py --scheduler --run
  PYTHONPATH=src python scripts/check_bench.py --allk --run
  PYTHONPATH=src python scripts/check_bench.py --serving --run
  PYTHONPATH=src python scripts/check_bench.py --estimator --run

``--run`` executes a fresh benchmark (appending the new record), then
compares it against the latest *prior* record. Failure conditions, per
matching (D, r) row:

- wall-clock regression: ``dense_us`` or ``bits_us`` grew by more than
  ``--ratio`` (default 1.5×) — loose enough to ride out shared-runner
  noise, tight enough to catch an accidentally serialized kernel;
- per-tile-byte regression: ``dense_tile_bytes / B`` or
  ``bits_tile_bytes / B`` grew *at all*. Tile bytes are analytic, not
  measured, so any increase is a real representation regression (e.g.
  losing the 32× packed shrink), never noise.

Wall-clock is only comparable between runs of the same provenance: each
record carries ``(backend, host)`` (``host`` is "ci" under ``$CI``,
else "dev"), and a provenance change skips the wall gate for that one
comparison — the byte gate always applies. The nightly workflow
persists the trajectory across runs via ``actions/cache``, so after the
first nightly bootstraps a ci-provenance baseline, every later nightly
compares ci-vs-ci and the wall gate is armed; it never compares a
GitHub runner against the committed dev-container record.

Rows present only on one side are reported but don't fail the gate
(benchmark coverage may grow); a trajectory with fewer than two records
passes vacuously so the first CI run on a fresh fork bootstraps itself.
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(REPO, "BENCH_kernels.json")
SCHED_TRAJECTORY = os.path.join(REPO, "BENCH_scheduler.json")
SERVING_TRAJECTORY = os.path.join(REPO, "BENCH_serving.json")
ESTIMATOR_TRAJECTORY = os.path.join(REPO, "BENCH_estimator.json")


def row_key(row: dict) -> tuple:
    return (row["D"], row["r"])


def per_unit(row: dict, field: str) -> float:
    """Per-unit tile bytes: the B chosen per run can legitimately vary
    (budget tuning), so the gate compares bytes per work unit."""
    return row[field] / max(row["B"], 1)


def compare(prev: dict, new: dict, ratio: float) -> list:
    """Return a list of human-readable regression strings."""
    regressions = []
    prev_rows = {row_key(r): r for r in prev["rows"]}
    new_rows = {row_key(r): r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        for field in ("dense_us", "bits_us"):
            if n[field] > ratio * p[field]:
                regressions.append(
                    f"(D={key[0]}, r={key[1]}) {field}: "
                    f"{p[field]:.0f}us -> {n[field]:.0f}us "
                    f"({n[field] / p[field]:.2f}x > {ratio}x)")
        for field in ("dense_tile_bytes", "bits_tile_bytes"):
            pu_p, pu_n = per_unit(p, field), per_unit(n, field)
            if pu_n > pu_p:
                regressions.append(
                    f"(D={key[0]}, r={key[1]}) {field}/unit: "
                    f"{pu_p:.0f} -> {pu_n:.0f} bytes (any growth fails)")
    return regressions


def compare_allk(prev: dict, new: dict, ratio: float) -> list:
    """All-k-trajectory gate, per graph row:

    - ``allk_us`` (the one-pass profile wall) may not regress past
      ``ratio`` — same provenance rules as the kernel wall gate;
    - ``speedup`` (sweep wall / all-k wall) must stay >= 3.0 — the
      benchmark asserts this before appending, so tripping it here
      means the record was edited by hand or the contract was
      weakened."""
    regressions = []
    prev_rows = {(r["graph"], r["kmax"]): r for r in prev["rows"]}
    new_rows = {(r["graph"], r["kmax"]): r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        if n["allk_us"] > ratio * p["allk_us"]:
            regressions.append(
                f"({key[0]}, kmax={key[1]}) allk_us: "
                f"{p['allk_us']:.0f} -> {n['allk_us']:.0f} "
                f"({n['allk_us'] / p['allk_us']:.2f}x > {ratio}x)")
        if n["speedup"] < 3.0:
            regressions.append(
                f"({key[0]}, kmax={key[1]}) speedup: "
                f"{n['speedup']:.2f}x < 3.0x (one-pass contract)")
        if n["profile"] != p["profile"]:
            regressions.append(
                f"({key[0]}, kmax={key[1]}) profile changed: "
                f"{p['profile']} -> {n['profile']} (counts are exact; "
                f"any drift is a correctness bug, not perf)")
    return regressions


def compare_scheduler(prev: dict, new: dict, ratio: float) -> list:
    """Scheduler-trajectory gate, per graph row:

    - ``base_wall_us`` (the clean ooc run) may not regress past
      ``ratio`` — same provenance rules as the kernel wall gate;
    - ``slice_frac`` (largest shard slice / full CSR footprint) is
      analytic and may not grow at all: growth means slices stopped
      being meaningfully out-of-core;
    - ``recovery_ratio`` must stay ≥ 2.0 — speculation recovery for
      the single-host row, kill-then-resume recovery for the
      multi-host (``-dist``) row; the benchmark asserts both before
      appending, so tripping it here means the record was edited by
      hand or the contract was weakened.
    """
    regressions = []
    prev_rows = {r["graph"]: r for r in prev["rows"]}
    new_rows = {r["graph"]: r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        if n["base_wall_us"] > ratio * p["base_wall_us"]:
            regressions.append(
                f"({key}) base_wall_us: {p['base_wall_us']:.0f} -> "
                f"{n['base_wall_us']:.0f} "
                f"({n['base_wall_us'] / p['base_wall_us']:.2f}x "
                f"> {ratio}x)")
        if n["slice_frac"] > p["slice_frac"]:
            regressions.append(
                f"({key}) slice_frac: {p['slice_frac']:.3f} -> "
                f"{n['slice_frac']:.3f} (any growth fails)")
        if n["recovery_ratio"] < 2.0:
            regressions.append(
                f"({key}) recovery_ratio: {n['recovery_ratio']:.2f} "
                f"< 2.0 (recovery contract)")
    return regressions


def compare_serving(prev: dict, new: dict, ratio: float) -> list:
    """Serving-trajectory gate, per workload row:

    - ``warm_p50_us`` / ``warm_p99_us`` (the store-hit latencies the
      gateway is accountable for) may not regress past ``ratio`` —
      same provenance rules as the kernel wall gate; the cold phase is
      engine-sweep territory and is not gated here;
    - ``hit_rate`` may not drop at all: the warm phase replays only
      persistable queries, so any miss means persistence broke;
    - ``speedup`` must stay ≥ 10.0 — the benchmark asserts this before
      appending, so tripping it here means the record was edited by
      hand or the contract was weakened."""
    regressions = []
    prev_rows = {r["workload"]: r for r in prev["rows"]}
    new_rows = {r["workload"]: r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        for field in ("warm_p50_us", "warm_p99_us"):
            if n[field] > ratio * p[field]:
                regressions.append(
                    f"({key}) {field}: {p[field]:.0f} -> "
                    f"{n[field]:.0f}us "
                    f"({n[field] / p[field]:.2f}x > {ratio}x)")
        if n["hit_rate"] < p["hit_rate"]:
            regressions.append(
                f"({key}) hit_rate: {p['hit_rate']:.2f} -> "
                f"{n['hit_rate']:.2f} (any drop fails)")
        if n["speedup"] < 10.0:
            regressions.append(
                f"({key}) speedup: {n['speedup']:.1f}x < 10x "
                f"(store-hit contract)")
    return regressions


def compare_estimator(prev: dict, new: dict, ratio: float) -> list:
    """Estimator-trajectory gate, per (method, rel_error) row:

    - ``wall_us`` may not regress past ``ratio`` — same provenance
      rules as the kernel wall gate;
    - ``covered`` must stay True: the benchmark asserts CI-contains-
      truth for every seed before appending, so a False here means the
      record was edited by hand or the contract was weakened;
    - the auto row at the tightest target must keep ``resolved ==
      "sampled"`` with a named ``winner`` (the portfolio race may not
      silently degrade to exact fall-through) and ``within_best`` must
      stay ≤ 1.5 — the race may not cost more than half again the
      oracle single-method choice."""
    regressions = []
    prev_rows = {(r["method"], r["rel"]): r for r in prev["rows"]}
    new_rows = {(r["method"], r["rel"]): r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        if n["wall_us"] > ratio * p["wall_us"]:
            regressions.append(
                f"({key[0]}, rel={key[1]}) wall_us: "
                f"{p['wall_us']:.0f} -> {n['wall_us']:.0f} "
                f"({n['wall_us'] / p['wall_us']:.2f}x > {ratio}x)")
        if not n.get("covered", True):
            regressions.append(
                f"({key[0]}, rel={key[1]}) covered=False "
                f"(CI-contains-truth contract)")
        if key[0] == "auto" and "within_best" in n:
            if n["within_best"] > 1.5:
                regressions.append(
                    f"(auto, rel={key[1]}) within_best: "
                    f"{n['within_best']:.2f}x > 1.5x (portfolio-race "
                    f"contract)")
            if n["resolved"] != "sampled" or not n.get("winner"):
                regressions.append(
                    f"(auto, rel={key[1]}) resolved={n['resolved']!r} "
                    f"winner={n.get('winner')!r} (auto must certify via "
                    f"a sampling lever at the tightest target)")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", action="store_true",
                    help="run the benchmark first (appends a fresh "
                         "record to the trajectory)")
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="wall-clock regression threshold (default 1.5x)")
    ap.add_argument("--scheduler", action="store_true",
                    help="gate BENCH_scheduler.json (the out-of-core "
                         "scheduler trajectory) instead of the kernel "
                         "one")
    ap.add_argument("--allk", action="store_true",
                    help="gate the allk_profile-tagged records in "
                         "BENCH_kernels.json (one-pass all-k profile "
                         "vs per-k sweep) instead of the kernel rows")
    ap.add_argument("--serving", action="store_true",
                    help="gate BENCH_serving.json (the gateway store-"
                         "hit latency trajectory) instead of the "
                         "kernel one")
    ap.add_argument("--estimator", action="store_true",
                    help="gate BENCH_estimator.json (the per-method "
                         "time-vs-accuracy frontier trajectory) "
                         "instead of the kernel one")
    args = ap.parse_args()
    if sum((args.scheduler, args.allk, args.serving,
            args.estimator)) > 1:
        ap.error("--scheduler/--allk/--serving/--estimator are "
                 "mutually exclusive")

    trajectory = (SCHED_TRAJECTORY if args.scheduler else
                  SERVING_TRAJECTORY if args.serving else
                  ESTIMATOR_TRAJECTORY if args.estimator else TRAJECTORY)
    if args.run:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        cmd = (["-m", "benchmarks.fig6_stragglers", "--scheduler",
                "--distributed"]
               if args.scheduler else
               ["-m", "benchmarks.gateway_load"] if args.serving else
               ["-m", "benchmarks.estimator_accuracy"]
               if args.estimator else
               ["-m", "benchmarks.allk_profile"] if args.allk else
               ["-m", "benchmarks.kernels_bench"])
        print(f"running {cmd[1]} ...", flush=True)
        subprocess.run([sys.executable] + cmd, cwd=REPO, env=env,
                       check=True)

    if not os.path.exists(trajectory):
        print(f"no trajectory at {trajectory}; run with --run first")
        return 1
    with open(trajectory) as f:
        full_history = json.load(f)
    history = full_history
    if not args.scheduler and not args.serving \
            and not args.estimator:
        # BENCH_kernels.json interleaves kernel and allk_profile
        # records; compare like against like (untagged = kernels)
        want = "allk_profile" if args.allk else "kernels"
        history = [rec for rec in full_history
                   if rec.get("bench", "kernels") == want]
    if len(history) < 2:
        print(f"only {len(history)} record(s) in the trajectory — "
              "nothing to compare against; passing (bootstrap)")
        return 0
    prev, new = history[-2], history[-1]
    same_machine = (prev.get("backend") == new.get("backend")
                    and prev.get("host", "dev") == new.get("host", "dev"))
    if not same_machine:
        print(f"note: provenance changed "
              f"({prev.get('host', 'dev')}/{prev.get('backend')!r} -> "
              f"{new.get('host', 'dev')}/{new.get('backend')!r}); "
              "wall-clock gate skipped (apples-to-oranges), per-tile "
              "bytes still enforced. In CI the trajectory is persisted "
              "via actions/cache, so the next nightly compares ci-vs-ci "
              "and the wall gate re-arms.")
    print(f"comparing run {new.get('ran_at')} against "
          f"{prev.get('ran_at')} ({len(new['rows'])} rows)")
    gate = (compare_scheduler if args.scheduler else
            compare_serving if args.serving else
            compare_estimator if args.estimator else
            compare_allk if args.allk else compare)
    regressions = gate(prev, new,
                       args.ratio if same_machine else float("inf"))
    if regressions:
        print("PERF REGRESSION:")
        for r in regressions:
            print(f"  - {r}")
        if args.run:
            # drop the regressed record so it can never become the next
            # run's baseline: the gate must keep failing against the
            # last *good* record until the regression is actually fixed,
            # not alarm once and silently ratchet the baseline down.
            # tmp + replace, like append_trajectory: a kill mid-write
            # must not corrupt the whole history. Drop only the one
            # regressed record — `history` may be a tag-filtered view,
            # and the other benchmarks' records must survive the write
            kept = [rec for rec in full_history if rec is not new]
            tmp = trajectory + ".tmp"
            with open(tmp, "w") as f:
                json.dump(kept, f, indent=1)
            os.replace(tmp, trajectory)
            print(f"regressed record dropped from {trajectory}; baseline "
                  f"stays at {prev.get('ran_at')}")
        return 1
    print("perf gate ok: no wall-clock regression over "
          f"{args.ratio}x, no analytic-metric growth")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
