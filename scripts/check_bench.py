#!/usr/bin/env python
"""Kernel-perf regression gate over the BENCH_kernels.json trajectory.

``benchmarks/kernels_bench.py`` appends one record per run (rows keyed
by (D, r) with wall times and per-tile bytes for the dense f32 and
packed uint32 paths). This script turns that log into a gate:

  PYTHONPATH=src python scripts/check_bench.py --run     # nightly CI
  PYTHONPATH=src python scripts/check_bench.py           # compare last 2

``--run`` executes a fresh benchmark (appending the new record), then
compares it against the latest *prior* record. Failure conditions, per
matching (D, r) row:

- wall-clock regression: ``dense_us`` or ``bits_us`` grew by more than
  ``--ratio`` (default 1.5×) — loose enough to ride out shared-runner
  noise, tight enough to catch an accidentally serialized kernel;
- per-tile-byte regression: ``dense_tile_bytes / B`` or
  ``bits_tile_bytes / B`` grew *at all*. Tile bytes are analytic, not
  measured, so any increase is a real representation regression (e.g.
  losing the 32× packed shrink), never noise.

Wall-clock is only comparable between runs of the same provenance: each
record carries ``(backend, host)`` (``host`` is "ci" under ``$CI``,
else "dev"), and a provenance change skips the wall gate for that one
comparison — the byte gate always applies. The nightly workflow
persists the trajectory across runs via ``actions/cache``, so after the
first nightly bootstraps a ci-provenance baseline, every later nightly
compares ci-vs-ci and the wall gate is armed; it never compares a
GitHub runner against the committed dev-container record.

Rows present only on one side are reported but don't fail the gate
(benchmark coverage may grow); a trajectory with fewer than two records
passes vacuously so the first CI run on a fresh fork bootstraps itself.
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRAJECTORY = os.path.join(REPO, "BENCH_kernels.json")


def row_key(row: dict) -> tuple:
    return (row["D"], row["r"])


def per_unit(row: dict, field: str) -> float:
    """Per-unit tile bytes: the B chosen per run can legitimately vary
    (budget tuning), so the gate compares bytes per work unit."""
    return row[field] / max(row["B"], 1)


def compare(prev: dict, new: dict, ratio: float) -> list:
    """Return a list of human-readable regression strings."""
    regressions = []
    prev_rows = {row_key(r): r for r in prev["rows"]}
    new_rows = {row_key(r): r for r in new["rows"]}
    for key in sorted(prev_rows.keys() | new_rows.keys()):
        if key not in new_rows:
            print(f"  note: row {key} vanished from the new run")
            continue
        if key not in prev_rows:
            print(f"  note: row {key} is new in this run")
            continue
        p, n = prev_rows[key], new_rows[key]
        for field in ("dense_us", "bits_us"):
            if n[field] > ratio * p[field]:
                regressions.append(
                    f"(D={key[0]}, r={key[1]}) {field}: "
                    f"{p[field]:.0f}us -> {n[field]:.0f}us "
                    f"({n[field] / p[field]:.2f}x > {ratio}x)")
        for field in ("dense_tile_bytes", "bits_tile_bytes"):
            pu_p, pu_n = per_unit(p, field), per_unit(n, field)
            if pu_n > pu_p:
                regressions.append(
                    f"(D={key[0]}, r={key[1]}) {field}/unit: "
                    f"{pu_p:.0f} -> {pu_n:.0f} bytes (any growth fails)")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", action="store_true",
                    help="run benchmarks/kernels_bench.py first (appends "
                         "a fresh record to the trajectory)")
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="wall-clock regression threshold (default 1.5x)")
    args = ap.parse_args()

    if args.run:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        print("running benchmarks.kernels_bench ...", flush=True)
        subprocess.run([sys.executable, "-m", "benchmarks.kernels_bench"],
                       cwd=REPO, env=env, check=True)

    if not os.path.exists(TRAJECTORY):
        print(f"no trajectory at {TRAJECTORY}; run with --run first")
        return 1
    with open(TRAJECTORY) as f:
        history = json.load(f)
    if len(history) < 2:
        print(f"only {len(history)} record(s) in the trajectory — "
              "nothing to compare against; passing (bootstrap)")
        return 0
    prev, new = history[-2], history[-1]
    same_machine = (prev.get("backend") == new.get("backend")
                    and prev.get("host", "dev") == new.get("host", "dev"))
    if not same_machine:
        print(f"note: provenance changed "
              f"({prev.get('host', 'dev')}/{prev.get('backend')!r} -> "
              f"{new.get('host', 'dev')}/{new.get('backend')!r}); "
              "wall-clock gate skipped (apples-to-oranges), per-tile "
              "bytes still enforced. In CI the trajectory is persisted "
              "via actions/cache, so the next nightly compares ci-vs-ci "
              "and the wall gate re-arms.")
    print(f"comparing run {new.get('ran_at')} against "
          f"{prev.get('ran_at')} ({len(new['rows'])} rows)")
    regressions = compare(prev, new,
                          args.ratio if same_machine else float("inf"))
    if regressions:
        print("PERF REGRESSION:")
        for r in regressions:
            print(f"  - {r}")
        if args.run:
            # drop the regressed record so it can never become the next
            # run's baseline: the gate must keep failing against the
            # last *good* record until the regression is actually fixed,
            # not alarm once and silently ratchet the baseline down.
            # tmp + replace, like append_trajectory: a kill mid-write
            # must not corrupt the whole history
            tmp = TRAJECTORY + ".tmp"
            with open(tmp, "w") as f:
                json.dump(history[:-1], f, indent=1)
            os.replace(tmp, TRAJECTORY)
            print(f"regressed record dropped from {TRAJECTORY}; baseline "
                  f"stays at {prev.get('ran_at')}")
        return 1
    print("perf gate ok: no wall-clock regression over "
          f"{args.ratio}x, no per-tile-byte growth")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
