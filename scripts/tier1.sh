#!/usr/bin/env bash
# Pre-merge check: the tier-1 test suite plus a fast engine smoke test.
#   ./scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python -m repro.launch.count --graph rmat:8:4 --k 4 --method color
