#!/usr/bin/env bash
# Pre-merge check: the tier-1 test suite (includes the cross-backend
# conformance suite), a fast engine smoke, and a CliqueService smoke
# (2 graphs through a 1-session pool: coalesced duplicate queries +
# LRU eviction, asserted by --serve itself).
#   ./scripts/tier1.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# full suite — tests/test_conformance.py (backend-vs-oracle agreement)
# and tests/test_golden.py (pinned corpus counts) are collected here
python -m pytest -x -q

python -m repro.launch.count --graph rmat:8:4 --k 4 --method color

# packed-bitset smoke: forced uint32 tile representation must reproduce
# the pinned golden counts on a corpus graph
python -m repro.launch.count --graph corpus:planted_32_6_7 --k 3,4,5,6 \
    --engine bitset --assert-golden

# all-k profile smoke: ONE tile pass must reproduce every pinned golden
# count at once (q_3..q_7 of the deep-k regression graph)
python -m repro.launch.count --graph corpus:planted_32_6_7 --k all \
    --assert-golden

# listing smoke: the streamed enumeration must reproduce the exact
# count on the same session (asserted by --list itself) and the pinned
# golden counts; the tiny --chunk forces the overflow drain path
python -m repro.launch.count --graph corpus:planted_32_6_7 --k 3,4,5 \
    --list --chunk 16 --list-show 2 --assert-golden

# estimator smoke: accuracy-targeted auto query on the corpus benchmark
# graph; --assert-golden checks the reported CI contains the golden count
python -m repro.launch.count --graph corpus:planted_1200_12_16_40 --k 5 \
    --rel-error 0.1 --assert-golden

# wedge-lever smoke: the single-lever adaptive run must certify the
# same golden-CI contract on the graph wedge sampling is built to win
python -m repro.launch.count --graph corpus:planted_1200_12_16_40 --k 5 \
    --method wedge --rel-error 0.1 --assert-golden

# out-of-core scheduler smoke: 4 workers over spilled shard slices with
# an injected task fault (retried) AND a forced straggler (speculated —
# both asserted by the launcher), still reproducing the golden count
ooc_spill="$(mktemp -d)"
dist_spill="$(mktemp -d)"
gw_store="$(mktemp -d)"
trap 'rm -rf "$ooc_spill" "$dist_spill" "$gw_store"' EXIT
python -m repro.launch.count --graph corpus:planted_1200_12_16_40 --k 4 \
    --backend ooc --workers 4 --spill-dir "$ooc_spill" \
    --inject-fault 1 --inject-straggler 4 --assert-golden

# distributed chaos smoke: 3 executor subprocesses, one SIGKILLed after
# its first commit and one slowed — the lease must expire, the task be
# reassigned, and the count stay golden; the second pass resumes from
# the ledger and must re-run nothing (--assert-no-rerun)
python -m repro.launch.count --graph corpus:planted_1200_12_16_40 --k 4 \
    --backend ooc --executors 3 --spill-dir "$dist_spill" \
    --chaos kill:1@1,slow:2/2.0 --lease 1.5 --ooc-task-delay 0.05 \
    --assert-golden
python -m repro.launch.count --graph corpus:planted_1200_12_16_40 --k 4 \
    --backend ooc --executors 3 --spill-dir "$dist_spill" \
    --resume --assert-no-rerun --assert-golden

python -m repro.launch.count --serve --graph rmat:7:4,er:60:150 \
    --k 3,4 --repeat 2 --max-sessions 1

# gateway smoke, two invocations against one store: the first executes
# and persists (its own second pass must be all store hits), the second
# is a cold-process restart that must answer everything from disk with
# zero engine executions — both asserted by --serve-gateway itself
python -m repro.launch.count --serve-gateway --graph rmat:7:4,er:60:150 \
    --k 3,4 --store-dir "$gw_store" --deadline 300
python -m repro.launch.count --serve-gateway --graph rmat:7:4,er:60:150 \
    --k 3,4 --store-dir "$gw_store" --deadline 300
