#!/usr/bin/env python
"""Regenerate — or drift-check — tests/fixtures/golden_counts.json, the
checked-in exact clique counts for the conformance corpus.

  PYTHONPATH=src python scripts/regen_golden.py            # rewrite
  PYTHONPATH=src python scripts/regen_golden.py --check    # CI guard

Counts come from the brute-force oracle (never from the engine under
test), so the fixture is an independent regression anchor: rerun the
writer only when the corpus itself changes deliberately, and review the
diff — a changed count means changed semantics, not a refresh.

``--check`` regenerates in memory and diffs against the checked-in
fixture without touching it, exiting non-zero on any mismatch. CI runs
it on every push/PR, so a corpus or oracle edit that silently shifts a
count (or forgets to regenerate the fixture) fails before review.

Coverage: k = 3..7 on the small corpus graphs (the deep-k regression —
planted_32_6_7 pins nonzero q_6/q_7, the bipartite graph pins the
all-zero column); the large estimator-benchmark graph stops at k = 5,
where both the oracle and the engine's exact path stay test-budget
friendly (its q_6/q_7 work grows as D^{k-1} on 32-wide units).
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import clique_count_bruteforce            # noqa: E402
from repro.graphs import conformance_corpus               # noqa: E402

KS = (3, 4, 5, 6, 7)
DEEP_K_MAX_NODES = 100   # graphs above this pin only k ≤ 5
OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures", "golden_counts.json")


def ks_for(n: int):
    return [k for k in KS if k <= 5 or n <= DEEP_K_MAX_NODES]


def compute_golden() -> dict:
    out = {}
    for g in conformance_corpus():
        counts = {str(k): int(clique_count_bruteforce(g, k))
                  for k in ks_for(g.n)}
        out[g.name] = {
            "n": g.n,
            "m": g.m,
            "counts": counts,
            # the k="all" anchor: q_3..q_{pinned max} as a vector (same
            # oracle values; comparisons zero-pad both sides, so a
            # profile trimmed at the clique number still matches)
            "profile": [counts[str(k)] for k in ks_for(g.n)],
        }
    return out


def check(golden: dict) -> int:
    """Diff the freshly computed golden dict against the fixture."""
    if not os.path.exists(OUT):
        print(f"DRIFT: fixture {OUT} is missing; run "
              f"scripts/regen_golden.py and commit it")
        return 1
    with open(OUT) as f:
        pinned = json.load(f)
    problems = []
    for name in sorted(set(golden) | set(pinned)):
        if name not in pinned:
            problems.append(f"corpus graph {name!r} is not in the fixture")
            continue
        if name not in golden:
            problems.append(f"fixture entry {name!r} is not in the corpus")
            continue
        for field in ("n", "m", "counts", "profile"):
            got, want = golden[name][field], pinned[name].get(field)
            if got != want:
                problems.append(f"{name}.{field}: corpus says {got!r}, "
                                f"fixture pins {want!r}")
    if problems:
        print(f"DRIFT between conformance_corpus() and {OUT}:")
        for p in problems:
            print(f"  - {p}")
        print("If the corpus change is deliberate, regenerate with "
              "`PYTHONPATH=src python scripts/regen_golden.py`, review "
              "the diff, and commit the fixture.")
        return 1
    print(f"golden fixture is in sync ({len(golden)} graphs, "
          f"{sum(len(e['counts']) for e in golden.values())} pinned "
          f"counts)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory and diff against the "
                         "checked-in fixture (exit 1 on drift) instead "
                         "of rewriting it")
    args = ap.parse_args()
    golden = compute_golden()
    if args.check:
        return check(golden)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {OUT}")
    for name, entry in golden.items():
        print(f"  {name}: n={entry['n']} m={entry['m']} "
              f"counts={entry['counts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
