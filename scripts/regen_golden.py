#!/usr/bin/env python
"""Regenerate tests/fixtures/golden_counts.json — the checked-in exact
clique counts for the conformance corpus.

  PYTHONPATH=src python scripts/regen_golden.py

Counts come from the brute-force oracle (never from the engine under
test), so the fixture is an independent regression anchor: rerun this
only when the corpus itself changes deliberately, and review the diff —
a changed count means changed semantics, not a refresh.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import clique_count_bruteforce            # noqa: E402
from repro.graphs import conformance_corpus               # noqa: E402

KS = (3, 4, 5)
OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures", "golden_counts.json")


def main() -> int:
    golden = {}
    for g in conformance_corpus():
        golden[g.name] = {
            "n": g.n,
            "m": g.m,
            "counts": {str(k): int(clique_count_bruteforce(g, k))
                       for k in KS},
        }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {OUT}")
    for name, entry in golden.items():
        print(f"  {name}: n={entry['n']} m={entry['m']} "
              f"counts={entry['counts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
