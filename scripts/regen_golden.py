#!/usr/bin/env python
"""Regenerate tests/fixtures/golden_counts.json — the checked-in exact
clique counts for the conformance corpus.

  PYTHONPATH=src python scripts/regen_golden.py

Counts come from the brute-force oracle (never from the engine under
test), so the fixture is an independent regression anchor: rerun this
only when the corpus itself changes deliberately, and review the diff —
a changed count means changed semantics, not a refresh.

Coverage: k = 3..7 on the small corpus graphs (the deep-k regression —
planted_32_6_7 pins nonzero q_6/q_7, the bipartite graph pins the
all-zero column); the large estimator-benchmark graph stops at k = 5,
where both the oracle and the engine's exact path stay test-budget
friendly (its q_6/q_7 work grows as D^{k-1} on 32-wide units).
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import clique_count_bruteforce            # noqa: E402
from repro.graphs import conformance_corpus               # noqa: E402

KS = (3, 4, 5, 6, 7)
DEEP_K_MAX_NODES = 100   # graphs above this pin only k ≤ 5
OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures", "golden_counts.json")


def ks_for(n: int):
    return [k for k in KS if k <= 5 or n <= DEEP_K_MAX_NODES]


def main() -> int:
    golden = {}
    for g in conformance_corpus():
        golden[g.name] = {
            "n": g.n,
            "m": g.m,
            "counts": {str(k): int(clique_count_bruteforce(g, k))
                       for k in ks_for(g.n)},
        }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {OUT}")
    for name, entry in golden.items():
        print(f"  {name}: n={entry['n']} m={entry['m']} "
              f"counts={entry['counts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
