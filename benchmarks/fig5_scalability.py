"""Paper Figure 5: scalability with cluster size.

This container has ONE physical core, so fake-device wall time cannot
show parallel speedup (all "workers" share the core — reported honestly
in the wall_s column). The scalability claim is therefore made the way
the dry-run makes all TPU claims: from the partitioned work itself.
``modeled_speedup`` = total cost / max per-worker cost after LPT
balancing — the critical-path speedup a real cluster realizes (the
paper's Fig. 5 numbers are wall-clock on EC2; ours are the same
quantity modeled). Exactness across worker counts is verified as part
of the run.
"""
import os
import subprocess
import sys

from repro.core import build_oriented, build_plan
from repro.core.plan import balance_report
from repro.graphs import rmat

from .common import emit

SNIPPET = """
import time
from repro.graphs import rmat
from repro.engine import CliqueEngine, CountRequest
g = rmat(10, 12, seed=3, name="scal")
eng = CliqueEngine(g, backend="shard_map")
t0 = time.perf_counter()
r = eng.submit(CountRequest(k={k}, method="{method}", colors=10))
print(r.estimate, time.perf_counter() - t0)
"""


def run(n_dev: int, k: int, method: str) -> tuple[float, float]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", SNIPPET.format(k=k, method=method)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    est, secs = out.stdout.split()[-2:]
    return float(est), float(secs)


def main() -> None:
    g = rmat(10, 12, seed=3, name="scal")
    og = build_oriented(g)
    for k, method in [(4, "exact"), (5, "exact"), (5, "color_smooth")]:
        plan = build_plan(og, k)
        total = plan.total_cost
        ests = set()
        for n_dev in (1, 2, 4, 8):
            est, secs = run(n_dev, k, method)
            ests.add(round(est, 3))
            rep = balance_report(plan, og, n_dev)
            modeled = total / max(rep["max"], 1.0)
            name = f"SI_{k}" if method == "exact" else f"SIC_{k}"
            emit(f"fig5/{name}/w{n_dev}", secs,
                 f"modeled_speedup={modeled:.2f};"
                 f"imbalance={rep['imbalance']:.2f};est={est:.0f}")
        assert len(ests) == 1, f"estimate changed with workers: {ests}"


if __name__ == "__main__":
    main()
