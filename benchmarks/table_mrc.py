"""Theorem 1 empirically: total space O(m^{3/2}), local space O(m),
total work O(m^{k/2}), Lemma 1 |Γ⁺| ≤ 2√m — measured across a size
ladder and reported as ratios to the bound (must stay bounded by a
constant as m grows)."""
import numpy as np

from repro.core import build_oriented, build_plan, check_lemma1
from repro.core.mrc import compute_stats

from .common import emit
from repro.graphs import rmat


def main() -> None:
    for scale in (8, 9, 10, 11, 12):
        g = rmat(scale, 8, seed=5, name=f"rmat{scale}")
        og = build_oriented(g)
        plan = build_plan(og, 4)
        st = compute_stats(og, plan)
        m = float(max(g.m, 1))
        emit(f"mrc/rmat{scale}", 0.0,
             f"m={g.m};space_ratio={st.round2_pairs / m ** 1.5:.3f};"
             f"work_ratio={st.total_work / m ** 2:.4f};"
             f"maxdeg_ratio={og.out_deg.max() / (2 * np.sqrt(m)):.3f};"
             f"lemma1={check_lemma1(g, og.out_deg)}")


if __name__ == "__main__":
    main()
