"""Paper Figure 1: benchmark statistics — n, m, MB, q3, q4, q5.

The paper's point: counts explode with k (tens/hundreds of billions on
real graphs). At our scale the explosion is visible as q5 >> q3 on the
clustered instances.
"""
from repro.core import count_cliques

from .common import bench_suite, emit, timed


def main() -> None:
    for g in bench_suite():
        qs = {}
        total = 0.0
        for k in (3, 4, 5):
            res, dt = timed(count_cliques, g, k)
            qs[k] = res.count
            total += dt
        emit(f"table1/{g.name}", total,
             f"n={g.n};m={g.m};MB={g.storage_mb():.1f};"
             f"q3={qs[3]};q4={qs[4]};q5={qs[5]}")


if __name__ == "__main__":
    main()
