"""Paper Figure 2: running time of NI++/SI_k/SIC_k + SIC_k error %.

Reproduces the claims: (1) NI++ beats SI_3 modestly (fewer rounds → in
our engine, no round-3 subgraph materialization); (2) SI_k extends to
k=4,5 within similar time; (3) SIC_k (10 colors ⇒ p=0.1, the paper's
setting) is dramatically faster at k=5 with error well under a few %.
Three runs per estimator, as in the paper.
"""
import numpy as np

from repro.core import count_cliques

from .common import bench_suite, emit, timed


def main() -> None:
    for g in bench_suite():
        exact = {}
        _, t_ni = timed(count_cliques, g, 3, method="ni++")
        emit(f"fig2/{g.name}/NI++", t_ni, "k=3")
        for k in (3, 4, 5):
            res, dt = timed(count_cliques, g, k)
            exact[k] = res.count
            emit(f"fig2/{g.name}/SI_{k}", dt, f"q{k}={res.count}")
        for k in (3, 4, 5):
            ests, dts = [], []
            for seed in range(3):
                res, dt = timed(count_cliques, g, k,
                                method="color_smooth", colors=10,
                                seed=seed)
                ests.append(res.estimate)
                dts.append(dt)
            err = abs(np.mean(ests) - exact[k]) / max(exact[k], 1) * 100
            emit(f"fig2/{g.name}/SIC_{k}", float(np.mean(dts)),
                 f"err%={err:.2f};exact={exact[k]};"
                 f"est={np.mean(ests):.0f}")


if __name__ == "__main__":
    main()
