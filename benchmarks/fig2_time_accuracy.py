"""Paper Figure 2: running time of NI++/SI_k/SIC_k + SIC_k error %.

Reproduces the claims: (1) NI++ beats SI_3 modestly (fewer rounds → in
our engine, no round-3 subgraph materialization); (2) SI_k extends to
k=4,5 within similar time; (3) SIC_k (10 colors ⇒ p=0.1, the paper's
setting) is dramatically faster at k=5 with error well under a few %.
Three runs per estimator, as in the paper.

All queries for one graph go through ONE engine session, so the timing
rows measure the amortized per-query cost the paper's per-job Hadoop
numbers could never reach: plan + CSR are built once per graph, and the
SIC sweep reuses the SI executables' plans from cache.
"""
import numpy as np

from repro.engine import CountRequest

from .common import bench_suite, emit, session, timed


def main() -> None:
    for g in bench_suite():
        eng = session(g)
        exact = {}
        # warm every (k, method) pair's plan + executables untimed so
        # all rows measure the steady-state per-query cost on equal
        # footing (executable cache keys include the method, so the
        # exact AND sampled paths each need a warm pass; otherwise the
        # first query of a row absorbs one-time plan build + compile)
        for k in (3, 4, 5):
            eng.submit(CountRequest(k=k))
            eng.submit(CountRequest(k=k, method="color_smooth",
                                    colors=10, seed=0))
        _, t_ni = timed(eng.submit, CountRequest(k=3, method="ni++"))
        emit(f"fig2/{g.name}/NI++", t_ni, "k=3")
        for k in (3, 4, 5):
            rep, dt = timed(eng.submit, CountRequest(k=k))
            exact[k] = rep.count
            emit(f"fig2/{g.name}/SI_{k}", dt,
                 f"q{k}={rep.count};plan_cache={rep.cache['plan']}")
        for k in (3, 4, 5):
            ests, dts, hits = [], [], 0
            for seed in range(3):
                rep, dt = timed(eng.submit, CountRequest(
                    k=k, method="color_smooth", colors=10, seed=seed))
                ests.append(rep.estimate)
                dts.append(dt)
                hits += rep.cache["exec_hits"]
            err = abs(np.mean(ests) - exact[k]) / max(exact[k], 1) * 100
            emit(f"fig2/{g.name}/SIC_{k}", float(np.mean(dts)),
                 f"err%={err:.2f};exact={exact[k]};"
                 f"est={np.mean(ests):.0f};exec_hits={hits}")


if __name__ == "__main__":
    main()
