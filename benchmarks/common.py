"""Shared benchmark utilities + the benchmark graph suite.

SNAP datasets aren't available offline; the suite mirrors the *roles* of
the paper's three graphs (Figure 1) at CPU-tractable scale:
  webBerk-like : dense web-ish RMAT (high clustering, heavy tail)
  skitter-like : sparser RMAT
  lj-like      : preferential-attachment (BA) graph
Sizes are chosen so exact q5 is computable on one CPU core in seconds —
the point is validating the *system*, not racing Hadoop.
"""
from __future__ import annotations

import time

from repro.engine import CliqueEngine
from repro.graphs import barabasi_albert, rmat


def bench_suite():
    return [
        rmat(10, edge_factor=16, a=0.65, b=0.15, c=0.15, seed=7,
             name="webBerk-like"),
        rmat(11, edge_factor=8, seed=11, name="skitter-like"),
        barabasi_albert(3000, 10, seed=13, name="lj-like"),
    ]


def session(g, backend: str = "local") -> CliqueEngine:
    """One engine session per benchmark graph: every driver measures
    *queries*, with the orient/upload cost paid once and reported by the
    session stats instead of polluting each timing row."""
    return CliqueEngine(g, backend=backend)


def timed(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived (per the harness contract)."""
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
