"""Per-method time-vs-accuracy frontier on the degree-skewed corpus
graph (the paper's Fig. 2 story, productized across the portfolio).

The paper plots running time against SIC_k error for hand-picked color
counts; ``repro.estimator`` inverts the interface — the caller states a
relative-error target and each method's lever finds its cheapest
operating point meeting it (or proves exact is cheaper). This driver
sweeps the target on the largest (planted, heavy-tailed) conformance
graph at k=5 for every portfolio member — color coding, wedge
sampling, sparsification, and the auto portfolio race — and reports,
per (method, target): wall time, the reported CI, the realized error
vs the golden count, and the speedup over the exact query on the same
warm session.

Asserted claims (the acceptance bar for the estimator subsystem),
checked before the record is appended to ``BENCH_estimator.json``:

- every reported CI contains the true count and every realized error
  is within the reported ``achieved_rel_error``;
- at the 5%/99% contract, wedge sampling is strictly faster than color
  coding — the new lever must beat the paper's SIC_k baseline exactly
  where it is built to win (degree skew);
- auto at 5%/99% resolves through a sampling lever (wedge or sparsify
  or subset — not exact fall-through) and lands within 1.5× of the
  best single method's wall: the portfolio race may not cost more than
  half again the oracle choice;
- auto at the 5% target stays ≥ 3× faster than exact (the pre-redesign
  bar, kept).

``scripts/check_bench.py --estimator`` replays these contracts from
the appended record and gates wall-clock drift run-over-run.
"""
import json
import os
import sys
import time

from repro.engine import CountRequest
from repro.estimator import Auto, from_string
from repro.graphs import conformance_corpus

from .common import emit, session, timed

TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_estimator.json")
FIXTURE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures",
    "golden_counts.json")
K = 5
TARGETS = (0.2, 0.1, 0.05)
METHODS = ("color", "wedge", "sparsify")   # single-lever frontier


def _append_trajectory(rows: list) -> None:
    """Same atomic accumulate-across-PRs idiom as kernels_bench."""
    import jax
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except ValueError:
            os.replace(TRAJECTORY, TRAJECTORY + ".corrupt")
            print(f"# unreadable {TRAJECTORY} moved aside; starting a "
                  f"fresh trajectory", file=sys.stderr, flush=True)
    history.append({
        "ran_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "bench": "estimator",
        "backend": jax.default_backend(),
        "host": "ci" if os.environ.get("CI") else "dev",
        "rows": rows,
    })
    tmp = TRAJECTORY + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, TRAJECTORY)


def _contract_run(eng, method, rel, truth):
    """Adaptive run at (method, rel): best-of-3-seeds wall + the first
    seed's report, with the honesty contracts asserted for all three."""
    reps, dts = [], []
    for seed in range(3):
        m = Auto(rel_error=rel, confidence=0.99) if method == "auto" \
            else from_string(method)
        rep, dt = timed(eng.submit, CountRequest(
            k=K, method=m, rel_error=rel, confidence=0.99, seed=seed))
        reps.append(rep)
        dts.append(dt)
    for r in reps:
        assert r.ci_low <= truth <= r.ci_high, \
            (method, rel, truth, r.ci_low, r.ci_high)
        realized = abs(r.estimate - truth)
        assert realized <= r.achieved_rel_error \
            * max(abs(r.estimate), 1.0) + 1e-9, (method, rel, realized)
    return reps[0], min(dts)


def main() -> None:
    g = max(conformance_corpus(), key=lambda g: g.m)
    with open(FIXTURE) as f:
        truth = json.load(f)[g.name]["counts"][str(K)]
    eng = session(g)
    # warm: exact plan+tiles, then one adaptive query per method
    # (density certificates, per-lever executables) so every row
    # measures steady-state query cost
    eng.submit(CountRequest(k=K))
    for m in METHODS + ("auto",):
        _contract_run(eng, m, max(TARGETS), truth)
    exact_rep, t_exact = timed(eng.submit, CountRequest(k=K), repeat=3)
    assert exact_rep.count == truth, (exact_rep.count, truth)
    emit(f"estimator/{g.name}/exact_k{K}", t_exact, f"q{K}={truth}")
    rows = [{"graph": g.name, "method": "exact", "rel": 0.0,
             "wall_us": t_exact * 1e6, "covered": True,
             "resolved": "exact", "speedup": 1.0}]

    walls = {}     # (method, rel) -> best wall
    for rel in TARGETS:
        for method in METHODS + ("auto",):
            rep, wall = _contract_run(eng, method, rel, truth)
            walls[(method, rel)] = wall
            err = abs(rep.estimate - truth) / truth
            port = (rep.estimator or {}).get("portfolio") or {}
            row = {"graph": g.name, "method": method, "rel": rel,
                   "wall_us": wall * 1e6,
                   "estimate": rep.estimate, "err": err,
                   "ci": [rep.ci_low, rep.ci_high], "covered": True,
                   "resolved": rep.params["resolved"],
                   "speedup": t_exact / wall}
            if method == "auto":
                row["winner"] = port.get("winner")
            rows.append(row)
            emit(f"estimator/{g.name}/{method}_rel{rel}", wall,
                 f"est={rep.estimate:.0f};err%={err * 100:.2f};"
                 f"resolved={rep.params['resolved']};"
                 f"winner={port.get('winner')};"
                 f"speedup={t_exact / wall:.2f}x")

    # -- the frontier contracts (asserted before the record lands) -----
    assert walls[("wedge", 0.05)] < walls[("color", 0.05)], \
        ("wedge must beat color coding on the degree-skewed graph",
         walls[("wedge", 0.05)], walls[("color", 0.05)])
    best_single = min(walls[(m, 0.05)] for m in METHODS)
    within = walls[("auto", 0.05)] / best_single
    auto_row = next(r for r in rows
                    if r["method"] == "auto" and r["rel"] == 0.05)
    auto_row["within_best"] = within
    assert within <= 1.5, \
        f"auto at 5% is {within:.2f}x the best single method (> 1.5x)"
    assert auto_row["resolved"] == "sampled" and auto_row["winner"], \
        ("auto at 5% must certify via a sampling lever, not fall "
         "through exact", auto_row)
    speedup_at_5pct = t_exact / walls[("auto", 0.05)]
    assert speedup_at_5pct >= 3.0, \
        f"auto at 5% target only {speedup_at_5pct:.2f}x faster than exact"

    stats = eng.session_stats()["estimator"]
    emit(f"estimator/{g.name}/controller", 0.0,
         f"queries={stats['queries']};sampled={stats['sampled']};"
         f"fallthroughs={stats['fallthroughs']};"
         f"winners={stats['winners']};"
         f"auto_within_best={within:.2f}x")
    _append_trajectory(rows)


if __name__ == "__main__":
    main()
