"""Time-vs-accuracy frontier with the adaptive controller choosing the
operating point (the paper's Fig. 2 story, productized).

The paper plots running time against SIC_k error for hand-picked color
counts; ``repro.estimator`` inverts the interface — the caller states a
relative-error target and the controller finds the cheapest operating
point meeting it (or proves exact is cheaper). This driver sweeps the
target on the largest conformance-corpus graph at k=5 and reports, per
target: wall time, the reported CI, the realized error vs the golden
count, and the speedup over the exact query on the same warm session.

Asserted claims (the acceptance bar for the estimator subsystem):
- at the 5% target the controller is ≥ 3× faster than exact,
- every reported CI contains the true count,
- every realized error is within the reported ``achieved_rel_error``.
"""
import json
import os

from repro.engine import CountRequest
from repro.graphs import conformance_corpus

from .common import emit, session, timed

FIXTURE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "fixtures",
    "golden_counts.json")
K = 5
TARGETS = (0.2, 0.1, 0.05)


def main() -> None:
    g = max(conformance_corpus(), key=lambda g: g.m)
    with open(FIXTURE) as f:
        truth = json.load(f)[g.name]["counts"][str(K)]
    eng = session(g)
    # warm: exact plan+tiles, then one auto query (density certificates,
    # subset executables) so every row measures steady-state query cost
    eng.submit(CountRequest(k=K))
    eng.submit(CountRequest(k=K, method="auto", rel_error=min(TARGETS)))
    exact_rep, t_exact = timed(eng.submit, CountRequest(k=K), repeat=3)
    assert exact_rep.count == truth, (exact_rep.count, truth)
    emit(f"estimator/{g.name}/exact_k{K}", t_exact, f"q{K}={truth}")
    speedup_at_5pct = None
    for rel in TARGETS:
        reps, dts = [], []
        for seed in range(3):
            rep, dt = timed(eng.submit, CountRequest(
                k=K, method="auto", rel_error=rel, confidence=0.99,
                seed=seed))
            reps.append(rep)
            dts.append(dt)
        t_auto = min(dts)
        speedup = t_exact / t_auto
        rep = reps[0]
        err = abs(rep.estimate - truth) / truth
        emit(f"estimator/{g.name}/auto_rel{rel}", t_auto,
             f"est={rep.estimate:.0f};err%={err * 100:.2f};"
             f"ci=[{rep.ci_low:.0f},{rep.ci_high:.0f}];"
             f"achieved={rep.achieved_rel_error:.4f};"
             f"resolved={rep.params['resolved']};"
             f"level={rep.estimator['level']};"
             f"reps={rep.estimator['replicates']};"
             f"speedup={speedup:.2f}x")
        for r in reps:
            assert r.ci_low <= truth <= r.ci_high, \
                (rel, truth, r.ci_low, r.ci_high)
            realized = abs(r.estimate - truth)
            assert realized <= r.achieved_rel_error \
                * max(abs(r.estimate), 1.0) + 1e-9, (rel, realized)
        if rel == 0.05:
            speedup_at_5pct = speedup
    assert speedup_at_5pct is not None and speedup_at_5pct >= 3.0, \
        f"auto at 5% target only {speedup_at_5pct:.2f}x faster than exact"
    stats = eng.session_stats()["estimator"]
    emit(f"estimator/{g.name}/controller", 0.0,
         f"queries={stats['queries']};sampled={stats['sampled']};"
         f"fallthroughs={stats['fallthroughs']};"
         f"replicates={stats['replicates']}")


if __name__ == "__main__":
    main()
