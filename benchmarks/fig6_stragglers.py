"""Paper Figure 6: the curse of the last reducer — and its cure.

(a) cost-distribution tail: slowest unit vs the x-th slowest (Fig 6b of
    the paper);
(b) per-worker imbalance after LPT *without* the split round — at 64+
    workers a single heavy G⁺(u) dominates and imbalance explodes,
    which is precisely the paper's observation;
(c) the §6 split round applied with a worker-count-aware threshold
    (max unit cost ≤ total/W): imbalance returns to ~1, global work
    unchanged — the paper's space-for-time trade, executed.

``--scheduler`` adds the *runtime* counterpart on the out-of-core
backend (``repro.scheduler``): wall-clock with and without straggler
speculation under an injected 10×-task-time straggler, asserting that
speculation recovers at least 2× of the penalty, plus the out-of-core
memory claim (largest shard slice ≪ the single-host CSR footprint).

``--distributed`` adds the multi-host counterpart: a clean 3-executor
coordinator run, a chaos run with one executor SIGKILLed mid-flight
(same count bit-exact, ≥1 lease expiry + reassignment), and a
``resume=True`` rerun that replays the ledger without re-executing a
single task — its ``recovery_ratio`` (chaos wall / resume wall) is the
price of the ledger-as-commit-protocol contract and must stay ≥ 2×.

One record per run (rows from every section run) is appended to
``BENCH_scheduler.json`` — the trajectory
``scripts/check_bench.py --scheduler`` gates.
"""
import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import build_oriented, build_plan
from repro.core.plan import balance_report, unit_cost
from repro.core.split import split_heavy

from .common import bench_suite, emit

TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_scheduler.json")


def _split_imbalance(og, k: int, n_workers: int) -> tuple[float, int]:
    plan = build_plan(og, k)
    d = og.out_deg[og.out_deg >= k - 1].astype(np.float64)
    costs = d ** (k - 1)
    target = max(costs.sum() / n_workers, 1.0)
    # threshold: largest degree whose unit cost stays under the target
    thr = max(int(target ** (1.0 / (k - 1))), k - 1)
    light_plan, splits = split_heavy(plan, og, k, thr)
    # unit costs after split: light d^{k-1}; split units D_parent^{k-2}
    unit_costs = []
    for b in light_plan.buckets:
        real = b.nodes[:b.n_real]
        unit_costs.extend(unit_cost(og.out_deg[real], k).tolist())
    n_split_units = 0
    for sp in splits:
        real = sp.nodes[:sp.n_real]
        unit_costs.extend(
            (og.out_deg[np.maximum(real, 0)].astype(np.float64)
             ** (k - 2)).tolist())
        n_split_units += sp.n_real
    unit_costs = np.sort(np.array(unit_costs))[::-1]
    loads = np.zeros(n_workers)
    for c in unit_costs:                       # LPT
        loads[np.argmin(loads)] += c
    return float(loads.max() / max(loads.mean(), 1e-9)), n_split_units


def _ooc_run(g, spill: str, *, straggle_s: float = 0.0,
             speculate: bool = True, hot: str = "") -> dict:
    """One fresh ooc query; returns the scheduler telemetry. A non-zero
    ``straggle_s`` delays the first execution of task ``hot`` only —
    the injected straggler speculative re-execution must route around."""
    from repro.engine import CliqueEngine, CountRequest
    from repro.scheduler import SchedulerConfig

    hook = None
    if straggle_s > 0:
        def hook(tid, ei, _hot=hot, _d=straggle_s):
            return _d if (tid == _hot and ei == 0) else 0.0
    eng = CliqueEngine(g, ooc=SchedulerConfig(
        n_workers=4, spill_dir=spill, target_tasks=24,
        speculate=speculate, speculation_factor=2.0,
        speculation_min_s=0.1, poll_s=0.005, delay_hook=hook))
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    tel["count"] = rep.count
    return tel


def scheduler_section() -> dict:
    """Wall-clock with/without speculation under an injected straggler,
    on the planted benchmark graph, via the real ooc backend."""
    from repro.graphs import planted_cliques
    from repro.scheduler import compile_tasks
    from repro.engine import CliqueEngine, CountRequest

    g = planted_cliques(2500, 0.008, [14, 12, 12, 10], seed=3,
                        name="planted-ooc")
    spill = tempfile.mkdtemp(prefix="bench-ooc-")

    # warm pass: compiles every tile size class, spills the shards, and
    # gives the clean-run baseline the two chaos runs are judged against
    warm = _ooc_run(g, spill)
    base = _ooc_run(g, spill)
    base_wall = base["wall_s"]
    assert base["count"] == warm["count"]

    # the injected straggler: 10× a typical task of the clean run
    task_s = base_wall * base["n_workers"] / max(base["tasks"], 1)
    straggle = max(10.0 * task_s, 1.0)
    probe = CliqueEngine(g)
    req = CountRequest(k=4)
    entry, _ = probe._plan_entry(req)
    from repro.scheduler import SchedulerConfig as _SC
    hot = compile_tasks(entry, probe.og, req,
                        elem_budget=_SC().tile_elem_budget,
                        target_tasks=24)[0].task_id

    nospec = _ooc_run(g, spill, straggle_s=straggle, speculate=False,
                      hot=hot)
    spec = _ooc_run(g, spill, straggle_s=straggle, speculate=True,
                    hot=hot)
    assert spec["count"] == base["count"] == nospec["count"]
    assert spec["speculated"] >= 1, spec

    penalty_nospec = max(nospec["wall_s"] - base_wall, 1e-9)
    penalty_spec = max(spec["wall_s"] - base_wall, 1e-9)
    recovery = penalty_nospec / penalty_spec
    # the satellite's contract: speculation must claw back ≥2× of the
    # straggler penalty (first-result-wins routes around the slow copy)
    assert recovery >= 2.0, (
        f"speculation recovered only {recovery:.2f}x of the straggler "
        f"penalty (base={base_wall:.2f}s nospec={nospec['wall_s']:.2f}s "
        f"spec={spec['wall_s']:.2f}s)")

    # the out-of-core memory claim: the largest slice any worker holds
    # is well below the single-host CSR footprint
    slice_frac = base["max_slice_bytes"] / base["csr_bytes"]
    assert slice_frac < 0.5, (
        f"largest shard slice is {slice_frac:.2f} of the full CSR — "
        "not meaningfully out-of-core")

    emit(f"fig6d/{g.name}/speculation", spec["wall_s"],
         f"base={base_wall:.3f}s;nospec={nospec['wall_s']:.3f}s;"
         f"straggle={straggle:.2f}s;recovery={recovery:.1f}x")
    emit(f"fig6d/{g.name}/memory", 0.0,
         f"max_slice_bytes={base['max_slice_bytes']};"
         f"csr_bytes={base['csr_bytes']};frac={slice_frac:.3f}")

    return {"graph": g.name, "k": 4, "tasks": base["tasks"],
            "n_workers": base["n_workers"],
            "base_wall_us": base_wall * 1e6,
            "nospec_wall_us": nospec["wall_s"] * 1e6,
            "spec_wall_us": spec["wall_s"] * 1e6,
            "straggle_us": straggle * 1e6,
            "recovery_ratio": recovery,
            "stolen": base["stolen"],
            "max_slice_bytes": base["max_slice_bytes"],
            "csr_bytes": base["csr_bytes"],
            "slice_frac": slice_frac}


def _dist_run(g, spill: str, *, chaos: str = None, resume: bool = False,
              task_delay: float = 0.0, lease: float = 5.0) -> dict:
    """One fresh 3-executor coordinator query; returns the telemetry."""
    from repro.engine import CliqueEngine, CountRequest
    from repro.scheduler import SchedulerConfig

    eng = CliqueEngine(g, ooc=SchedulerConfig(
        executors=3, spill_dir=spill, target_tasks=24,
        lease_s=lease, poll_s=0.005, task_delay_s=task_delay,
        chaos=chaos, resume=resume))
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    tel["count"] = rep.count
    return tel


def distributed_section() -> dict:
    """Kill-recovery on the multi-host pool: clean 3-executor run →
    chaos run with one executor SIGKILLed mid-flight → ledger resume.
    The gated ``recovery_ratio`` here is chaos wall / resume wall: how
    much of a killed run's cost the commit protocol refunds."""
    from repro.graphs import planted_cliques

    g = planted_cliques(2500, 0.008, [14, 12, 12, 10], seed=3,
                        name="planted-ooc-dist")
    spill = tempfile.mkdtemp(prefix="bench-dist-")

    # warm pass compiles + spills; base is the clean-run yardstick
    warm = _dist_run(g, spill)
    base = _dist_run(g, spill)
    assert base["count"] == warm["count"]
    assert base["executors"] == 3 and base["run"] == base["tasks"]

    # per-task pacing stretches the run so the kill lands mid-flight;
    # kill:1@1 SIGKILLs executor 1 once it holds a lease past the
    # first commit — the EOF-expiry + reassignment path, for real
    chaos = _dist_run(g, spill, chaos="kill:1@1", task_delay=0.05,
                      lease=1.0)
    assert chaos["count"] == base["count"], (chaos, base)
    assert chaos["lease_expiries"] >= 1, chaos
    assert chaos["reassigned"] >= 1, chaos
    assert chaos["chaos"] == ["kill:1"], chaos

    # the refund: resume replays the completed ledger — zero tasks
    # re-executed, no port bound, no executor spawned
    resumed = _dist_run(g, spill, resume=True)
    assert resumed["count"] == base["count"]
    assert resumed["run"] == 0, resumed
    assert resumed["resumed"] == resumed["tasks"], resumed

    recovery = chaos["wall_s"] / max(resumed["wall_s"], 1e-9)
    assert recovery >= 2.0, (
        f"ledger resume refunded only {recovery:.2f}x of the killed "
        f"run (chaos={chaos['wall_s']:.2f}s "
        f"resume={resumed['wall_s']:.2f}s)")
    slice_frac = base["max_slice_bytes"] / base["csr_bytes"]

    emit(f"fig6e/{g.name}/kill-recovery", chaos["wall_s"],
         f"base={base['wall_s']:.3f}s;resume={resumed['wall_s']:.3f}s;"
         f"lease_expiries={chaos['lease_expiries']};"
         f"recovery={recovery:.1f}x")

    return {"graph": g.name, "k": 4, "tasks": base["tasks"],
            "n_workers": base["executors"],
            "base_wall_us": base["wall_s"] * 1e6,
            "chaos_wall_us": chaos["wall_s"] * 1e6,
            "resume_wall_us": resumed["wall_s"] * 1e6,
            "lease_expiries": chaos["lease_expiries"],
            "reassigned": chaos["reassigned"],
            "recovery_ratio": recovery,
            "stolen": base["stolen"],
            "max_slice_bytes": base["max_slice_bytes"],
            "csr_bytes": base["csr_bytes"],
            "slice_frac": slice_frac}


def _append_trajectory(rows: list) -> None:
    """Same atomic accumulate-across-PRs idiom as kernels_bench."""
    import jax
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except ValueError:
            os.replace(TRAJECTORY, TRAJECTORY + ".corrupt")
            print(f"# unreadable {TRAJECTORY} moved aside; starting a "
                  f"fresh trajectory", file=sys.stderr, flush=True)
    history.append({
        "ran_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "host": "ci" if os.environ.get("CI") else "dev",
        "rows": rows,
    })
    tmp = TRAJECTORY + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, TRAJECTORY)
    print(f"# scheduler trajectory appended to {TRAJECTORY} "
          f"({len(history)} records)", file=sys.stderr, flush=True)


def main(scheduler: bool = False, distributed: bool = False) -> None:
    for g in bench_suite():
        og = build_oriented(g)
        k = 5
        plan = build_plan(og, k)
        costs = np.sort(unit_cost(og.out_deg[og.out_deg >= k - 1], k))
        slowest = costs[-1]
        ratios = {x: float(slowest / costs[-x])
                  for x in (10, 100, 1000) if len(costs) >= x}
        emit(f"fig6a/{g.name}", 0.0,
             ";".join(f"slowest/x{x}={r:.1f}" for x, r in ratios.items()))
        for w in (8, 64, 256):
            rep = balance_report(plan, og, w)
            post, n_units = _split_imbalance(og, k, w)
            emit(f"fig6b/{g.name}/w{w}", 0.0,
                 f"imbalance_no_split={rep['imbalance']:.2f};"
                 f"imbalance_with_split={post:.2f};"
                 f"split_units={n_units}")
    # one record for however many sections ran, so the nightly gate
    # compares rows like-for-like across consecutive records
    rows = []
    if scheduler:
        rows.append(scheduler_section())
    if distributed:
        rows.append(distributed_section())
    if rows:
        _append_trajectory(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", action="store_true",
                    help="also run the out-of-core scheduler section "
                         "(appends to BENCH_scheduler.json)")
    ap.add_argument("--distributed", action="store_true",
                    help="also run the multi-host kill-recovery section "
                         "(3 executors, one SIGKILLed, ledger resume; "
                         "appends to BENCH_scheduler.json)")
    args = ap.parse_args()
    main(scheduler=args.scheduler, distributed=args.distributed)
