"""Paper Figure 6: the curse of the last reducer — and its cure.

(a) cost-distribution tail: slowest unit vs the x-th slowest (Fig 6b of
    the paper);
(b) per-worker imbalance after LPT *without* the split round — at 64+
    workers a single heavy G⁺(u) dominates and imbalance explodes,
    which is precisely the paper's observation;
(c) the §6 split round applied with a worker-count-aware threshold
    (max unit cost ≤ total/W): imbalance returns to ~1, global work
    unchanged — the paper's space-for-time trade, executed.
"""
import numpy as np

from repro.core import build_oriented, build_plan
from repro.core.plan import balance_report, unit_cost
from repro.core.split import split_heavy

from .common import bench_suite, emit


def _split_imbalance(og, k: int, n_workers: int) -> tuple[float, int]:
    plan = build_plan(og, k)
    d = og.out_deg[og.out_deg >= k - 1].astype(np.float64)
    costs = d ** (k - 1)
    target = max(costs.sum() / n_workers, 1.0)
    # threshold: largest degree whose unit cost stays under the target
    thr = max(int(target ** (1.0 / (k - 1))), k - 1)
    light_plan, splits = split_heavy(plan, og, k, thr)
    # unit costs after split: light d^{k-1}; split units D_parent^{k-2}
    unit_costs = []
    for b in light_plan.buckets:
        real = b.nodes[:b.n_real]
        unit_costs.extend(unit_cost(og.out_deg[real], k).tolist())
    n_split_units = 0
    for sp in splits:
        real = sp.nodes[:sp.n_real]
        unit_costs.extend(
            (og.out_deg[np.maximum(real, 0)].astype(np.float64)
             ** (k - 2)).tolist())
        n_split_units += sp.n_real
    unit_costs = np.sort(np.array(unit_costs))[::-1]
    loads = np.zeros(n_workers)
    for c in unit_costs:                       # LPT
        loads[np.argmin(loads)] += c
    return float(loads.max() / max(loads.mean(), 1e-9)), n_split_units


def main() -> None:
    for g in bench_suite():
        og = build_oriented(g)
        k = 5
        plan = build_plan(og, k)
        costs = np.sort(unit_cost(og.out_deg[og.out_deg >= k - 1], k))
        slowest = costs[-1]
        ratios = {x: float(slowest / costs[-x])
                  for x in (10, 100, 1000) if len(costs) >= x}
        emit(f"fig6a/{g.name}", 0.0,
             ";".join(f"slowest/x{x}={r:.1f}" for x, r in ratios.items()))
        for w in (8, 64, 256):
            rep = balance_report(plan, og, w)
            post, n_units = _split_imbalance(og, k, w)
            emit(f"fig6b/{g.name}/w{w}", 0.0,
                 f"imbalance_no_split={rep['imbalance']:.2f};"
                 f"imbalance_with_split={post:.2f};"
                 f"split_units={n_units}")


if __name__ == "__main__":
    main()
