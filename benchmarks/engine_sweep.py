"""Session amortization: ``submit_many`` sweeps on one CliqueEngine.

The scenario the engine API exists for: a session serving many
(k, method) queries on one graph, on the shard_map backend — where the
seed API (`count_cliques_distributed`) rebuilt and recompiled
`jit(shard_map(...))` executables on every call. Three measurements per
graph:

  naive   — fresh engine per query: the seed cost model (re-orient,
            re-upload, re-plan, and rebuild every jit(shard_map)
            executable per call)
  session — one engine, ``submit_many`` over k=3,4,5 exact + k=3..7
            color_smooth (cold: compiles each executable once)
  warm    — the same sweep resubmitted on the same session (every plan,
            shard stack, and executable cached — a server's steady state)

An untimed warm pass first absorbs process-global one-time costs
(device init, the module-jitted local tile paths) so the rows isolate
what the *session* saves: per-query shard_map retrace/compile + orient
+ upload + planning. Graphs are serving-scale; fig2/fig5 cover
paper-scale single-query cost.
"""
import time

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import barabasi_albert, rmat

from .common import emit

BACKEND = "shard_map"


def _graphs():
    return [rmat(9, 8, seed=7, name="serve-rmat9"),
            barabasi_albert(1200, 8, seed=13, name="serve-ba1200")]


def _sweep_requests():
    return ([CountRequest(k=k) for k in (3, 4, 5)] +
            [CountRequest(k=k, method="color_smooth", colors=10, seed=0)
             for k in (3, 4, 5, 6, 7)])


def main() -> None:
    for g in _graphs():
        reqs = _sweep_requests()

        for r in reqs:  # untimed: absorb process-global one-time costs
            CliqueEngine(g, backend=BACKEND).submit(r)

        t0 = time.perf_counter()
        naive = [CliqueEngine(g, backend=BACKEND).submit(r) for r in reqs]
        t_naive = time.perf_counter() - t0

        # decorrelate=False: the naive baseline above submitted each
        # request verbatim, and the cold-vs-naive estimate equality check
        # below needs identical seeds, not a decorrelated sweep
        t0 = time.perf_counter()
        eng = CliqueEngine(g, backend=BACKEND)
        cold = eng.submit_many(reqs, decorrelate=False)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = eng.submit_many(reqs, decorrelate=False)
        t_warm = time.perf_counter() - t0

        for a, b in zip(cold, warm):
            assert a.estimate == b.estimate, (a.k, a.method)
        for a, b in zip(cold, naive):
            assert a.estimate == b.estimate, (a.k, a.method)

        stats = eng.session_stats()
        plan_hits = stats["plans"]["hits"]
        exec_hits = stats["executables"]["hits"]
        emit(f"engine_sweep/{g.name}/naive", t_naive / len(reqs),
             f"queries={len(reqs)};backend={BACKEND}")
        emit(f"engine_sweep/{g.name}/session_cold", t_cold / len(reqs),
             f"speedup_vs_naive={t_naive / max(t_cold, 1e-9):.2f}")
        emit(f"engine_sweep/{g.name}/session_warm", t_warm / len(reqs),
             f"speedup_vs_naive={t_naive / max(t_warm, 1e-9):.2f};"
             f"plan_hits={plan_hits};exec_hits={exec_hits}")


if __name__ == "__main__":
    main()
