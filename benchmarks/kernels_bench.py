"""Kernel micro-benchmarks: jnp reference vs Pallas(interpret) counting
path, plus analytic MXU utilization of the kernel's matmul shapes.

On CPU the interpret-mode wall time is meaningless for TPU; the derived
column therefore reports the *analytic* kernel FLOPs and the VMEM
working set per tile — the numbers the §Roofline section uses.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.count import dag_count
from repro.kernels.cliques import kernel_bytes, kernel_flops
from repro.kernels.cliques.ops import pick_tile

from .common import emit, timed


def main() -> None:
    rng = np.random.default_rng(0)
    for D in (128, 256, 512):
        B = max(1, 1 << 22 >> (2 * int(np.log2(D))))
        A = jnp.asarray(
            np.triu((rng.random((B, D, D)) < 0.2), 1).astype(np.float32))
        for r in (2, 3, 4):
            out, dt = timed(lambda: dag_count(A, r).block_until_ready(),
                            repeat=2)
            fl = kernel_flops(B, D, r)
            tb = pick_tile(D)
            vmem = tb * D * D * 4 / 2 ** 20
            emit(f"kernels/dag_count/D{D}/r{r}", dt,
                 f"B={B};flops={fl:.2e};tile_b={tb};"
                 f"vmem_tile_MiB={vmem:.1f};"
                 f"intensity={fl / kernel_bytes(B, D):.1f}")


if __name__ == "__main__":
    main()
