"""Kernel micro-benchmarks: dense f32 (MXU matmul identity) vs packed
uint32 bitset (AND+popcount) counting paths, side by side.

Each (D, r) row reports wall time for both jnp reference paths, the
per-tile HBM bytes of each representation (the packed tile is 32×
smaller — the tentpole claim, asserted ≥ 8× here), and the analytic
op counts (MXU FLOPs vs VPU word-ops) the §Roofline section uses. On
CPU the Pallas interpret-mode wall times are meaningless for TPU, so
the derived columns carry the analytic numbers.

The run is also appended to ``BENCH_kernels.json`` at the repo root —
one record per invocation — so successive PRs accumulate a perf
trajectory for the kernel layer.
"""
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.count import (dag_count, dag_count_bits,
                              dag_count_bits_ops, dag_count_flops,
                              tile_unit_bytes)
from repro.core.extract import pack_adjacency
from repro.kernels.cliques.ops import pick_tile

from .common import emit, timed

TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def append_trajectory(rows: list, bench: str = "kernels") -> None:
    """One record per benchmark run, accumulated across PRs. The write
    is atomic (tmp + replace) and a corrupt/empty history is set aside
    rather than crashing away the run's rows. ``bench`` tags the record
    so several benchmarks can share the file (scripts/check_bench.py
    compares like-tagged records only; untagged history predates the
    tag and means "kernels")."""
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except ValueError:
            os.replace(TRAJECTORY, TRAJECTORY + ".corrupt")
            print(f"# unreadable {TRAJECTORY} moved aside; starting a "
                  f"fresh trajectory", file=sys.stderr, flush=True)
    history.append({
        "ran_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "bench": bench,
        "backend": jax.default_backend(),
        # host provenance: wall-clock is only comparable between runs of
        # the same kind of machine (scripts/check_bench.py skips the
        # wall gate across a provenance change; bytes compare anywhere)
        "host": "ci" if os.environ.get("CI") else "dev",
        "rows": rows,
    })
    tmp = TRAJECTORY + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, TRAJECTORY)
    print(f"# kernel trajectory appended to {TRAJECTORY} "
          f"({len(history)} records)", file=sys.stderr, flush=True)


def main() -> None:
    rng = np.random.default_rng(0)
    dense_fn = jax.jit(dag_count, static_argnames=("r",))
    bits_fn = jax.jit(dag_count_bits, static_argnames=("r",))
    rows = []
    for D in (128, 256, 512):
        for r in (2, 3, 4):
            if r == 4 and D > 256:
                continue    # minutes of fori_loop on CPU; same trend
            # r=2 is so cheap that a small batch is dispatch-bound; use
            # the wide batch the engine would actually run there, so the
            # timing measures the kernels rather than launch overhead
            elems = 1 << 25 if r == 2 else 1 << 22
            B = max(1, elems >> (2 * int(np.log2(D))))
            A = jnp.asarray(np.triu((rng.random((B, D, D)) < 0.2), 1)
                            .astype(np.float32))
            bits = pack_adjacency(A)
            want, dt_dense = timed(
                lambda: dense_fn(A, r).block_until_ready(), repeat=3)
            got, dt_bits = timed(
                lambda: bits_fn(bits, r).block_until_ready(), repeat=3)
            assert np.array_equal(np.asarray(want), np.asarray(got)), \
                (D, r, "packed path disagrees with dense")
            row = {
                "D": D, "r": r, "B": B,
                "dense_us": dt_dense * 1e6, "bits_us": dt_bits * 1e6,
                "dense_tile_bytes": B * tile_unit_bytes(D, "dense"),
                "bits_tile_bytes": B * tile_unit_bytes(D, "bits"),
                "dense_flops": dag_count_flops(D, B, r),
                "bits_word_ops": dag_count_bits_ops(D, B, r),
                "mxu_tile_b": pick_tile(D),
            }
            row["bytes_ratio"] = (row["dense_tile_bytes"]
                                  / row["bits_tile_bytes"])
            row["speedup"] = dt_dense / max(dt_bits, 1e-12)
            rows.append(row)
            assert row["bytes_ratio"] >= 8.0, row    # tentpole claim
            emit(f"kernels/dense/D{D}/r{r}", dt_dense,
                 f"B={B};tile_MiB={row['dense_tile_bytes'] / 2**20:.1f};"
                 f"flops={row['dense_flops']:.2e}")
            emit(f"kernels/bits/D{D}/r{r}", dt_bits,
                 f"B={B};tile_MiB={row['bits_tile_bytes'] / 2**20:.2f};"
                 f"word_ops={row['bits_word_ops']:.2e};"
                 f"bytes_ratio={row['bytes_ratio']:.0f}x;"
                 f"speedup={row['speedup']:.1f}x")
    # the k=3 acceptance: packed jnp beats dense jnp at r=2 for D ≥ 256.
    # Measured margin is ~9x here, but this now runs in nightly CI on
    # shared runners — grant timer-noise headroom so the acceptance
    # tests the claim, not the scheduler (the 1.5x trend gate lives in
    # scripts/check_bench.py).
    for row in rows:
        if row["r"] == 2 and row["D"] >= 256:
            assert row["bits_us"] < 1.2 * row["dense_us"], row
    append_trajectory(rows)


if __name__ == "__main__":
    main()
