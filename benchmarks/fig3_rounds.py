"""Paper Figure 3: round-by-round running times.

Our rounds: R1 = orientation + CSR build (host sorts), R2 = batched
extraction (edge-lookup joins), R3 = counting kernel. The paper's
findings to check: R1 ~ constant in k; R2 dominated by 2-path volume,
shrinks under sampling; R3 grows with k and dominates for k=5; sampling
collapses R3.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import build_oriented, build_plan
from repro.core.count import _count_tile, _tile_batches
from repro.core.extract import extract_adjacency, to_device

from .common import bench_suite, emit


def rounds_for(g, k: int, method: str, colors: int = 10):
    t0 = time.perf_counter()
    og = build_oriented(g)
    plan = build_plan(og, k)
    csr = to_device(og)
    jax.block_until_ready(csr.offsets)
    t1 = time.perf_counter()
    # round 2: extraction only
    for b in plan.buckets:
        for tile in _tile_batches(b.nodes, b.capacity):
            A, _ = extract_adjacency(csr, jnp.asarray(tile),
                                     capacity=b.capacity,
                                     n_iters=og.lookup_iters)
            jax.block_until_ready(A)
    t2 = time.perf_counter()
    # rounds 2+3 fused (the production path): subtract to get round 3
    key = jax.random.PRNGKey(0)
    for b in plan.buckets:
        for tile in _tile_batches(b.nodes, b.capacity):
            v = _count_tile(csr, jnp.asarray(tile), key,
                            capacity=b.capacity, n_iters=og.lookup_iters,
                            r=k - 1, method=method, p=0.1, c=colors,
                            engine="jnp")
            jax.block_until_ready(v)
    t3 = time.perf_counter()
    return t1 - t0, t2 - t1, max(t3 - t2 - (t2 - t1), 0.0)


def main() -> None:
    for g in bench_suite()[:2]:
        for k in (4, 5):
            for method in ("exact", "color_smooth"):
                r1, r2, r3 = rounds_for(g, k, method)
                name = f"SI_{k}" if method == "exact" else f"SIC_{k}"
                emit(f"fig3/{g.name}/{name}", r1 + r2 + r3,
                     f"r1={r1:.2f};r2={r2:.2f};r3={r3:.2f}")


if __name__ == "__main__":
    main()
