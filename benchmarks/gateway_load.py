"""Gateway serving latency: store hits vs recompute.

The scenario the result store exists for: repeated production traffic
over a small set of hot graphs. Phase 1 (cold) runs a mixed workload
through a fresh :class:`ServingGateway` — every query executes on an
engine and is persisted. Phase 2 (warm) replays the workload against
the *same store from a fresh gateway* (the restart path: new process,
nothing resident but the disk), measuring pure store-hit latency.

Reported per workload row:

- ``cold_p50_us`` / ``cold_p99_us`` — per-query execute latency;
- ``warm_p50_us`` / ``warm_p99_us`` — per-query store-hit latency
  (submit → born-resolved ticket → result);
- ``speedup`` — cold p50 / warm p50; the store contract asserts ≥ 10×
  before the record is appended;
- ``hit_rate`` — store hits / lookups during the warm phase (must be
  1.0: every replayed query is persistable and persisted).

Every warm answer is checked bit-exact against its cold original
(estimate, count, and the CI fields round-trip through JSON + a
process restart). One record per run is appended to
``BENCH_serving.json`` — the trajectory ``scripts/check_bench.py
--serving`` gates nightly.
"""
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.engine import CountRequest
from repro.serving.gateway import ServingGateway

from .common import emit

TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")

WARM_REPLAYS = 5   # store hits are cheap; replay for stable percentiles


def _graphs():
    """Serving-scale graphs (the service_throughput regime): small
    enough that per-query fixed costs dominate, which is exactly what
    a store hit skips."""
    from repro.graphs import barabasi_albert, erdos_renyi_m, rmat
    return [rmat(8, 6, seed=7, name="gw-rmat8"),
            barabasi_albert(500, 7, seed=13, name="gw-ba500"),
            erdos_renyi_m(400, 1800, seed=21, name="gw-er400")]


def _workload(graphs):
    """12 distinct persistable queries: exact k ∈ {3,4,5} and one color
    probe per graph — the method families a production mix spans."""
    jobs = []
    for g in graphs:
        jobs += [(g, CountRequest(k=k)) for k in (3, 4, 5)]
        jobs += [(g, CountRequest(k=4, method="color", colors=10,
                                  seed=3))]
    return jobs


def _timed_pass(gw, jobs):
    """Sequential per-query latencies (us) + the reports, submit →
    result one at a time so each sample isolates one query's cost."""
    lat, reports = [], []
    for g, req in jobs:
        t0 = time.perf_counter()
        reports.append(gw.submit(g, req).result(timeout=600))
        lat.append((time.perf_counter() - t0) * 1e6)
    return np.asarray(lat), reports


def _append_trajectory(rows: list) -> None:
    """Same atomic accumulate-across-PRs idiom as kernels_bench."""
    import jax
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except ValueError:
            os.replace(TRAJECTORY, TRAJECTORY + ".corrupt")
            print(f"# unreadable {TRAJECTORY} moved aside; starting a "
                  f"fresh trajectory", file=sys.stderr, flush=True)
    history.append({
        "ran_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "bench": "gateway",
        "backend": jax.default_backend(),
        "host": "ci" if os.environ.get("CI") else "dev",
        "rows": rows,
    })
    tmp = TRAJECTORY + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, TRAJECTORY)
    print(f"# serving trajectory appended to {TRAJECTORY} "
          f"({len(history)} records)", file=sys.stderr, flush=True)


def main() -> None:
    graphs = _graphs()
    jobs = _workload(graphs)
    store_dir = tempfile.mkdtemp(prefix="gw-bench-")
    try:
        # untimed: absorb process-global one-time costs (device init,
        # module jits) so the cold phase times the per-query work
        warmup = ServingGateway()
        warmup.submit(graphs[0], CountRequest(k=3)).result(timeout=600)
        warmup.shutdown()

        gw = ServingGateway(store_dir=store_dir)
        cold, cold_reports = _timed_pass(gw, jobs)
        assert gw.stats()["store"]["entries"] == len(jobs)
        gw.shutdown()

        # the restart path: fresh gateway, nothing resident but the disk
        gw2 = ServingGateway(store_dir=store_dir, warm_start=False)
        warm, warm_reports = _timed_pass(
            gw2, [j for _ in range(WARM_REPLAYS) for j in jobs])
        store = gw2.stats()["store"]
        assert store["hits"] == len(jobs) * WARM_REPLAYS
        hit_rate = store["hit_rate"]
        for i, rep in enumerate(warm_reports):
            orig = cold_reports[i % len(jobs)]
            assert rep.estimate == orig.estimate, (i, rep.k)
            assert rep.count == orig.count
            assert rep.ci_low == orig.ci_low
            assert rep.ci_high == orig.ci_high
        assert gw2.stats()["service"]["executed"] == 0
        gw2.shutdown()
    finally:
        shutil.rmtree(store_dir, ignore_errors=True)

    cold_p50, cold_p99 = np.percentile(cold, [50, 99])
    warm_p50, warm_p99 = np.percentile(warm, [50, 99])
    speedup = cold_p50 / max(warm_p50, 1e-9)
    emit("gateway_load/cold_execute", cold_p50 / 1e6,
         f"p50_us={cold_p50:.0f};p99_us={cold_p99:.0f};"
         f"queries={len(jobs)}")
    emit("gateway_load/warm_store_hit", warm_p50 / 1e6,
         f"p50_us={warm_p50:.0f};p99_us={warm_p99:.0f};"
         f"speedup={speedup:.1f};hit_rate={hit_rate:.2f}")
    assert speedup >= 10.0, \
        f"store hit must be ≥10x faster than recompute, got {speedup:.1f}x"
    assert hit_rate == 1.0, f"warm phase missed the store: {hit_rate}"
    _append_trajectory([{
        "workload": "mixed3",
        "graphs": len(graphs),
        "queries": len(jobs),
        "warm_replays": WARM_REPLAYS,
        "cold_p50_us": float(cold_p50),
        "cold_p99_us": float(cold_p99),
        "warm_p50_us": float(warm_p50),
        "warm_p99_us": float(warm_p99),
        "speedup": float(speedup),
        "hit_rate": float(hit_rate),
    }])


if __name__ == "__main__":
    main()
