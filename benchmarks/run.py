"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Select with --only substring;
--fast skips the multi-process scalability sweep. The kernel-layer
module additionally appends this run's packed-vs-dense rows to
``BENCH_kernels.json`` at the repo root, so successive PRs accumulate
a perf trajectory for the hot path.
"""
import argparse
import importlib
import sys
import time

MODULES = [
    "benchmarks.table1_stats",      # paper Figure 1
    "benchmarks.fig2_time_accuracy",  # paper Figure 2
    "benchmarks.fig3_rounds",       # paper Figure 3
    "benchmarks.fig4_subgraph_sizes",  # paper Figure 4
    "benchmarks.fig5_scalability",  # paper Figure 5
    "benchmarks.fig6_stragglers",   # paper Figure 6
    "benchmarks.engine_sweep",      # session amortization (submit_many)
    "benchmarks.estimator_accuracy",  # adaptive controller frontier
    "benchmarks.service_throughput",  # CliqueService vs engine-per-request
    "benchmarks.table_mrc",         # Theorem 1 bounds
    "benchmarks.kernels_bench",     # kernel layer
    "benchmarks.roofline_report",   # §Roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for mod in MODULES:
        if args.only and args.only not in mod:
            continue
        if args.fast and "fig5" in mod:
            continue
        t0 = time.time()
        try:
            importlib.import_module(mod).main()
        except Exception as e:  # noqa: BLE001
            print(f"{mod},0,ERROR:{type(e).__name__}:{e}", flush=True)
        print(f"# {mod} done in {time.time() - t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
