"""All-k profile benchmark: ONE tile pass vs an equivalent per-k sweep.

The tentpole claim of the one-pass profile path is that answering
q_3..q_kmax together costs roughly one deepest-k pass, while the sweep
pays a full pipeline per k — separate executables, separate tile
batches, separate dispatches — and, above all, runs the full depth-k
recursion on *every* unit, where the profile path's certificate pass
clamps each unit to its KK-bound depth and settles complete units on
the host. This benchmark measures both cold on the largest corpus
graph (the estimator benchmark graph, n=1200) at ``max_k=7``, the
depth where that asymmetry dominates (the sweep's k=7 pass alone is
tens of seconds; the whole one-pass profile is a few):

- ``allk_us``:  a fresh engine answering ``CountRequest(k="all")``;
- ``sweep_us``: a fresh engine answering ``submit_many`` over
  k = 3..kmax with ``coalesce_sweeps=False`` (the pre-profile
  behaviour: N independent exact queries).

Both sides pre-build the (k-agnostic) plan before the clock starts so
the ratio compares the counting paths, not graph preprocessing, and
both sides include their own jit compilations — that asymmetry (one
profile executable per depth group vs one count executable per
(capacity, k) pair, times k passes) is part of what the one-pass
design buys. The profiles must agree exactly with the per-k sweep
counts before a row is recorded.

The run appends a ``bench="allk_profile"``-tagged record to
``BENCH_kernels.json`` (same trajectory file as the kernel
micro-benchmarks; scripts/check_bench.py --allk gates it) and asserts
the headline speedup >= 3x.
"""
import numpy as np

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import conformance_corpus

from .common import emit, timed
from .kernels_bench import append_trajectory

MIN_SPEEDUP = 3.0


def bench_graph(g, kmax: int) -> dict:
    ks = list(range(3, kmax + 1))

    # cold sweep first: its per-k count executables are disjoint from
    # the profile executables, so neither side warms the other's jits
    eng_sweep = CliqueEngine(g)
    eng_sweep._plan_entry(CountRequest(k=3))
    reps, sweep_s = timed(lambda: eng_sweep.submit_many(
        [CountRequest(k=k) for k in ks], coalesce_sweeps=False), repeat=1)
    sweep_counts = np.array([int(round(r.estimate)) for r in reps])

    eng_allk = CliqueEngine(g)
    eng_allk._plan_entry(CountRequest(k=3))
    rep, allk_s = timed(lambda: eng_allk.submit(
        CountRequest(k="all", max_k=kmax)), repeat=1)
    profile = np.zeros(len(ks), np.int64)
    profile[:rep.profile.size] = rep.profile

    assert np.array_equal(profile, sweep_counts), \
        (g.name, profile, sweep_counts)
    row = {
        "graph": g.name, "n": g.n, "m": g.m, "kmax": kmax,
        "allk_us": allk_s * 1e6, "sweep_us": sweep_s * 1e6,
        "speedup": sweep_s / max(allk_s, 1e-12),
        "profile": [int(v) for v in profile],
    }
    emit(f"allk/{g.name}/kmax{kmax}", allk_s,
         f"sweep_us={row['sweep_us']:.0f};speedup={row['speedup']:.2f}x;"
         f"profile={row['profile']}")
    return row


def main() -> None:
    largest = max(conformance_corpus(), key=lambda g: g.n)
    rows = [bench_graph(largest, kmax=7)]
    # the acceptance: one pass must beat the equivalent sweep by >= 3x
    # on the largest corpus graph (N passes -> 1, N compiles -> ~1)
    for row in rows:
        assert row["speedup"] >= MIN_SPEEDUP, \
            (f"all-k one-pass speedup {row['speedup']:.2f}x < "
             f"{MIN_SPEEDUP}x on {row['graph']}", row)
    append_trajectory(rows, bench="allk_profile")


if __name__ == "__main__":
    main()
