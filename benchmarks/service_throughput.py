"""Service throughput: CliqueService vs engine-per-request.

The scenario the serving layer exists for: 20 queries from many "users"
over 3 graphs, with duplicates (popular graphs get asked the same
question). The naive baseline builds a fresh CliqueEngine per request —
re-orienting, re-uploading, re-planning, and (on the shard_map backend)
rebuilding every jit(shard_map) executable per call. The service holds
an LRU pool of sessions, coalesces the duplicates, and batches each
session's queries back-to-back.

An untimed warm pass absorbs process-global one-time costs (device
init, module-jitted local tile paths) so the rows isolate what the
*service* saves: per-request orient/upload/plan plus the per-session
shard_map compiles, and the executions coalescing avoids entirely.

Emits queries/sec for both, the speedup, and the coalescing hit-rate;
asserts the ≥ 2× speedup the serving layer is accountable for.
"""
import time

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import barabasi_albert, erdos_renyi_m, rmat
from repro.serving.cliques import CliqueService

from .common import emit

BACKEND = "shard_map"


def _graphs():
    """Serving-scale graphs: small enough that per-request fixed costs
    (orient, upload, plan, shard-stack, jit(shard_map) compile) dominate
    raw counting — the regime a front end amortizes. engine_sweep and
    fig2/fig5 cover paper-scale single-query compute."""
    return [rmat(8, 6, seed=7, name="svc-rmat8"),
            barabasi_albert(500, 7, seed=13, name="svc-ba500"),
            erdos_renyi_m(400, 1800, seed=21, name="svc-er400")]


def _workload(graphs):
    """20 mixed queries shaped like shared-service traffic over 3 graphs:

    - 10 unique executions — exact k ∈ {3,4} and a color probe per
      graph, plus one color re-probe at different (colors, seed) whose
      sampling params are *traced*, so the session serves it from the
      compiled-executable cache while the naive baseline recompiles;
    - 10 duplicates of the popular queries (different users asking the
      same question, including exact asks under different seeds) — the
      coalescing targets.
    """
    g1, g2, g3 = graphs
    unique = []
    for g in graphs:
        unique += [(g, CountRequest(k=3)), (g, CountRequest(k=4))]
        unique += [(g, CountRequest(k=4, method="color", colors=10))]
    unique += [(g1, CountRequest(k=4, method="color", colors=25, seed=7))]
    dups = ([(g, CountRequest(k=4, seed=s)) for g in graphs
             for s in (1, 2)] +                      # exact: seed-blind
            [(g, CountRequest(k=3)) for g in graphs] +
            [(g1, CountRequest(k=4, method="color", colors=10))])
    jobs = unique + dups
    assert len(jobs) == 20 and len(unique) == 10
    return jobs


def main() -> None:
    graphs = _graphs()
    jobs = _workload(graphs)

    for g, req in jobs[:10]:  # untimed: one pass over the unique prefix
        CliqueEngine(g, backend=BACKEND).submit(req)

    t0 = time.perf_counter()
    naive = [CliqueEngine(g, backend=BACKEND).submit(req)
             for g, req in jobs]
    t_naive = time.perf_counter() - t0

    svc = CliqueService(max_sessions=len(graphs), default_backend=BACKEND)
    t0 = time.perf_counter()
    tickets = svc.submit_many(jobs)
    svc.drain()
    served = [t.result() for t in tickets]
    t_service = time.perf_counter() - t0

    for a, b in zip(naive, served):
        assert a.estimate == b.estimate, (a.k, a.method)

    stats = svc.stats()
    speedup = t_naive / max(t_service, 1e-9)
    emit("service_throughput/naive_engine_per_request",
         t_naive / len(jobs),
         f"qps={len(jobs) / t_naive:.2f};queries={len(jobs)};"
         f"backend={BACKEND}")
    emit("service_throughput/clique_service",
         t_service / len(jobs),
         f"qps={len(jobs) / t_service:.2f};speedup={speedup:.2f};"
         f"coalesce_rate={stats['coalesce_rate']:.2f};"
         f"executed={stats['executed']};"
         f"pool_hits={stats['pool']['hits']}")
    assert speedup >= 2.0, \
        f"service must be ≥2× engine-per-request, got {speedup:.2f}×"


if __name__ == "__main__":
    main()
