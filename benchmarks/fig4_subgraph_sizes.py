"""Paper Figure 4: induced subgraph (|Γ⁺(u)|) size distributions, with
and without sampling — the quantity that drives round-3 cost and the
straggler tail."""
import numpy as np

from repro.core import build_oriented

from .common import bench_suite, emit


def main() -> None:
    for g in bench_suite():
        og = build_oriented(g)
        d = og.out_deg[og.out_deg >= 2]
        qs = np.percentile(d, [50, 90, 99, 100]).astype(int)
        # color sampling with c colors keeps ~d/c per color class
        d_sampled = np.maximum(d / 10.0, 0)
        qs_s = np.percentile(d_sampled, [50, 90, 99, 100]).astype(int)
        emit(f"fig4/{g.name}", 0.0,
             f"p50={qs[0]};p90={qs[1]};p99={qs[2]};max={qs[3]};"
             f"sampled_p99={qs_s[2]};sampled_max={qs_s[3]};"
             f"lemma1_bound={int(2 * np.sqrt(g.m))}")


if __name__ == "__main__":
    main()
