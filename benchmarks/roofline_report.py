"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

One row per (arch × shape × mesh): the three terms, the bottleneck, and
the roofline fraction — the §Roofline source of truth.
"""
import glob
import json
import os

from .common import emit


def main() -> None:
    root = os.path.join(os.path.dirname(__file__), "..",
                        "experiments", "dryrun")
    files = sorted(glob.glob(os.path.join(root, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "run repro.launch.dryrun first")
        return
    for f in files:
        d = json.load(open(f))
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if not d.get("runnable", True):
            emit(f"roofline/{tag}", 0.0, "SKIP")
            continue
        if d.get("status") != "ok":
            emit(f"roofline/{tag}", 0.0, f"ERROR {d.get('error','')[:60]}")
            continue
        r = d["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{tag}", dom,
             f"compute={r['compute_s']:.3f};memory={r['memory_s']:.3f};"
             f"collective={r['collective_s']:.3f};"
             f"bottleneck={r['bottleneck']};"
             f"frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_ratio']:.3f};"
             f"peakGiB={d['memory']['peak_per_device_gib'] * d['roofline']['n_devices']:.1f}")


if __name__ == "__main__":
    main()
