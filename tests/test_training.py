"""Training substrate: optimizer, grad accum, compression, loops."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.models import init_params
from repro.training.compression import (dequantize_int8, init_residuals,
                                        quantize_int8, wire_bytes_saved)
from repro.training.optimizer import (OptConfig, adamw_update, init_opt_state,
                                      schedule)
from repro.training.train_step import make_train_step

CFG = get_smoke_config("tinyllama-1.1b")
SHAPE = ShapeConfig("t", 32, 8, "train")


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             next(make_pipeline(CFG, SHAPE, seed=2)).items()}
    return params, batch


def test_loss_decreases(setup):
    params, batch = setup
    oc = OptConfig(lr=2e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(CFG, oc, remat="none"))
    opt = init_opt_state(params)
    losses = []
    p = params
    for _ in range(8):
        p, opt, m = step(p, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_grad_accum_parity(setup):
    params, batch = setup
    oc = OptConfig(lr=1e-3)
    opt = init_opt_state(params)
    s1 = jax.jit(make_train_step(CFG, oc, remat="none", grad_accum=1))
    s4 = jax.jit(make_train_step(CFG, oc, remat="none", grad_accum=4))
    pa, _, ma = s1(params, opt, batch)
    pb, _, mb = s4(params, opt, batch)
    diff = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    assert diff < 5e-3, diff  # bf16 accumulation tolerance


def test_remat_parity(setup):
    params, batch = setup
    oc = OptConfig(lr=1e-3)
    opt = init_opt_state(params)
    outs = []
    for remat in ("none", "full", "dots"):
        step = jax.jit(make_train_step(CFG, oc, remat=remat))
        p, _, m = step(params, opt, batch)
        outs.append(float(m["loss"]))
    assert max(outs) - min(outs) < 1e-3, outs


def test_adamw_master_weights_update():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    grads = {"w": jnp.full((4, 4), 0.5, jnp.bfloat16)}
    oc = OptConfig(lr=1e-2, warmup_steps=1, weight_decay=0.0)
    st = init_opt_state(params)
    p2, st2, m = adamw_update(oc, params, grads, st)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2.master["w"].dtype == jnp.float32
    assert float(st2.master["w"][0, 0]) < 1.0  # moved against gradient
    assert int(st2.step) == 1


def test_schedule_warmup_and_decay():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(schedule(oc, jnp.int32(5))) == pytest.approx(0.5, abs=0.02)
    assert float(schedule(oc, jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(schedule(oc, jnp.int32(110))) < 0.01


def test_global_norm_clip_applies():
    params = {"w": jnp.zeros((2, 2), jnp.float32)}
    grads = {"w": jnp.full((2, 2), 100.0)}
    oc = OptConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    _, _, m = adamw_update(oc, params, grads, init_opt_state(params))
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------- compression ----------------

def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (128,)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_residual_bookkeeping():
    from repro.training.compression import compressed_psum
    # single "device": psum over a trivial mesh of 1
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("d",))
    g = {"w": jnp.asarray([[0.001, 1.0], [-1.0, 0.3]], jnp.float32)}
    r = init_residuals(g)

    def f(g, r):
        return compressed_psum(g, r, "d")

    from jax.sharding import PartitionSpec as P
    from repro.core.compat import shard_map
    out, newr = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))(g, r)
    # residual must equal exactly what was lost to quantization
    np.testing.assert_allclose(np.asarray(out["w"] + newr["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-7)


def test_wire_bytes_saved():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((5,))}
    assert wire_bytes_saved(params)["ratio"] == 4.0


def test_compressed_dp_training_converges(tmp_path):
    """int8+EF training tracks uncompressed within tolerance."""
    from repro.training.compression import make_compressed_dp_step
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             next(make_pipeline(CFG, SHAPE, seed=2)).items()}
    oc = OptConfig(lr=2e-3, warmup_steps=2, total_steps=50)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    cstep = make_compressed_dp_step(CFG, oc, mesh, axis="data")
    res = init_residuals(params)
    opt = init_opt_state(params)
    p = params
    losses = []
    for _ in range(6):
        p, opt, res, (loss, m) = cstep(p, opt, res, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.15, losses
