"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (build_oriented, check_lemma1,
                        clique_count_bruteforce, count_cliques)
from repro.core.oracle import complete_graph_cliques
from repro.core.order import ranks
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import (complete_graph, erdos_renyi_m, from_edges, relabel,
                          union, random_graph_for_tests)


graphs = st.integers(min_value=0, max_value=10_000)


@settings(max_examples=20, deadline=None)
@given(seed=graphs, k=st.integers(3, 5))
def test_exact_count_matches_bruteforce(seed, k):
    g = random_graph_for_tests(seed, max_n=28)
    assert count_cliques(g, k).count == clique_count_bruteforce(g, k)


@settings(max_examples=15, deadline=None)
@given(seed=graphs)
def test_relabeling_invariance(seed):
    g = random_graph_for_tests(seed, max_n=24)
    rng = np.random.default_rng(seed + 1)
    g2 = relabel(g, rng.permutation(g.n))
    assert count_cliques(g, 4).count == count_cliques(g2, 4).count


@settings(max_examples=10, deadline=None)
@given(s1=graphs, s2=graphs)
def test_disjoint_union_additivity(s1, s2):
    a = random_graph_for_tests(s1, max_n=20)
    b = random_graph_for_tests(s2, max_n=20)
    u = union(a, b)
    for k in (3, 4):
        assert count_cliques(u, k).count == \
            count_cliques(a, k).count + count_cliques(b, k).count


@settings(max_examples=15, deadline=None)
@given(seed=graphs)
def test_edge_addition_monotone(seed):
    """Adding one edge can never decrease any clique count."""
    g = random_graph_for_tests(seed, max_n=20)
    rng = np.random.default_rng(seed)
    u, v = rng.integers(0, g.n, 2)
    if u == v:
        return
    g2 = from_edges(np.concatenate([g.edges, [[u, v]]], 0), n=g.n)
    for k in (3, 4):
        assert count_cliques(g2, k).count >= count_cliques(g, k).count


@settings(max_examples=20, deadline=None)
@given(seed=graphs)
def test_lemma1_always_holds(seed):
    g = random_graph_for_tests(seed, max_n=40)
    og = build_oriented(g)
    assert check_lemma1(g, og.out_deg)


@settings(max_examples=20, deadline=None)
@given(seed=graphs)
def test_orientation_is_total_order(seed):
    """ranks are a permutation and orientation is acyclic by rank."""
    g = random_graph_for_tests(seed, max_n=40)
    r = ranks(g.degrees)
    assert sorted(r.tolist()) == list(range(g.n))
    og = build_oriented(g)
    for u in range(min(g.n, 12)):
        for x in og.gamma_plus(u):
            assert r[u] < r[x]


@settings(max_examples=10, deadline=None)
@given(seed=graphs, p=st.sampled_from([0.5, 1.0]))
def test_edge_sampling_never_overcounts_at_p1(seed, p):
    g = random_graph_for_tests(seed, max_n=22)
    exact = count_cliques(g, 3).count
    est = count_cliques(g, 3, method="edge", p=p, seed=seed).estimate
    if p == 1.0:
        assert round(est) == exact
    else:
        assert est >= 0


@settings(max_examples=15, deadline=None)
@given(seed=graphs)
def test_edge_deletion_monotone(seed):
    """Metamorphic: deleting any edge never increases any clique count."""
    g = random_graph_for_tests(seed, max_n=20)
    if g.m == 0:
        return
    rng = np.random.default_rng(seed)
    keep = np.ones(g.m, dtype=bool)
    keep[rng.integers(0, g.m)] = False
    g2 = from_edges(g.edges[keep], n=g.n)
    eng, eng2 = CliqueEngine(g), CliqueEngine(g2)
    for k in (3, 4):
        assert eng2.submit(CountRequest(k=k)).count <= \
            eng.submit(CountRequest(k=k)).count


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 14), k=st.integers(3, 5))
def test_complete_graph_closed_form(n, k):
    """K_n must hit the C(n, k) closed form exactly, on the engine."""
    eng = CliqueEngine(complete_graph(n))
    assert eng.submit(CountRequest(k=k)).count == complete_graph_cliques(n, k)


@settings(max_examples=15, deadline=None)
@given(seed=graphs, k=st.integers(3, 5))
def test_engine_relabeling_invariance(seed, k):
    """Node relabeling leaves every q_k invariant (engine sessions on
    both labelings — the CSR build must not depend on label order)."""
    g = random_graph_for_tests(seed, max_n=22)
    rng = np.random.default_rng(seed + 2)
    g2 = relabel(g, rng.permutation(g.n))
    assert CliqueEngine(g).submit(CountRequest(k=k)).count == \
        CliqueEngine(g2).submit(CountRequest(k=k)).count


@settings(max_examples=10, deadline=None)
@given(s1=graphs, s2=graphs, k=st.integers(3, 5))
def test_engine_union_additivity(s1, s2, k):
    """Disjoint union sums counts — no cross-component cliques leak."""
    a = random_graph_for_tests(s1, max_n=18)
    b = random_graph_for_tests(s2, max_n=18)
    u = union(a, b)
    assert CliqueEngine(u).submit(CountRequest(k=k)).count == \
        CliqueEngine(a).submit(CountRequest(k=k)).count + \
        CliqueEngine(b).submit(CountRequest(k=k)).count


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), frac=st.floats(0.0, 1.0), seed=graphs)
def test_erdos_renyi_m_exact_edge_count(n, frac, seed):
    """G(n, m) must deliver exactly m edges for every feasible m (the
    fixed-oversample version undershot on dense targets)."""
    max_m = n * (n - 1) // 2
    m = int(round(frac * max_m))
    g = erdos_renyi_m(n, m, seed=seed)
    assert g.m == m
    assert g.n == n
    with pytest.raises(ValueError):
        erdos_renyi_m(n, max_m + 1, seed=seed)


@settings(max_examples=8, deadline=None)
@given(seed=graphs)
def test_per_node_counts_sum_to_total(seed):
    g = random_graph_for_tests(seed, max_n=26)
    res = count_cliques(g, 4, return_per_node=True)
    assert int(round(res.per_node.sum())) == res.count
