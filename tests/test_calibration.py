"""Statistical calibration of the adaptive estimator: are the error
bars honest?

For each corpus case we run the auto controller over many fixed seeds
and check two empirical guarantees against the golden (oracle) counts:

- **coverage** — the fraction of runs whose reported CI contains the
  true count must be ≥ the nominal confidence (the CI is conservative
  by construction, so the observed coverage should sit well above it —
  a dip below nominal is a real calibration bug, not noise);
- **honesty** — ``achieved_rel_error`` must actually bound the realized
  relative error at the same rate.

Runs that resolve exact (work-model fall-through) count toward both —
"exact, zero-width" is the honest answer for targets sampling cannot
certify. Tier-1 runs the 20-seed smoke; the full ≥200-seed sweep is the
``stat`` tier (``pytest --stat``).
"""
import json
import os

import pytest

import numpy as np

from repro.engine import CliqueEngine, CountRequest
from repro.estimator import Auto, Sparsify
from repro.graphs import conformance_corpus

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "golden_counts.json")

# (graph name, k, rel_error, confidence): spans the regimes — the large
# planted graph actually samples, the ER/BA controls mostly fall through
# exact, the bipartite graph exercises the zero-count certificates
CASES = [
    ("planted_1200_12_16_40", 5, 0.05, 0.9),
    ("planted_1200_12_16_40", 4, 0.10, 0.9),
    ("er_n48_p0.25", 4, 0.10, 0.9),
    ("ba_n64_k6", 5, 0.10, 0.9),
    ("K12_12", 4, 0.05, 0.9),
    ("planted_32_6_7", 5, 0.10, 0.9),
]


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def engines():
    by_name = {g.name: g for g in conformance_corpus()}
    cache = {}

    def get(name: str) -> CliqueEngine:
        if name not in cache:
            cache[name] = CliqueEngine(by_name[name])
        return cache[name]

    return get


def _run_case(engines, golden, name, k, rel, conf, seeds, method="auto"):
    eng = engines(name)
    truth = golden[name]["counts"][str(k)]
    covered = honest = sampled = 0
    # "auto" goes through the typed spec (canonical spelling); "wedge"
    # and "sparsify" are adaptive single-lever runs via rel_error
    spec = Auto(rel_error=rel, confidence=conf) if method == "auto" \
        else method
    for seed in seeds:
        rep = eng.submit(CountRequest(k=k, method=spec, rel_error=rel,
                                      confidence=conf, seed=seed))
        covered += rep.ci_low <= truth <= rep.ci_high
        err = abs(rep.estimate - truth)
        honest += err <= rep.achieved_rel_error * max(abs(rep.estimate),
                                                      1.0) + 1e-9
        sampled += rep.params["resolved"] == "sampled"
    n = len(seeds)
    assert covered / n >= conf, \
        (name, k, f"coverage {covered}/{n} below nominal {conf}")
    assert honest / n >= conf, \
        (name, k, f"achieved_rel_error dishonest {honest}/{n}")
    return sampled


@pytest.mark.parametrize("name,k,rel,conf", CASES)
def test_calibration_smoke_20_seeds(engines, golden, name, k, rel, conf):
    _run_case(engines, golden, name, k, rel, conf, range(20))


def test_smoke_includes_a_genuinely_sampled_case(engines, golden):
    """Guard against the smoke silently passing because every case fell
    through to exact: the big planted graph must certify via sampling."""
    sampled = _run_case(engines, golden, "planted_1200_12_16_40", 5,
                        0.05, 0.9, range(5))
    assert sampled == 5


@pytest.mark.stat
@pytest.mark.parametrize("name,k,rel,conf", CASES)
def test_calibration_full_sweep(engines, golden, name, k, rel, conf):
    """≥200 seeds per case (disjoint from the smoke's seed range)."""
    _run_case(engines, golden, name, k, rel, conf, range(100, 300))


# ---------------- per-method contracts (portfolio levers) ----------------

# single-lever adaptive runs: the named lever must honor the same
# coverage/honesty contract as auto (falling through to exact where it
# cannot certify is the honest answer and counts toward both)
METHOD_CASES = [
    ("wedge", "planted_1200_12_16_40", 5, 0.10, 0.9),
    ("wedge", "ba_n64_k6", 4, 0.25, 0.9),
    ("sparsify", "er_n48_p0.25", 4, 0.50, 0.9),
]


@pytest.mark.parametrize("method,name,k,rel,conf", METHOD_CASES)
def test_method_calibration_smoke_20_seeds(engines, golden, method, name,
                                           k, rel, conf):
    _run_case(engines, golden, name, k, rel, conf, range(20),
              method=method)


def test_wedge_actually_samples_on_the_planted_graph(engines, golden):
    """Wedge must be able to *certify* (not just fall through) where it
    is built to win — the degree-skewed planted graph."""
    sampled = _run_case(engines, golden, "planted_1200_12_16_40", 5,
                        0.10, 0.9, range(5), method="wedge")
    assert sampled == 5


def test_sparsify_direct_is_unbiased(engines, golden):
    """E[q^{-C(k,2)}·count(G_q)] = count(G): the mean of direct (non-
    adaptive) sparsified estimates over seeds must sit within a few
    standard errors of the truth."""
    eng = engines("er_n48_p0.25")
    truth = golden["er_n48_p0.25"]["counts"]["3"]
    ests = [eng.submit(CountRequest(k=3, method=Sparsify(q=0.7),
                                    seed=s)).estimate
            for s in range(40)]
    mean, se = np.mean(ests), np.std(ests) / np.sqrt(len(ests))
    assert abs(mean - truth) <= 6.0 * se + 1e-9, (mean, truth, se)


@pytest.mark.stat
@pytest.mark.parametrize("method,name,k,rel,conf", METHOD_CASES)
def test_method_calibration_full_sweep(engines, golden, method, name, k,
                                       rel, conf):
    _run_case(engines, golden, name, k, rel, conf, range(100, 300),
              method=method)
