"""Listing conformance: the streamed enumeration must reproduce the
brute-force oracle's clique *sets* — not just its counts.

Coverage map (the acceptance contract of the listing subsystem):

- every conformance-corpus graph, k ∈ 3..5: the streamed set from both
  tile representations (dense f32 / packed uint32) equals the oracle's
  set. The full 3-backend × 2-repr cross product runs on the small
  corpus graphs; the large estimator-benchmark graph (663k 5-cliques)
  runs both reprs on the local backend at every k plus a cross-backend
  spot check — the stream compiles to the same tile executables on
  every backend, so the extra combos would re-run identical device code
  for minutes of CI time.
- bounded memory: a deliberately undersized chunk buffer must drain
  tiles in ≤-chunk batches (asserted per batch) and still reproduce the
  exact set.
- len(list) == count whenever no limit is hit (hypothesis property).
- limit early-stop, predicate filtering, validation, service tickets.
"""
import numpy as np
import pytest

from repro.core import clique_count_bruteforce, clique_list_bruteforce
from repro.engine import LISTING_BACKENDS, CliqueEngine, CountRequest
from repro.graphs import complete_graph, conformance_corpus
from repro.listing import CliqueBatch, containing, stream_cliques

KS = (3, 4, 5)
REPRS = ("dense", "bitset")
BIG = 100    # corpus graphs above this n get the reduced combo matrix


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_state():
    """Compile this module's listing executables from a clean client.

    Late in a full-suite run the accumulated XLA CPU JIT state makes
    the first listing compile segfault inside
    ``jax._src.compiler.backend_compile`` (deterministically at
    test_listing_matches_oracle_sets_small; the module passes in
    isolation). Dropping jax's caches first trades a few recompiles
    for a crash-free compile."""
    import jax
    jax.clear_caches()
    yield


def canon(rows: np.ndarray) -> np.ndarray:
    """Canonical set form: sort within each clique, then lexsort rows."""
    rows = np.sort(np.asarray(rows, np.int64), axis=1)
    return rows[np.lexsort(rows.T[::-1])] if len(rows) else rows


@pytest.fixture(scope="module")
def corpus():
    return conformance_corpus()


@pytest.fixture(scope="module")
def oracle_sets(corpus):
    return {g.name: {k: canon(clique_list_bruteforce(g, k)) for k in KS}
            for g in corpus}


def assert_valid_cliques(g, rows: np.ndarray) -> None:
    """Independent validity check: distinct rows, distinct members,
    every pair adjacent (doesn't rely on the oracle)."""
    rows = np.asarray(rows, np.int64)
    srt = np.sort(rows, axis=1)
    assert (np.diff(srt, axis=1) > 0).all(), "repeated member in a clique"
    as_tuples = {tuple(r) for r in srt}
    assert len(as_tuples) == len(rows), "duplicate clique emitted"
    edges = {(int(u), int(v)) for u, v in g.edges}
    edges |= {(v, u) for u, v in edges}
    for r in srt[:256]:     # spot-check adjacency on a bounded sample
        for i in range(len(r)):
            for j in range(i + 1, len(r)):
                assert (int(r[i]), int(r[j])) in edges, r


def test_listing_matches_oracle_sets_small(corpus, oracle_sets):
    """Small corpus graphs: full backend × representation × k matrix."""
    for g in corpus:
        if g.n > BIG:
            continue
        eng = CliqueEngine(g)
        for k in KS:
            want = oracle_sets[g.name][k]
            for backend in LISTING_BACKENDS:
                for engine in REPRS:
                    rep = eng.submit(CountRequest(
                        k=k, mode="list", backend=backend, engine=engine))
                    got = canon(rep.cliques)
                    assert rep.count == len(want), \
                        (g.name, k, backend, engine)
                    assert np.array_equal(got, want), \
                        (g.name, k, backend, engine)


def test_listing_matches_oracle_sets_large(corpus, oracle_sets):
    """The big graph: both reprs at every k on local (the executables
    are backend-shared), plus a cross-backend spot check at k=4."""
    g = next(g for g in corpus if g.n > BIG)
    eng = CliqueEngine(g)
    for k in KS:
        want = oracle_sets[g.name][k]
        for engine in REPRS:
            rep = eng.submit(CountRequest(k=k, mode="list", engine=engine))
            assert rep.count == len(want), (k, engine)
            assert np.array_equal(canon(rep.cliques), want), (k, engine)
    for backend in ("pallas", "shard_map"):
        for engine in REPRS:
            rep = eng.submit(CountRequest(k=4, mode="list",
                                          backend=backend, engine=engine))
            assert np.array_equal(canon(rep.cliques),
                                  oracle_sets[g.name][4]), (backend, engine)
    assert_valid_cliques(g, rep.cliques)


def test_undersized_buffer_drains_and_bounds_memory(corpus, oracle_sets):
    """A chunk far smaller than the clique count must (a) bound every
    yielded batch by the chunk size — the peak-host-memory contract —
    (b) actually exercise the overflow drain, (c) lose nothing."""
    g = corpus[0]            # K10: 120 triangles in one 8-wide bucket
    eng = CliqueEngine(g)
    for engine in REPRS:
        stats: dict = {}
        req = CountRequest(k=3, mode="list", chunk=7, engine=engine)
        batches = list(stream_cliques(eng, req, stats=stats))
        assert all(isinstance(b, CliqueBatch) for b in batches)
        assert all(len(b.cliques) <= 7 for b in batches), \
            "a batch exceeded the chunk capacity"
        assert stats["drained_tiles"] >= 1, \
            "undersized buffer never hit the drain path"
        assert max(b.chunk_index for b in batches) >= 1
        got = canon(np.concatenate([b.cliques for b in batches]))
        assert np.array_equal(got, oracle_sets[g.name][3])
        assert stats["listed"] == len(got)


def test_stream_order_is_deterministic(corpus):
    g = corpus[3]            # the BA graph
    eng = CliqueEngine(g)
    req = CountRequest(k=4, mode="list", chunk=13)
    a = np.concatenate([b.cliques for b in eng.stream(req)])
    b = np.concatenate([b.cliques for b in eng.stream(req)])
    np.testing.assert_array_equal(a, b)


def test_limit_early_stops(corpus):
    g = next(g for g in corpus if g.n > BIG)   # 663k 5-cliques available
    eng = CliqueEngine(g)
    rep = eng.submit(CountRequest(k=5, mode="list", limit=50, chunk=32))
    assert rep.count == 50 and len(rep.cliques) == 50
    assert rep.listing["truncated"]
    # early-stop must leave device work on the table, not enumerate
    # everything and slice: far fewer cliques materialized than exist
    assert rep.listing["listed"] == 50
    assert rep.listing["tiles"] <= 2, \
        "limit did not stop the tile loop early"
    assert_valid_cliques(g, rep.cliques)


def test_predicate_filters_and_composes_with_limit():
    g = complete_graph(10)
    eng = CliqueEngine(g)
    # cliques through node 0: C(9, 2) = 36 triangles
    rep = eng.submit(CountRequest(k=3, mode="list",
                                  predicate=containing(0)))
    assert rep.count == 36
    assert (np.sort(rep.cliques, axis=1)[:, 0] == 0).all()
    rep = eng.submit(CountRequest(k=3, mode="list", chunk=8,
                                  predicate=containing(0), limit=10))
    assert rep.count == 10 and rep.listing["truncated"]
    assert (np.sort(rep.cliques, axis=1)[:, 0] == 0).all()


def test_sparse_predicate_limit_counts_matches_only():
    """Ordering pin: the limit budget is spent on predicate *matches*,
    never on enumerated-then-filtered rows. With a sparse predicate
    (10 of K12's 220 triangles contain both 0 and 1) and limit=4, a
    limit applied before filtering would stop the stream after 4
    enumerated triangles and return almost nothing; the contract is
    exactly 4 rows, every one a match."""
    g = complete_graph(12)
    eng = CliqueEngine(g)
    req = CountRequest(k=3, mode="list", chunk=8,
                       predicate=containing(0, 1), limit=4)
    rep = eng.submit(req)
    assert rep.count == 4 and len(rep.cliques) == 4
    assert rep.listing["truncated"]
    srt = np.sort(rep.cliques, axis=1)
    assert (srt[:, 0] == 0).all() and (srt[:, 1] == 1).all()
    # the stream kept enumerating past the first `limit` candidates to
    # find its matches — the filter ran before the budget
    assert rep.listing["enumerated"] > 4
    # and with the limit above the match count, all 10 matches arrive
    rep = eng.submit(CountRequest(k=3, mode="list", chunk=8,
                                  predicate=containing(0, 1)))
    assert rep.count == 10 and not rep.listing["truncated"]
    assert_valid_cliques(g, rep.cliques)


def test_per_node_attribution_header(corpus, oracle_sets):
    """Column 0 of each row is the ≺-minimum responsible node: the
    per-node listing histogram must match the exact per-node counts."""
    g = corpus[4]            # planted_32_6_7
    eng = CliqueEngine(g)
    _, per_node = clique_count_bruteforce(g, 4, return_per_node=True)
    rep = eng.submit(CountRequest(k=4, mode="list"))
    hist = np.bincount(rep.cliques[:, 0], minlength=g.n)
    np.testing.assert_array_equal(hist, per_node)


def test_listing_request_validation():
    with pytest.raises(ValueError, match="exact"):
        CountRequest(k=4, mode="list", method="color").validate()
    with pytest.raises(ValueError, match="mode"):
        CountRequest(k=4, mode="enumerate").validate()
    with pytest.raises(ValueError, match="list"):
        CountRequest(k=4, limit=5).validate()
    with pytest.raises(ValueError, match="split"):
        CountRequest(k=4, mode="list", split_threshold=8).validate()
    with pytest.raises(ValueError, match="chunk"):
        CountRequest(k=4, mode="list", chunk=0).validate()
    with pytest.raises(ValueError, match="rel_error"):
        CountRequest(k=4, mode="list", rel_error=0.1).validate()
    CountRequest(k=4, mode="list", limit=5, chunk=2).validate()


def test_listing_query_key_coalescing_identity():
    base = CountRequest(k=4, mode="list")
    assert base.query_key() != CountRequest(k=4).query_key()
    assert base.query_key() == \
        CountRequest(k=4, mode="list", seed=99).query_key()   # seed moot
    assert base.query_key() == \
        CountRequest(k=4, mode="list", chunk=7).query_key()   # batching
    assert base.query_key() != \
        CountRequest(k=4, mode="list", limit=5).query_key()
    pred = containing(3)
    a = CountRequest(k=4, mode="list", predicate=pred)
    b = CountRequest(k=4, mode="list", predicate=pred)
    assert a.query_key() == b.query_key()                     # same object
    c = CountRequest(k=4, mode="list", predicate=containing(3))
    assert a.query_key() != c.query_key()                     # distinct fn


def test_service_listing_tickets(corpus):
    from repro.serving.cliques import CliqueService
    g = corpus[0]
    svc = CliqueService(max_sessions=2)
    t1 = svc.submit(g, CountRequest(k=3, mode="list"))
    t2 = svc.submit(g, CountRequest(k=3, mode="list", seed=5))  # coalesces
    t3 = svc.submit(g, CountRequest(k=3, mode="list", limit=5))
    r1, r2, r3 = t1.result(), t2.result(), t3.result()
    assert r1.count == r2.count == 120
    np.testing.assert_array_equal(r1.cliques, r2.cliques)
    assert r1.cliques is not r2.cliques, \
        "coalesced waiters must not share the mutable cliques array"
    assert r3.count == 5 and r3.listing["truncated"]
    assert svc.stats()["coalesced"] == 1


@pytest.mark.slow
def test_multiworker_shard_map_listing_matches_oracle():
    """W > 1 takes the partition_for_workers walk in stream_cliques —
    unreachable on the single in-process device — so run it under fake
    host devices in a subprocess and pin the streamed set to the
    oracle there."""
    from conftest import run_with_devices
    run_with_devices("""
import numpy as np
from repro.core import clique_count_bruteforce, clique_list_bruteforce
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import barabasi_albert
g = barabasi_albert(96, 6, seed=3)
eng = CliqueEngine(g, backend="shard_map")
assert eng._backend("shard_map").n_workers == 4
def canon(rows):
    rows = np.sort(np.asarray(rows, np.int64), axis=1)
    return rows[np.lexsort(rows.T[::-1])]
for k in (3, 4):
    for engine in ("dense", "bitset"):
        rep = eng.submit(CountRequest(k=k, mode="list", engine=engine,
                                      chunk=64))
        assert rep.count == clique_count_bruteforce(g, k), (k, engine)
        want = canon(clique_list_bruteforce(g, k))
        assert np.array_equal(canon(rep.cliques), want), (k, engine)
print("OK")
""", n_devices=4)


def test_len_list_equals_count_property():
    """Hypothesis: on random graphs, len(listing) == exact count for
    random (k, chunk) whenever no limit is set — the counting identity
    and the emit recursion are the same recursion."""
    hyp = pytest.importorskip("hypothesis")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies
    from repro.graphs import random_graph_for_tests

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(3, 5),
           chunk=st.integers(1, 64),
           engine=st.sampled_from(REPRS))
    def inner(seed, k, chunk, engine):
        g = random_graph_for_tests(seed, max_n=24)
        eng = CliqueEngine(g)
        rep = eng.submit(CountRequest(k=k, mode="list", chunk=chunk,
                                      engine=engine))
        assert rep.count == clique_count_bruteforce(g, k)
        assert len(rep.cliques) == rep.count
        if len(rep.cliques):
            assert_valid_cliques(g, rep.cliques)

    inner()
