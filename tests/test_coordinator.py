"""The multi-host scheduler: wire protocol, leases/heartbeats,
commit-protocol dedup, chaos schedules, and real-executor fault drills.

Two layers of coverage:

- **Protocol-level** (fast): a real ``Coordinator`` with
  ``spawn_executors=False`` plus *scripted* executors — plain sockets
  speaking the frame protocol with prescribed behavior (stall, die,
  error, heartbeat-while-slow) — so lease expiry, reassignment,
  first-committed-wins, retry, and cross-host speculation are pinned
  without paying executor-process startup.
- **Process-level** (slow-marked): real ``repro.scheduler.executor``
  subprocesses running real counting tasks, with SIGKILL mid-run — the
  acceptance drill: bit-exact counts vs the local backend, ≥1 lease
  expiry, ≥1 reassignment, and a resume that re-executes nothing.
"""
import os
import socket
import struct
import subprocess
import sys
import threading
import time
import types

import numpy as np
import pytest

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import planted_cliques
from repro.runtime.chaos import ChaosMonkey, parse_chaos
from repro.scheduler import (Coordinator, SchedulerConfig, Task,
                             TaskLedger, TaskResult)
from repro.scheduler.transport import (Channel, recv_frame,
                                       result_from_wire, result_to_wire,
                                       send_frame, task_from_wire,
                                       task_to_wire)

# ---------------- transport ----------------


def test_frame_roundtrip_and_eof():
    a, b = socket.socketpair()
    send_frame(a, {"x": 1, "s": "π", "f": 1 / 3})
    got = recv_frame(b)
    assert got == {"x": 1, "s": "π", "f": 1 / 3}
    assert got["f"] == 1 / 3                # float repr round-trip: exact
    a.close()
    assert recv_frame(b) is None            # clean EOF
    b.close()


def test_truncated_frame_reads_as_disconnect():
    a, b = socket.socketpair()
    a.sendall(struct.pack(">I", 64) + b'{"half":')   # died mid-payload
    a.close()
    assert recv_frame(b) is None
    b.close()


def test_absurd_frame_header_is_refused():
    a, b = socket.socketpair()
    a.sendall(struct.pack(">I", 1 << 30))
    with pytest.raises(ValueError, match="cap"):
        recv_frame(b)
    a.close()
    b.close()


def test_task_and_result_wire_roundtrip():
    t = Task(task_id="s8-0001-abc", kind="split", capacity=8,
             tile_repr="bits", units=np.array([3, 1, 4], np.int32),
             pivots=np.array([0, 2, 1], np.int32), cost=7.5, r=2)
    t2 = task_from_wire(task_to_wire(t))
    assert t2.task_id == t.task_id and t2.kind == t.kind
    assert t2.capacity == t.capacity and t2.tile_repr == t.tile_repr
    np.testing.assert_array_equal(t2.units, t.units)
    np.testing.assert_array_equal(t2.pivots, t.pivots)
    assert t2.cost == t.cost and t2.r == t.r

    res = TaskResult(task_sum=1 / 7, elapsed_s=0.25,
                     unit_ids=np.array([5, 9], np.int64),
                     unit_vals=np.array([0.1, 2 / 3]),
                     profile=np.array([3.0, 1 / 9]))
    r2 = result_from_wire(result_to_wire(res))
    assert r2.task_sum == res.task_sum      # bit-exact through JSON
    np.testing.assert_array_equal(r2.unit_ids, res.unit_ids)
    np.testing.assert_array_equal(r2.unit_vals, res.unit_vals)
    np.testing.assert_array_equal(r2.profile, res.profile)


# ---------------- chaos schedules ----------------


def test_chaos_spec_parsing():
    ev = parse_chaos("kill:1@2,hang:0@3/2.0,slow:2/1.5,part:1")
    assert [(e.action, e.executor, e.after_commits, e.seconds)
            for e in ev] == [("kill", 1, 2, 0.0), ("hang", 0, 3, 2.0),
                             ("slow", 2, 0, 1.5), ("part", 1, 0, 0.0)]
    for bad in ("boom:1", "kill", "kill:x", "hang:1@2", "slow:1"):
        with pytest.raises(ValueError):
            parse_chaos(bad)


def test_chaos_kill_waits_for_a_lease():
    """kill/hang stay armed until the victim holds a lease, so the
    smoke's lease-expiry assertion can never race an idle victim."""
    killed = []
    mk = ChaosMonkey(parse_chaos("kill:0@2"), kill=killed.append)
    mk.on_commit(1, lambda i: True)         # not due yet
    assert not killed and mk.pending()
    mk.on_commit(2, lambda i: False)        # due, victim idle → armed
    assert not killed and mk.pending()
    mk.on_commit(2, lambda i: True)
    assert killed == [0] and not mk.pending()
    assert mk.applied == ["kill:0"]


def test_chaos_slow_is_a_task_delay_not_an_event():
    mk = ChaosMonkey(parse_chaos("slow:2/1.5"))
    assert mk.task_delay(2) == 1.5 and mk.task_delay(0) == 0.0
    assert not mk.pending()


def test_chaos_event_fires_exactly_once_under_concurrent_commits():
    # the coordinator pokes on_commit from every connection-handler
    # thread and from its monitor loop; a due event must not double-fire
    kills = []
    mk = ChaosMonkey(parse_chaos("kill:1@1"), kill=kills.append)
    barrier = threading.Barrier(8)

    def poke():
        barrier.wait()
        for n in range(1, 50):
            mk.on_commit(n, lambda idx: True)

    threads = [threading.Thread(target=poke) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert kills == [1]
    assert mk.applied == ["kill:1"]
    assert not mk.pending()


# ---------------- protocol-level coordinator (scripted executors) -------


def _mk_task(tid: str, cost: float = 1.0) -> Task:
    return Task(task_id=tid, kind="bucket", capacity=8,
                tile_repr="dense", units=np.arange(4, dtype=np.int32),
                pivots=None, cost=cost)


def _cfg(**kw) -> SchedulerConfig:
    base = dict(executors=2, spawn_executors=False, lease_s=0.25,
                heartbeat_s=0.05, poll_s=0.005, connect_timeout_s=2.0,
                host_backoff_s=0.02, host_backoff_cap_s=0.1,
                retry_backoff_s=0.01, retry_backoff_cap_s=0.05,
                max_retries=3,
                # effectively disable speculation unless a test opts in
                speculation_min_s=30.0)
    base.update(kw)
    return SchedulerConfig(**base)


def _coordinator(tmp_path, tasks, cfg, completed=None, ledger=None):
    store = types.SimpleNamespace(root=str(tmp_path),
                                  fingerprint="f" * 16,
                                  plan_sig="p" * 16)
    req = types.SimpleNamespace(k=3, effective_method="exact", p=1.0,
                                colors=1, return_per_node=False, seed=0)
    if ledger is None:
        ledger = TaskLedger(str(tmp_path / "ledger.jsonl"), "sig")
        ledger.open_fresh()
    coord = Coordinator(store, req, cfg, tasks, ledger,
                        dict(completed or {}), key_seed=None,
                        lookup_iters=4)
    return coord, ledger


def _scripted(addr, name, handler, committed):
    """A fake executor: speaks the real protocol, behavior prescribed
    by ``handler(task_wire) -> action tuple``:

      ("result", sum)              — commit immediately
      ("error", msg)               — report failure, ask for more
      ("stall", secs, beat[, sum]) — go dark (or heartbeat) that long,
                                     then send the (possibly stale)
                                     result
      ("die",)                     — close the socket abruptly
    """
    sock = socket.create_connection(addr, timeout=10)
    chan = Channel(sock)
    try:
        chan.send({"type": "hello", "executor": name})
        job = chan.recv()
        assert job["type"] == "job", job
        while True:
            chan.send({"type": "ready"})
            msg = chan.recv()
            if msg is None or msg["type"] == "shutdown":
                return
            if msg["type"] == "wait":
                time.sleep(float(msg.get("wait_s", 0.02)))
                continue
            t = msg["task"]
            act = handler(t)
            if act[0] == "die":
                return
            if act[0] == "error":
                chan.send({"type": "error", "task": t["task_id"],
                           "error": act[1]})
                continue
            elapsed, val = 0.01, 1.0
            if act[0] == "result":
                val = float(act[1])
            else:   # stall
                secs, beat = float(act[1]), bool(act[2])
                if len(act) > 3:
                    val = float(act[3])
                end = time.monotonic() + secs
                while time.monotonic() < end:
                    if beat:
                        chan.send({"type": "heartbeat"})
                    time.sleep(0.02)
                elapsed = secs
            chan.send({"type": "result", "task": t["task_id"],
                       "sum": val, "elapsed_s": elapsed, "loaded": 0})
            committed.append(t["task_id"])
    except OSError:
        pass
    finally:
        chan.close()


def _drive(coord, executors, timeout=30.0):
    """Run the coordinator in a thread, attach scripted executors once
    it is listening, and return {"results": ...} or {"error": ...}."""
    box = {}

    def go():
        try:
            box["results"] = coord.run()
        except BaseException as e:  # noqa: BLE001 — surfaced to the test
            box["error"] = e

    th = threading.Thread(target=go, daemon=True)
    th.start()
    deadline = time.monotonic() + 5
    while coord.address is None and th.is_alive() \
            and time.monotonic() < deadline:
        time.sleep(0.005)
    threads = []
    for name, handler, committed in executors:
        t = threading.Thread(target=_scripted,
                             args=(coord.address, name, handler,
                                   committed),
                             daemon=True)
        t.start()
        threads.append(t)
    th.join(timeout)
    if th.is_alive():
        pytest.fail("coordinator did not finish")
    for t in threads:
        t.join(timeout=5)
    return box


def test_distributes_and_steals_across_hosts(tmp_path):
    tasks = [_mk_task(f"t{i}") for i in range(8)]
    coord, ledger = _coordinator(tmp_path, tasks, _cfg())
    a_done, b_done = [], []
    box = _drive(coord, [
        ("e0", lambda t: ("result", 1.0), a_done),
        # e1 is slow per task: e0 drains its own queue then steals
        ("e1", lambda t: ("stall", 0.08, True, 1.0), b_done)])
    assert set(box["results"]) == {t.task_id for t in tasks}
    assert a_done and sorted(a_done + b_done) == \
        sorted(t.task_id for t in tasks)
    assert coord.stats["run"] == 8
    assert coord.stats["stolen"] >= 1
    # the ledger holds one committed line per task (plus the header)
    ledger.close()
    with open(ledger.path) as f:
        assert sum(1 for _ in f) == 9


def test_silent_executor_expires_lease_and_work_is_reassigned(tmp_path):
    """An executor that stops heartbeating mid-task (SIGSTOP-shaped)
    loses its lease; the task moves to a live host; the thawed
    original's stale result is discarded by first-committed-wins."""
    tasks = [_mk_task(f"t{i}") for i in range(16)]
    coord, ledger = _coordinator(
        tmp_path, tasks, _cfg(lease_s=0.15))
    a_done, b_done = [], []
    state = {"stalled": False}

    def flaky(t):
        if not state["stalled"]:
            state["stalled"] = True
            return ("stall", 0.8, False, 999.0)   # dark > lease, bad sum
        return ("result", 1.0)

    def steady(t):
        # pace e1 so the run is still going when the stale 999.0 lands
        time.sleep(0.08)
        return ("result", 1.0)

    box = _drive(coord, [("e0", flaky, a_done), ("e1", steady, b_done)])
    results = box["results"]
    assert set(results) == {t.task_id for t in tasks}
    assert coord.stats["lease_expiries"] >= 1
    assert coord.stats["heartbeats_missed"] >= 1   # socket stayed open
    assert coord.stats["reassigned"] >= 1
    # first-committed-wins: the reassigned execution's sum (1.0) landed;
    # the stale 999.0 was discarded and counted as a duplicate
    assert all(results[tid].task_sum == 1.0 for tid in results)
    assert coord.core.commit_dups >= 1
    # the flapping host was penalized before re-admission
    assert coord.expiries["e0"] >= 1
    ledger.close()


def test_disconnect_expires_leases_immediately(tmp_path):
    """A closed socket (SIGKILL-shaped) needs no lease timeout: the
    dead executor's task is reassigned at EOF and the run completes on
    the survivor."""
    tasks = [_mk_task(f"t{i}") for i in range(6)]
    coord, ledger = _coordinator(
        tmp_path, tasks, _cfg(lease_s=5.0))   # expiry can't be the clock
    a_done, b_done = [], []
    box = _drive(coord, [
        ("e0", lambda t: ("die",), a_done),
        # e1 paced so e0 is guaranteed a task before the pool drains
        ("e1", lambda t: ("stall", 0.05, True, 1.0), b_done)])
    assert set(box["results"]) == {t.task_id for t in tasks}
    assert coord.stats["lease_expiries"] >= 1
    assert coord.stats["reassigned"] >= 1
    assert coord.stats["heartbeats_missed"] == 0   # EOF, not timeout
    assert not coord.hosts["e0"]["alive"]
    assert not a_done and sorted(b_done) == \
        sorted(t.task_id for t in tasks)
    ledger.close()


def test_cross_host_speculation_first_commit_wins(tmp_path):
    """A heartbeating-but-slow host keeps its lease alive, so only the
    straggler envelope can save the run — and the duplicate must land
    on a different host."""
    tasks = [_mk_task(f"t{i}") for i in range(8)]
    coord, ledger = _coordinator(
        tmp_path, tasks,
        _cfg(lease_s=1.0, speculation_min_s=0.05,
             speculation_factor=1.0, speculation_min_done=3))
    a_done, b_done = [], []
    state = {"first": True}

    def slow_once(t):
        if state["first"]:
            state["first"] = False
            return ("stall", 2.0, True, 555.0)  # alive but 40× too slow
        return ("result", 1.0)

    box = _drive(coord, [
        ("e0", slow_once, a_done),
        ("e1", lambda t: ("result", 1.0), b_done)])
    results = box["results"]
    assert set(results) == {t.task_id for t in tasks}
    assert coord.stats["speculated"] >= 1
    assert coord.stats["speculation_wins"] >= 1
    assert coord.stats["lease_expiries"] == 0   # heartbeats held it
    assert all(results[tid].task_sum == 1.0 for tid in results)
    ledger.close()


def test_error_frames_are_retried_with_backoff(tmp_path):
    tasks = [_mk_task(f"t{i}") for i in range(4)]
    coord, ledger = _coordinator(tmp_path, tasks, _cfg(executors=1))
    fails = {"left": 2}

    def flaky(t):
        if fails["left"] > 0:
            fails["left"] -= 1
            return ("error", "transient")
        return ("result", 1.0)

    done = []
    box = _drive(coord, [("e0", flaky, done)])
    assert set(box["results"]) == {t.task_id for t in tasks}
    assert coord.stats["retried"] >= 2
    ledger.close()


def test_poison_task_fails_the_run_with_resume_pointer(tmp_path):
    tasks = [_mk_task(f"t{i}") for i in range(3)]
    coord, ledger = _coordinator(
        tmp_path, tasks, _cfg(executors=1, max_retries=1))
    done = []
    box = _drive(coord, [("e0", lambda t: ("error", "poison"), done)])
    assert "error" in box
    assert "resume=True" in str(box["error"])
    ledger.close()


def test_all_executors_lost_raises_then_resumes_cleanly(tmp_path):
    """Losing every executor fails the run loudly (pointing at the
    ledger); a second coordinator over the same ledger replays the
    committed prefix and only re-executes the rest — the coordinator-
    crash recovery path uses exactly the same mechanism."""
    tasks = [_mk_task(f"t{i}") for i in range(4)]
    coord, ledger = _coordinator(
        tmp_path, tasks, _cfg(executors=1, connect_timeout_s=0.4))
    state = {"n": 0}

    def one_then_die(t):
        state["n"] += 1
        return ("result", 2.0) if state["n"] == 1 else ("die",)

    done = []
    box = _drive(coord, [("e0", one_then_die, done)])
    assert "error" in box
    assert "resume=True" in str(box["error"])
    ledger.close()
    assert len(done) == 1

    led2 = TaskLedger(ledger.path, "sig")
    completed = led2.load()
    assert set(completed) == set(done)
    led2.open_append(completed)
    coord2, _ = _coordinator(tmp_path, tasks, _cfg(executors=1),
                             completed=completed, ledger=led2)
    done2 = []
    box2 = _drive(coord2, [("e0", lambda t: ("result", 1.0), done2)])
    results = box2["results"]
    assert set(results) == {t.task_id for t in tasks}
    # the committed task was never re-dispatched, and its journaled
    # value (not the fresh 1.0) is what aggregation sees
    assert done[0] not in done2
    assert results[done[0]].task_sum == 2.0
    led2.close()


def test_fully_replayed_resume_spawns_nothing(tmp_path):
    tasks = [_mk_task(f"t{i}") for i in range(3)]
    completed = {t.task_id: TaskResult(task_sum=1.0, elapsed_s=0.01)
                 for t in tasks}
    coord, ledger = _coordinator(tmp_path, tasks,
                                 _cfg(spawn_executors=True),
                                 completed=completed)
    results = coord.run()       # must return without binding a port
    assert coord.address is None and not coord._procs
    assert set(results) == {t.task_id for t in tasks}
    assert coord.stats["run"] == 0
    ledger.close()


# ---------------- process-level fault drills (real executors) -----------


@pytest.mark.slow
def test_distributed_run_bit_exact_including_per_node(tmp_path):
    """Two real executor subprocesses, clean run: scalar count, per-node
    attribution, and a sampled (seeded) estimate all bit-exact vs the
    local backend — the wire and the per-process PRNG rebuild preserve
    every answer-defining bit."""
    g = planted_cliques(400, 0.02, [8, 8, 9], seed=5)
    local = CliqueEngine(g)
    ref = local.submit(CountRequest(k=4, return_per_node=True))
    ref_col = local.submit(CountRequest(k=4, method="color", p=0.5,
                                        colors=8, seed=3))
    eng = CliqueEngine(g, ooc=SchedulerConfig(
        executors=2, spill_dir=str(tmp_path), target_tasks=12))
    rep = eng.submit(CountRequest(k=4, backend="ooc",
                                  return_per_node=True))
    assert rep.count == ref.count
    np.testing.assert_array_equal(rep.per_node, ref.per_node)
    tel = rep.cache["scheduler"]
    assert tel["executors"] == 2 and tel["run"] == tel["tasks"]
    assert sum(h["committed"] for h in tel["per_host"].values()) \
        == tel["tasks"]
    rep_col = eng.submit(CountRequest(k=4, backend="ooc",
                                      method="color", p=0.5, colors=8,
                                      seed=3))
    assert rep_col.estimate == ref_col.estimate


@pytest.mark.slow
def test_executor_sigkill_recovery_bit_exact_and_resume(tmp_path):
    """The acceptance drill: 3 real executors, one SIGKILLed mid-flight
    by the chaos harness. The run must complete bit-exact vs the local
    backend with ≥1 lease expiry and ≥1 reassignment, and a resume=True
    rerun must re-execute zero committed tasks."""
    g = planted_cliques(400, 0.02, [8, 8, 9], seed=5)
    golden = CliqueEngine(g).submit(CountRequest(k=4)).count

    eng = CliqueEngine(g, ooc=SchedulerConfig(
        executors=3, spill_dir=str(tmp_path), target_tasks=12,
        lease_s=1.0, task_delay_s=0.15, chaos="kill:1@1",
        poll_s=0.005))
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    assert rep.count == golden
    assert tel["executors"] == 3
    assert tel["lease_expiries"] >= 1, tel
    assert tel["reassigned"] >= 1, tel
    assert tel["chaos"] == ["kill:1"]
    # the survivors covered the dead host's work
    assert sum(h["committed"] for h in tel["per_host"].values()) \
        == tel["tasks"]

    eng2 = CliqueEngine(g, ooc=SchedulerConfig(
        executors=3, spill_dir=str(tmp_path), resume=True,
        target_tasks=12))
    rep2 = eng2.submit(CountRequest(k=4, backend="ooc"))
    tel2 = rep2.cache["scheduler"]
    assert rep2.count == golden
    assert tel2["run"] == 0 and tel2["resumed"] == tel2["tasks"]
    assert tel2["spawned"] == 0     # fully replayed: no processes


@pytest.mark.slow
def test_executor_cli_entrypoint_reports_protocol_errors():
    """`python -m repro.scheduler.executor` against a coordinator that
    speaks garbage exits nonzero instead of hanging."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    host, port = srv.getsockname()[:2]

    def bad_coordinator():
        conn, _ = srv.accept()
        recv_frame(conn)                        # swallow the hello
        send_frame(conn, {"type": "nonsense"})  # not a jobspec
        conn.close()

    t = threading.Thread(target=bad_coordinator, daemon=True)
    t.start()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.scheduler.executor",
         "--connect", f"{host}:{port}", "--id", "e9"],
        env=env, timeout=60, capture_output=True)
    assert proc.returncode == 1
    srv.close()
