"""Data pipeline determinism + fault injection machinery."""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import PipelineState, SyntheticLM, make_pipeline
from repro.runtime.faults import FaultDomain, RoundScheduler, SimulatedFault


def test_pipeline_deterministic_replay():
    p1 = SyntheticLM(512, 16, 4, seed=9)
    batches1 = [next(p1) for _ in range(3)]
    p2 = SyntheticLM(512, 16, 4, seed=9)
    batches2 = [next(p2) for _ in range(3)]
    for a, b in zip(batches1, batches2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_pipeline_resume_from_state():
    p1 = SyntheticLM(512, 16, 4, seed=9)
    next(p1); next(p1)
    state = p1.state.to_dict()
    b3 = next(p1)
    p2 = SyntheticLM(512, 16, 4, seed=0)
    p2.state = PipelineState.from_dict(state)
    np.testing.assert_array_equal(b3["tokens"], next(p2)["tokens"])


def test_pipeline_family_prefixes():
    whisper = get_smoke_config("whisper-small")
    vlm = get_smoke_config("internvl2-76b")
    shape = ShapeConfig("t", 8, 2, "train")
    bw = next(make_pipeline(whisper, shape))
    assert bw["frames"].shape == (2, whisper.max_source_positions,
                                  whisper.d_model)
    bv = next(make_pipeline(vlm, shape))
    assert bv["patches"].shape == (2, vlm.n_vision_tokens,
                                   vlm.vision_embed_dim)


def test_targets_are_shifted_tokens():
    p = SyntheticLM(512, 16, 2, seed=1)
    b = p.batch_at(0)
    toks = p._tokens(0)
    np.testing.assert_array_equal(b["tokens"], toks[:, :-1])
    np.testing.assert_array_equal(b["targets"], toks[:, 1:])


# ---------------- faults ----------------

def test_fault_domain_retries_then_succeeds():
    fd = FaultDomain(fail_at=(0, 1), max_retries=3)
    assert fd.run(lambda: 42) == 42
    assert fd.calls == 3  # 2 failures + 1 success


def test_fault_domain_gives_up():
    fd = FaultDomain(fail_at=tuple(range(10)), max_retries=2)
    with pytest.raises(SimulatedFault):
        fd.run(lambda: 1)


def test_round_scheduler_journal_recovery():
    calls = []

    def unit(name):
        def f():
            calls.append(name)
            return f"done-{name}"
        return f

    fd = FaultDomain(fail_at=(1,), max_retries=2)
    sched = RoundScheduler(faults=fd)
    out = sched.run_round([("a", unit("a")), ("b", unit("b"))])
    assert out == {"a": "done-a", "b": "done-b"}
    # crash/restart: a new scheduler with the journal re-runs nothing
    sched2 = RoundScheduler(journal=dict(out))
    out2 = sched2.run_round([("a", unit("a")), ("b", unit("b"))])
    assert out2 == out
    assert calls == ["a", "b"]  # no re-execution after recovery
