"""One-pass all-k profile conformance and validation.

``CountRequest(k="all")`` answers the whole clique-number profile
q_3..q_kmax from one tile pass. The profile must equal the per-k
brute-force oracle (via the golden fixture, itself regenerated only
from ``clique_count_bruteforce``) on every backend and both tile
representations, bit-exactly; degenerate requests must be rejected up
front; and same-graph exact k-sweeps through ``submit_many`` must
coalesce into a single all-k execution.
"""
import json
import os

import numpy as np
import pytest

from repro.engine import BACKENDS, CliqueEngine, CountRequest
from repro.engine.allk import MAX_AUTO_RMAX
from repro.graphs import conformance_corpus
from repro.graphs.generators import erdos_renyi

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "golden_counts.json")


@pytest.fixture(scope="module")
def corpus():
    return conformance_corpus()


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def padded(profile, width: int) -> np.ndarray:
    """Zero-pad a (possibly clique-number-trimmed) profile to width."""
    out = np.zeros(width, np.int64)
    out[:min(profile.size, width)] = profile[:width]
    return out


# -- conformance -----------------------------------------------------------

def test_profile_matches_oracle_all_backends_and_reprs(corpus, golden):
    """4 backends x {bitset, dense}: every profile column must equal the
    pinned per-k oracle counts, from ONE pass per (backend, repr)."""
    for g in corpus:
        pinned = np.asarray(golden[g.name]["profile"], np.int64)
        kmax = 2 + len(pinned)
        eng = CliqueEngine(g)
        for b in BACKENDS:
            for engine in ("bitset", "dense"):
                rep = eng.submit(CountRequest(k="all", max_k=kmax,
                                              backend=b, engine=engine))
                got = padded(rep.profile, len(pinned))
                np.testing.assert_array_equal(
                    got, pinned, err_msg=f"{g.name} {b}/{engine}")
                assert rep.estimate == float(rep.profile.sum())


def test_uncapped_profile_extends_to_clique_number(golden):
    """Without max_k the profile runs to the graph's clique number —
    the complete-unit host path is exact at any depth, so K10's q_8..
    q_10 appear beyond the fixture's pinned k <= 7 range."""
    corpus = conformance_corpus()
    g = next(g for g in corpus if g.name == "K10")
    rep = CliqueEngine(g).submit(CountRequest(k="all"))
    want = np.array([120, 210, 252, 210, 120, 45, 10, 1], np.int64)
    np.testing.assert_array_equal(rep.profile, want)
    pinned = np.asarray(golden["K10"]["profile"], np.int64)
    np.testing.assert_array_equal(rep.profile[:len(pinned)], pinned)


def test_profile_trims_trailing_zeros(corpus):
    """A graph whose clique number is below the pinned range returns a
    short profile, not trailing zero columns."""
    for g in corpus:
        rep = CliqueEngine(g).submit(CountRequest(k="all", max_k=7))
        if rep.profile.size:
            assert rep.profile[-1] > 0, (g.name, rep.profile)


# -- depth guard -----------------------------------------------------------

def test_auto_depth_guard_requires_max_k():
    """A graph with a deep non-complete unit must refuse an uncapped
    all-k (device recursion past MAX_AUTO_RMAX) and point at max_k."""
    g = erdos_renyi(32, 0.85, seed=7)
    eng = CliqueEngine(g)
    with pytest.raises(ValueError, match="max_k"):
        eng.submit(CountRequest(k="all"))
    # the same request capped runs, and matches the per-k exact path
    rep = eng.submit(CountRequest(k="all", max_k=5))
    for j, k in enumerate((3, 4, 5)):
        want = eng.submit(CountRequest(k=k)).count
        got = int(rep.profile[j]) if j < rep.profile.size else 0
        assert got == want, (k, rep.profile)
    assert MAX_AUTO_RMAX == 8   # docs + error message quote this bound


# -- validation ------------------------------------------------------------

def test_degenerate_k_rejected_up_front():
    for bad in (2, 0, -1, True, 3.0, "al", None):
        with pytest.raises(ValueError):
            CountRequest(k=bad).validate()


def test_allk_rejects_non_exact_modes():
    for kw in (dict(mode="list", limit=5),
               dict(method="color", colors=4),
               dict(method="edge", p=0.5),
               dict(rel_error=0.1, method="auto"),
               dict(return_per_node=True),
               dict(split_threshold=8),
               dict(max_k=2),
               dict(max_k="7")):
        with pytest.raises(ValueError):
            CountRequest(k="all", **kw).validate()
    # max_k is an all-k knob only
    with pytest.raises(ValueError):
        CountRequest(k=4, max_k=6).validate()


def test_ooc_resolved_default_backend_rejects_listing(corpus):
    """A mode="list" request with backend=None on an ooc-default engine
    must fail validation (no in-memory emit path) instead of dying on
    a missing tile budget mid-stream."""
    eng = CliqueEngine(corpus[0], backend="ooc")
    with pytest.raises(ValueError, match="listing|list"):
        list(eng.stream(CountRequest(k=3, mode="list", chunk=8)))


# -- sweep coalescing ------------------------------------------------------

def test_submit_many_coalesces_exact_sweep(corpus):
    g = next(g for g in corpus if g.n <= 64)
    eng = CliqueEngine(g)
    ks = (3, 4, 5)
    want = {k: eng.submit(CountRequest(k=k)).count for k in ks}
    reps = eng.submit_many([CountRequest(k=k) for k in ks])
    assert [r.k for r in reps] == list(ks)
    for r in reps:
        assert r.cache["sweep_coalesced"] == len(ks)
        assert r.profile is None          # fan-out reports are per-k
        assert int(round(r.estimate)) == want[r.k]


def test_submit_many_coalescing_opt_out_and_mixed_batches(corpus):
    g = next(g for g in corpus if g.n <= 64)
    eng = CliqueEngine(g)
    reps = eng.submit_many([CountRequest(k=k) for k in (3, 4)],
                           coalesce_sweeps=False)
    assert all("sweep_coalesced" not in r.cache for r in reps)
    # a sampled entry breaks eligibility: the batch runs per-request
    mixed = eng.submit_many([CountRequest(k=3),
                             CountRequest(k=4, method="color", colors=4)])
    assert all("sweep_coalesced" not in r.cache for r in mixed)
    # per-request backends must also match for the batch to coalesce
    split = eng.submit_many([CountRequest(k=3),
                             CountRequest(k=4, backend="shard_map")])
    assert all("sweep_coalesced" not in r.cache for r in split)
