"""Adaptive estimator (repro.estimator): controller behavior, the
subset tile's unbiasedness, CI plumbing through engine and service,
seed decorrelation in sweeps, and the Lemma 1 bound check."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clique_count_bruteforce
from repro.core.count import subset_tile_values
from repro.core.csr import build_oriented
from repro.core.extract import to_device
from repro.core.mrc import compute_stats
from repro.core.plan import build_plan
from repro.engine import CliqueEngine, CountRequest
from repro.estimator import empirical_bernstein, kruskal_katona_bound
from repro.graphs import (barabasi_albert, complete_bipartite,
                          conformance_corpus, erdos_renyi,
                          planted_cliques)


@pytest.fixture(scope="module")
def big_planted():
    return planted_cliques(1200, 0.02, [12, 16, 40], seed=9,
                           name="planted_1200_12_16_40")


# -- subset tile -----------------------------------------------------------

def test_subset_tile_unbiased_and_exact_when_kept_covers():
    """Fixed-size neighborhood subsampling is unbiased (mean over keys →
    per-node exact counts) and degenerates to exact when kept ≥ d."""
    g = erdos_renyi(40, 0.4, seed=2)
    og = build_oriented(g)
    csr = to_device(og)
    plan = build_plan(og, 4)
    bf, per_node = clique_count_bruteforce(g, 4, return_per_node=True)
    r = 3
    total_exact = 0.0
    means = np.zeros(g.n)
    for b in plan.buckets:
        nodes = jnp.asarray(b.nodes)
        # kept ≥ capacity ⇒ every neighborhood fully retained ⇒ exact
        vals = subset_tile_values(csr, nodes, jax.random.PRNGKey(0),
                                  capacity=b.capacity, kept=b.capacity,
                                  n_iters=og.lookup_iters, r=r)
        total_exact += float(np.asarray(vals).sum())
        reps = np.stack([
            np.asarray(subset_tile_values(
                csr, nodes, jax.random.PRNGKey(s), capacity=b.capacity,
                kept=8, n_iters=og.lookup_iters, r=r))
            for s in range(300)])
        sel = b.nodes >= 0
        np.add.at(means, b.nodes[sel], reps.mean(axis=0)[sel])
    assert total_exact == pytest.approx(bf)
    heavy = og.out_deg > 8          # only these are actually subsampled
    assert heavy.any()
    rel = np.abs(means - per_node)[heavy] / np.maximum(per_node[heavy], 1)
    assert rel.mean() < 0.15, rel    # 300 replicates → means converge


def test_kruskal_katona_bound_matches_extremal_graphs():
    # complete graphs: e = C(x,2) edges hold exactly C(x,r) r-cliques
    for x, r in [(4, 3), (6, 3), (6, 4), (8, 5)]:
        e = x * (x - 1) // 2
        from math import comb
        assert kruskal_katona_bound(np.array([e]), r)[0] == comb(x, r)
    # below C(r,2) edges no r-clique fits
    assert kruskal_katona_bound(np.array([2.0]), 3)[0] == 0


def test_empirical_bernstein_zero_width_only_when_certified():
    X = np.zeros((3, 10))
    est, hw, _ = empirical_bernstein(X, 0.99, M=0.0)
    assert est == 0.0 and hw == 0.0
    # same observations, but a unit could still hide mass: hw must stay
    # open — lucky all-zero replicates cannot fake certainty
    est, hw, _ = empirical_bernstein(X, 0.99, M=5.0)
    assert hw > 0.0


# -- controller ------------------------------------------------------------

def test_auto_small_graph_falls_through_to_exact():
    g = barabasi_albert(64, 6, seed=3)
    eng = CliqueEngine(g)
    rep = eng.submit(CountRequest(k=5, method="auto", rel_error=0.05))
    assert rep.params["resolved"] == "exact"
    assert rep.count == clique_count_bruteforce(g, 5)
    assert rep.ci_low == rep.ci_high == rep.estimate
    assert rep.achieved_rel_error == 0.0
    assert eng.session_stats()["estimator"]["fallthroughs"] == 1


def test_auto_large_graph_samples_and_covers(big_planted):
    eng = CliqueEngine(big_planted)
    exact = eng.submit(CountRequest(k=5)).count
    rep = eng.submit(CountRequest(k=5, method="auto", rel_error=0.05,
                                  confidence=0.99, seed=3))
    assert rep.params["resolved"] == "sampled"
    assert rep.ci_low <= exact <= rep.ci_high
    assert rep.achieved_rel_error <= 0.05
    assert rep.estimator["replicates"] >= 2
    # sampled work stayed below the exact work model
    assert rep.estimator["spent_work"] < rep.estimator["exact_work"]


def test_auto_zero_count_graph_reports_honest_zero():
    """Bipartite ⇒ q_k = 0 for k ≥ 3: the zero-certificates collapse
    every unit (no edges inside any Γ⁺), so the CI is exactly [0, 0]."""
    g = complete_bipartite(12, 12)
    eng = CliqueEngine(g)
    for k in (3, 4):
        rep = eng.submit(CountRequest(k=k, method="auto", rel_error=0.05))
        assert rep.estimate == 0.0
        assert rep.ci_low <= 0.0 <= rep.ci_high
        assert rep.ci_high - rep.ci_low == 0.0


def test_adaptive_mask_levers_stay_honest():
    """edge/color with a rel_error target: tiny graph + tiny count ⇒ no
    mask level can certify the bar, so the controller escalates its knob
    and lands exact — never a lucky zero-width lie."""
    g = erdos_renyi(48, 0.25, seed=11)
    eng = CliqueEngine(g)
    bf = clique_count_bruteforce(g, 4)
    for method in ("edge", "color"):
        rep = eng.submit(CountRequest(k=4, method=method, rel_error=0.1,
                                      confidence=0.9))
        assert rep.ci_low <= bf <= rep.ci_high, method
        assert rep.estimator is not None    # report carries CI fields
        assert rep.escalations > 0 or rep.params["resolved"] == "exact"


def test_adaptive_rejects_shard_map_and_bad_targets():
    g = erdos_renyi(30, 0.3, seed=1)
    eng = CliqueEngine(g)
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=4, method="auto", rel_error=0.1,
                                backend="shard_map"))
    with pytest.raises(ValueError):
        CountRequest(k=4, method="auto", rel_error=-0.1).validate()
    with pytest.raises(ValueError):
        CountRequest(k=4, method="exact", rel_error=0.1).validate()
    with pytest.raises(ValueError):
        CountRequest(k=4, method="auto", confidence=1.5).validate()
    with pytest.raises(ValueError):
        CountRequest(k=4, method="auto", rel_error=0.1,
                     split_threshold=8).validate()
    with pytest.raises(ValueError):
        # split units would be sampled but never certified — the mask
        # levers must refuse too, not just auto
        CountRequest(k=4, method="edge", rel_error=0.1,
                     split_threshold=8).validate()


def test_auto_never_subsamples_below_clique_size():
    """Regression: a start level with kept < r = k−1 destroys every
    clique in the kept subgraphs and used to report a certified-zero
    [0, 0] interval for deep k. The lever must clamp its start level to
    ≥ r (exercised here by forcing init_kept below r, the cheap stand-in
    for the k ≥ 10 case where r outgrows the default of 8)."""
    from repro.estimator import EstimatorPolicy
    g = erdos_renyi(40, 0.5, seed=1)
    eng = CliqueEngine(g)
    eng.estimator_policy = EstimatorPolicy(init_kept=2)
    truth = clique_count_bruteforce(g, 5)
    assert truth > 0
    rep = eng.submit(CountRequest(k=5, method="auto", rel_error=0.1))
    assert rep.estimator["level"] is None or rep.estimator["level"] >= 4
    assert rep.ci_low <= truth <= rep.ci_high, \
        (truth, rep.ci_low, rep.ci_high, rep.params["resolved"])


def test_run_adaptive_reuses_certificates_and_exact_parts(big_planted):
    """Second auto query on a session recomputes neither the density
    certificates nor the key-independent deterministic/stochastic node
    split the wedge lever replicates over."""
    eng = CliqueEngine(big_planted)
    eng.submit(CountRequest(k=5, method="auto", rel_error=0.05, seed=0))
    # plans went k-agnostic in the all-k PR: keyed by plan_key() =
    # (max_capacity, split_threshold), not (k, ...)
    entry = eng._plans[(None, None)]
    assert "certificates" in entry._aux
    assert ("subset_parts", 4) in entry._aux   # r = k - 1
    m0, h0 = eng.executables.misses, eng.executables.hits
    eng.submit(CountRequest(k=5, method="auto", rel_error=0.05, seed=1))
    assert eng.executables.hits > h0          # compiled tiles reused
    assert eng.executables.misses == m0       # ... with nothing rebuilt


# -- report / service plumbing --------------------------------------------

def test_auto_query_key_coalesces_on_target_not_seed():
    a = CountRequest(k=5, method="auto", rel_error=0.05, seed=1)
    b = CountRequest(k=5, method="auto", rel_error=0.05, seed=2,
                     p=0.7, colors=3)
    c = CountRequest(k=5, method="auto", rel_error=0.01, seed=1)
    d = CountRequest(k=5, method="edge", rel_error=0.05)
    assert a.query_key() == b.query_key()
    assert a.query_key() != c.query_key()
    assert a.query_key() != d.query_key()
    # non-adaptive sampled requests still key on their knobs
    e = CountRequest(k=5, method="edge", p=0.5, seed=1)
    f = CountRequest(k=5, method="edge", p=0.5, seed=2)
    assert e.query_key() != f.query_key()


def test_service_coalesces_auto_and_reports_adaptive_stats():
    from repro.serving.cliques import CliqueService
    g = erdos_renyi(40, 0.3, seed=6)
    svc = CliqueService(max_sessions=2)
    t1 = svc.submit(g, CountRequest(k=4, method="auto", rel_error=0.1,
                                    seed=1))
    t2 = svc.submit(g, CountRequest(k=4, method="auto", rel_error=0.1,
                                    seed=2))
    r1, r2 = t1.result(), t2.result()
    assert r1.count == r2.count == clique_count_bruteforce(g, 4)
    stats = svc.stats()
    assert stats["coalesced"] == 1
    assert stats["adaptive"]["executed"] == 1
    assert r1.cache["coalesced"] == 2


# -- sweep seed plumbing (regression) -------------------------------------

def test_submit_many_decorrelates_sampled_sweep_entries():
    g = barabasi_albert(300, 8, seed=9)
    eng = CliqueEngine(g)
    req = CountRequest(k=4, method="color", colors=3, seed=0)
    reps = eng.submit_many([req, req, req])
    ests = [r.estimate for r in reps]
    assert len(set(ests)) == 3, \
        f"sweep replicates share one seed (correlated): {ests}"
    # deterministic: the same sweep resubmitted reproduces bit-for-bit
    again = [r.estimate for r in eng.submit_many([req, req, req])]
    assert again == ests
    # opt-out restores verbatim submission (all entries identical)
    verbatim = [r.estimate
                for r in eng.submit_many([req, req], decorrelate=False)]
    assert verbatim[0] == verbatim[1]
    # exact entries are untouched by decorrelation
    ex = eng.submit_many([CountRequest(k=4), CountRequest(k=4)])
    assert ex[0].estimate == ex[1].estimate


# -- Lemma 1 ---------------------------------------------------------------

def test_lemma1_bound_holds_on_corpus():
    """Largest capacity class (max |Γ⁺(u)|) ≤ 2√m — paper Lemma 1, now
    actually checked instead of stubbed True."""
    for g in conformance_corpus():
        og = build_oriented(g)
        plan = build_plan(og, 4)
        stats = compute_stats(og, plan)
        assert stats.max_unit_size == int(og.out_deg.max())
        checks = stats.check_bounds()
        assert checks["lemma1"], (g.name, stats.max_unit_size, stats.m)


def test_lemma1_check_detects_violation():
    g = erdos_renyi(30, 0.3, seed=1)
    og = build_oriented(g)
    stats = compute_stats(og, build_plan(og, 4))
    bad = dataclasses.replace(stats, max_unit_size=10 ** 6)
    assert not bad.check_bounds()["lemma1"]
