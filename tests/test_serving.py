"""Serving: prefill→decode consistency, SWA ring buffers, engine API."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_pipeline
from repro.models import decode_step, init_params, prefill
from repro.serving.engine import Engine

KEY = jax.random.PRNGKey(1)
B, S = 2, 24


def _setup(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    shape = ShapeConfig("t", S + 1, B, "train")
    full = {k: jnp.asarray(v) for k, v in
            next(make_pipeline(cfg, shape, seed=3)).items()}
    return cfg, params, full


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_then_decode_matches_longer_prefill(arch):
    """prefill(S) + decode(token S) ≡ prefill(S+1) last logits — the
    strongest cache-consistency check there is."""
    cfg, params, full = _setup(arch)
    n_prefix = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    b1 = dict(full); b1["tokens"] = full["tokens"][:, :S]
    b2 = dict(full); b2["tokens"] = full["tokens"][:, :S + 1]
    cap = S + 1 + n_prefix
    cache, _ = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len=cap))(
        params, b1)
    logits_dec, _ = jax.jit(
        lambda p, c, t, q: decode_step(cfg, p, c, t, q))(
            params, cache, full["tokens"][:, S], jnp.int32(n_prefix + S))
    _, logits_pf = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len=cap))(
        params, b2)
    rel = float(jnp.max(jnp.abs(logits_dec - logits_pf))) / \
        (float(jnp.max(jnp.abs(logits_pf))) + 1e-9)
    assert rel < 0.03, rel


def test_swa_ring_buffer_matches_full_cache():
    """With window >= seq the ring cache must reproduce full attention."""
    import dataclasses
    cfg = get_smoke_config("mixtral-8x7b")
    cfg_big = dataclasses.replace(cfg, sliding_window=4096)  # no-op window
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    params = init_params(cfg_full, KEY)
    shape = ShapeConfig("t", S + 1, B, "train")
    full = {k: jnp.asarray(v) for k, v in
            next(make_pipeline(cfg, shape, seed=5)).items()}
    b = dict(full); b["tokens"] = full["tokens"][:, :S]
    outs = []
    for c in (cfg_big, cfg_full):
        cache, lg = jax.jit(
            lambda p, bb: prefill(c, p, bb, cache_len=S + 1))(params, b)
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-2, atol=2e-2)


def test_engine_generate_greedy_deterministic():
    cfg, params, full = _setup("tinyllama-1.1b")
    eng = Engine(cfg, params)
    b = {"tokens": full["tokens"][:, :8]}
    out1 = eng.generate(b, max_new_tokens=5)
    out2 = eng.generate(b, max_new_tokens=5)
    assert out1.shape == (B, 5)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 < cfg.vocab_size).all()


def test_engine_temperature_sampling_runs():
    cfg, params, full = _setup("mamba2-370m")
    eng = Engine(cfg, params)
    out = eng.generate({"tokens": full["tokens"][:, :8]},
                       max_new_tokens=4, temperature=0.8, seed=3)
    assert out.shape == (B, 4)
