"""Pipeline parallelism: pipelined forward ≡ sequential forward."""
import pytest

from conftest import run_with_devices

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 30) < 0.04  # many microbatches amortize


@pytest.mark.slow
def test_pipelined_forward_matches_sequential():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline import pipeline_forward

L, D, B = 8, 16, 12
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * 0.3

def block_fn(stage_params, x):
    def layer(c, wl):
        return jnp.tanh(c @ wl), ()
    y, _ = jax.lax.scan(layer, x, stage_params)
    return y

x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
# sequential reference
ref = block_fn(w, x)
mesh = make_mesh((4, 2), ("pipe", "data"))
for n_stages, n_micro in ((4, 4), (4, 6)):
    fn = pipeline_forward(block_fn, n_stages, n_micro, mesh, axis="pipe")
    got = jax.jit(fn)(w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
print("OK")
""", n_devices=8, timeout=600)
