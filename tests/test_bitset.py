"""Packed-bitset tile pipeline: conformance, round-trip, budgets.

The packed uint32 representation must be *bit-exact* against the
brute-force oracle on the whole conformance corpus (k ∈ 3..6, local and
shard_map backends), `pack_rows`/`unpack_rows` must round-trip any 0/1
adjacency, the byte-accounted tile batching must never exceed the
budget (the seed's `max(8, …)` floor shipped 512 MiB tiles at D=4096),
and `engine="bitset"` must reproduce the golden fixture.
"""
import json
import math
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import clique_count_bruteforce
from repro.core.count import (_pick_tile_b, _tile_batches, dag_count,
                              dag_count_bits, pick_tile_repr,
                              subset_unit_bytes, tile_batch_repr,
                              tile_unit_bytes)
from repro.core.extract import pack_adjacency
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import conformance_corpus
from repro.kernels.bitset import (dag_count_bits_pallas, pack_rows,
                                  unpack_rows)

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "golden_counts.json")

# the large planted graph is oracle-tractable only up to k=5 (its 40-clique
# alone holds C(40,6) ≈ 3.8M 6-cliques); golden pins it the same way
BIG = "planted_1200_12_16_40"
KS = (3, 4, 5, 6)


def _random_dag(rng, B, D, density):
    return np.triu((rng.random((B, D, D)) < density), 1).astype(np.float32)


@pytest.fixture(scope="module")
def corpus():
    return conformance_corpus()


# --------------------------------------------------------------------------
# kernel-level: packed identities vs the dense reference
# --------------------------------------------------------------------------

@pytest.mark.parametrize("D", [8, 40, 64, 128])
@pytest.mark.parametrize("r", [2, 3, 4, 5])
def test_dag_count_bits_matches_dense(D, r):
    rng = np.random.default_rng(D * 10 + r)
    A = jnp.asarray(_random_dag(rng, 5, D, 0.3))
    bits = pack_adjacency(A)
    want = np.asarray(dag_count(A, r))
    np.testing.assert_array_equal(np.asarray(dag_count_bits(bits, r)),
                                  want)
    np.testing.assert_array_equal(np.asarray(dag_count_bits_pallas(bits,
                                                                   r)),
                                  want)


@pytest.mark.parametrize("r", [3, 4, 5, 6])
def test_bits_complete_graph_closed_form(r):
    D = 12
    A = jnp.asarray(np.triu(np.ones((2, D, D), np.float32), 1))
    got = np.asarray(dag_count_bits(pack_adjacency(A), r))
    assert got[0] == got[1] == math.comb(D, r)


# --------------------------------------------------------------------------
# engine-level: bitset engine vs the brute-force oracle, all backends
# --------------------------------------------------------------------------

def test_bitset_engine_matches_bruteforce(corpus):
    for g in corpus:
        eng = CliqueEngine(g)
        for k in KS:
            if g.name == BIG and k > 5:
                continue
            expected = clique_count_bruteforce(g, k)
            for backend in ("local", "shard_map"):
                rep = eng.submit(CountRequest(k=k, backend=backend,
                                              engine="bitset"))
                assert rep.count == expected, (g.name, k, backend)


def test_bitset_per_node_bit_for_bit(corpus):
    """Packed per-node attributions must equal the oracle's ≺-minimum
    responsibility assignment exactly (local + pallas backends)."""
    for g in corpus[:5]:
        eng = CliqueEngine(g)
        _, per_node = clique_count_bruteforce(g, 4, return_per_node=True)
        for backend in ("local", "pallas"):
            rep = eng.submit(CountRequest(k=4, backend=backend,
                                          engine="bitset",
                                          return_per_node=True))
            got = np.round(rep.per_node).astype(np.int64)
            np.testing.assert_array_equal(got, per_node,
                                          err_msg=f"{g.name} {backend}")


def test_bitset_split_round_conformance(corpus):
    for g in corpus[:5]:
        eng = CliqueEngine(g)
        for k in (3, 4):
            expected = clique_count_bruteforce(g, k)
            for backend in ("local", "shard_map"):
                rep = eng.submit(CountRequest(k=k, backend=backend,
                                              engine="bitset",
                                              split_threshold=8))
                assert rep.count == expected, (g.name, k, backend)


def test_sampled_estimates_identical_across_reprs(corpus):
    """Masks are packed before counting, so a sampled estimate is the
    same number on the dense and packed paths (same seed, same mask)."""
    eng = CliqueEngine(corpus[1])
    for method, kw in [("edge", {"p": 0.5}), ("color", {"colors": 3})]:
        ests = {e: eng.submit(CountRequest(k=4, method=method, seed=7,
                                           engine=e, **kw)).estimate
                for e in ("dense", "bitset")}
        assert round(ests["dense"], 6) == round(ests["bitset"], 6), ests


def test_bitset_engine_matches_golden():
    with open(FIXTURE) as f:
        golden = json.load(f)
    for g in conformance_corpus():
        eng = CliqueEngine(g)
        for k_str, expected in golden[g.name]["counts"].items():
            rep = eng.submit(CountRequest(k=int(k_str), engine="bitset"))
            assert rep.count == expected, (g.name, k_str)


def test_nipp_rides_the_bitset_path(corpus):
    """method="ni++" (k=3) must resolve to the packed representation it
    was written for, report 2-round MRC stats, and stay exact."""
    assert pick_tile_repr(r=2, capacity=64, method="ni++",
                          choice="auto") == "bits"
    g = corpus[3]
    eng = CliqueEngine(g)
    rep = eng.submit(CountRequest(k=3, method="ni++"))
    assert rep.count == clique_count_bruteforce(g, 3)
    assert rep.mrc.rounds == 2
    assert any(key[0] == "tile" and key[2] == "bits"
               for key in eng.executables._fns), \
        "ni++ did not touch a packed tile executable"


# --------------------------------------------------------------------------
# representation cost model + byte-accounted tile batching
# --------------------------------------------------------------------------

def test_tile_unit_bytes_ratio():
    for D in (32, 128, 256, 1024, 4096):
        assert tile_unit_bytes(D, "dense") == 4 * D * D
        assert tile_unit_bytes(D, "dense") == 32 * tile_unit_bytes(D,
                                                                   "bits")


def test_pick_tile_repr_policy():
    budget = 1 << 23
    # k=3 (r=2) and ni++ are popcount work at any capacity
    assert pick_tile_repr(r=2, capacity=8, elem_budget=budget) == "bits"
    # mid-size r>=3 buckets keep the MXU matmul identity
    assert pick_tile_repr(r=3, capacity=256, elem_budget=budget) == "dense"
    assert pick_tile_repr(r=4, capacity=1024, elem_budget=budget) == "dense"
    # huge-capacity buckets: a minimal dense batch blows the byte budget
    assert pick_tile_repr(r=4, capacity=2048, elem_budget=budget) == "bits"
    assert pick_tile_repr(r=4, capacity=4096, elem_budget=budget) == "bits"
    # forced choices override the model
    assert pick_tile_repr(r=4, capacity=64, choice="bitset") == "bits"
    assert pick_tile_repr(r=2, capacity=64, choice="dense") == "dense"


def test_tile_batches_respect_byte_budget():
    """The seed's `B = max(8, budget // D²)` exceeded the budget for
    D ≥ 2048 (8 units at D=4096 is a 512 MiB f32 tile). Pin the fixed
    sizes: bytes per tile ≤ 4·elem_budget for every representation."""
    budget = 1 << 23                      # f32 elements → 32 MiB
    expect = {("dense", 1024): 8, ("dense", 2048): 2, ("dense", 4096): 1,
              ("bits", 1024): 256, ("bits", 2048): 64, ("bits", 4096): 16}
    nodes = np.arange(4096, dtype=np.int32)
    for (repr_, D), want_b in expect.items():
        got_b = _pick_tile_b(len(nodes), D, budget, repr_)
        assert got_b == want_b, (repr_, D, got_b, want_b)
        tiles = list(_tile_batches(nodes, D, budget, repr_))
        assert all(len(t) == want_b for t in tiles)
        if want_b > 1:  # a single unit is the floor — can't split further
            assert want_b * tile_unit_bytes(D, repr_) <= 4 * budget
        assert sum((t >= 0).sum() for t in tiles) == len(nodes)


def test_sampled_packed_tiles_batch_at_dense_sizes():
    """Sampled methods materialize a transient dense mask before
    packing, so their packed tiles must not claim the 32× batch."""
    assert tile_batch_repr("bits", "exact") == "bits"
    assert tile_batch_repr("dense", "exact") == "dense"
    for method in ("edge", "color", "color_smooth"):
        assert tile_batch_repr("bits", method) == "dense"
        assert tile_batch_repr("dense", method) == "dense"


def test_subset_units_not_accounted_at_full_capacity():
    """The subset lever's units build an (S, S) compacted tile, not a
    D² one — a capacity-4096 bucket must still batch many units."""
    budget = 1 << 23
    b = _pick_tile_b(10_000, 4096, budget,
                     unit_bytes=subset_unit_bytes(4096, 8))
    assert b >= 8, b
    assert b * subset_unit_bytes(4096, 8) <= 4 * budget


def test_tile_batches_small_caps_unchanged():
    """Buckets whose dense tiles already fit keep the seed's sizes (no
    recompile churn for existing sessions)."""
    nodes = np.arange(100, dtype=np.int32)
    assert _pick_tile_b(len(nodes), 512, 1 << 23, "dense") == 32
    assert _pick_tile_b(len(nodes), 1024, 1 << 23, "dense") == 8


# --------------------------------------------------------------------------
# pack/unpack round-trip (hypothesis)
# --------------------------------------------------------------------------

def test_pack_rows_agrees_with_core_packer():
    rng = np.random.default_rng(3)
    A = jnp.asarray(_random_dag(rng, 2, 40, 0.5))   # D=40: ragged word
    np.testing.assert_array_equal(np.asarray(pack_rows(A)),
                                  np.asarray(pack_adjacency(A)))


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    given = None


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), B=st.integers(1, 4),
           D=st.integers(1, 70), density=st.floats(0.0, 1.0))
    def test_pack_unpack_roundtrip(seed, B, D, density):
        rng = np.random.default_rng(seed)
        A = (rng.random((B, D, D)) < density).astype(np.float32)  # any 0/1
        Aj = jnp.asarray(A)
        for packer in (pack_rows, pack_adjacency):
            bits = packer(Aj)
            assert bits.shape == (B, D, (D + 31) // 32)
            assert bits.dtype == jnp.uint32
            np.testing.assert_array_equal(np.asarray(unpack_rows(bits, D)),
                                          A)
