"""Flash attention (custom_vjp) vs naive softmax attention: outputs and
gradients, across mask modes, GQA grouping, and MLA-style dv ≠ dh."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention


def naive(q, k, v, causal, window, q_offset=0):
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


CASES = [
    dict(causal=True, window=0, dv=16, Hkv=2, H=4),    # GQA causal
    dict(causal=True, window=8, dv=16, Hkv=2, H=2),    # SWA
    dict(causal=False, window=0, dv=16, Hkv=4, H=4),   # cross-attn style
    dict(causal=True, window=0, dv=12, Hkv=2, H=4),    # MLA dv != dh
]


@pytest.mark.parametrize("case", CASES)
def test_flash_forward_matches_naive(case):
    rng = np.random.default_rng(0)
    B, Sq, Skv, dh = 2, 16, 32, 16
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, case["H"], dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Skv, case["Hkv"], dh)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Skv, case["Hkv"], case["dv"])),
                    jnp.float32)
    got = chunked_attention(q, k, v, causal=case["causal"],
                            window=case["window"], chunk=8)
    want = naive(q, k, v, case["causal"], case["window"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:2])
def test_flash_gradients_match_naive(case):
    rng = np.random.default_rng(1)
    B, Sq, Skv, dh = 2, 16, 32, 16
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, case["H"], dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Skv, case["Hkv"], dh)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Skv, case["Hkv"], case["dv"])),
                    jnp.float32)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(chunked_attention(
            q, k, v, causal=case["causal"], window=case["window"],
            chunk=8)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, case["causal"],
                                     case["window"])))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=2e-5)


def test_flash_q_offset_decode_continuation():
    """q_offset shifts the causal frontier (prefill continuation)."""
    rng = np.random.default_rng(2)
    B, H, dh = 1, 2, 8
    k = jnp.asarray(rng.normal(0, 1, (B, 16, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, 16, H, dh)), jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, 4, H, dh)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, chunk=8, q_offset=12)
    want = naive(q, k, v, True, 0, q_offset=12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_flash_bf16_path_close_to_f32():
    rng = np.random.default_rng(3)
    B, S, H, dh = 2, 32, 4, 16
    q = rng.normal(0, 1, (B, S, H, dh))
    k = rng.normal(0, 1, (B, S, 2, dh))
    v = rng.normal(0, 1, (B, S, 2, dh))
    f32 = chunked_attention(jnp.asarray(q, jnp.float32),
                            jnp.asarray(k, jnp.float32),
                            jnp.asarray(v, jnp.float32),
                            causal=True, chunk=8)
    b16 = chunked_attention(jnp.asarray(q, jnp.bfloat16),
                            jnp.asarray(k, jnp.bfloat16),
                            jnp.asarray(v, jnp.bfloat16),
                            causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(b16, np.float32),
                               np.asarray(f32), rtol=0.1, atol=0.05)
