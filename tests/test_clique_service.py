"""CliqueService: pooled sessions, request coalescing, LRU eviction,
telemetry, and the background worker."""
import threading

import pytest

from repro.core import clique_count_bruteforce
from repro.engine import CliqueEngine, CountRequest, graph_fingerprint
from repro.graphs import barabasi_albert, erdos_renyi, relabel
from repro.serving.cliques import CliqueService, EnginePool

import numpy as np


@pytest.fixture(scope="module")
def graphs():
    return (erdos_renyi(40, 0.25, seed=1),
            barabasi_albert(80, 5, seed=2),
            erdos_renyi(36, 0.3, seed=3))


@pytest.fixture(scope="module")
def bf(graphs):
    return {g.name: {k: clique_count_bruteforce(g, k) for k in (3, 4)}
            for g in graphs}


def test_fingerprint_is_structural(graphs):
    a, b, _ = graphs
    assert graph_fingerprint(a) == graph_fingerprint(a)
    assert graph_fingerprint(a) != graph_fingerprint(b)
    # identity permutation reorders nothing: same canonical edges
    ident = relabel(a, np.arange(a.n))
    assert graph_fingerprint(ident) == graph_fingerprint(a)
    assert CliqueEngine(a).fingerprint == graph_fingerprint(a)


def test_results_match_oracle_across_graphs(graphs, bf):
    svc = CliqueService(max_sessions=3)
    tickets = svc.submit_many([(g, CountRequest(k=k))
                               for g in graphs for k in (3, 4)])
    for t, (g, k) in zip(tickets, [(g, k) for g in graphs
                                   for k in (3, 4)]):
        assert t.result().count == bf[g.name][k]
    stats = svc.stats()
    assert stats["executed"] == 6 and stats["failed"] == 0
    assert stats["pool"]["live"] == 3


def test_duplicate_inflight_queries_coalesce(graphs, bf):
    g = graphs[0]
    svc = CliqueService(max_sessions=2)
    dup = [svc.submit(g, CountRequest(k=4)) for _ in range(4)]
    other = svc.submit(g, CountRequest(k=3))
    svc.drain()
    for t in dup:
        rep = t.result()
        assert rep.count == bf[g.name][4]
        assert rep.cache["coalesced"] == 4     # fanout visible per report
    assert other.result().cache["coalesced"] == 1
    stats = svc.stats()
    assert stats["submitted"] == 5
    assert stats["coalesced"] == 3             # 3 of the 4 dups rode along
    assert stats["executed"] == 2              # one k=4 run + one k=3 run


def test_exact_queries_coalesce_across_seeds_sampled_do_not(graphs):
    g = graphs[1]
    svc = CliqueService()
    svc.submit(g, CountRequest(k=3, seed=0))
    svc.submit(g, CountRequest(k=3, seed=99))           # exact: same answer
    svc.submit(g, CountRequest(k=3, method="color", colors=3, seed=0))
    svc.submit(g, CountRequest(k=3, method="color", colors=3, seed=99))
    svc.drain()
    stats = svc.stats()
    assert stats["coalesced"] == 1 and stats["executed"] == 3


def test_submit_many_decorrelates_sampled_replicates(graphs):
    """Ordering pin: submit_many must fold each batch index into the
    sampled seeds BEFORE submit() computes the coalescing key. R
    identical sampled replicates in one batch are meant as independent
    estimates — submitted verbatim they would share a query key and
    collapse into R copies of ONE execution."""
    g = graphs[1]
    base = CountRequest(k=3, method="color", colors=3, seed=7)
    svc = CliqueService()
    tickets = svc.submit_many([(g, base)] * 3)
    svc.drain()
    stats = svc.stats()
    assert stats["coalesced"] == 0 and stats["executed"] == 3
    seeds = {t.result().params["seed"] for t in tickets}
    assert len(seeds) == 3                     # distinct derived seeds
    # exact replicates still coalesce (their keys normalize the seed)
    svc2 = CliqueService()
    svc2.submit_many([(g, CountRequest(k=3, seed=s)) for s in (0, 1, 2)])
    svc2.drain()
    s2 = svc2.stats()
    assert s2["coalesced"] == 2 and s2["executed"] == 1
    # and the escape hatch submits verbatim: one execution, R copies
    svc3 = CliqueService()
    svc3.submit_many([(g, base)] * 3, decorrelate=False)
    svc3.drain()
    s3 = svc3.stats()
    assert s3["coalesced"] == 2 and s3["executed"] == 1


def test_lru_eviction_closes_session_and_readmits(graphs, bf):
    a, b, _ = graphs
    svc = CliqueService(max_sessions=1)
    assert svc.submit(a, CountRequest(k=3)).result().cache["session"] == \
        "miss"
    held = svc.pool.peek(graph_fingerprint(a))
    assert held is not None and not held.closed
    svc.submit(b, CountRequest(k=3)).result()           # evicts a
    assert held.closed                                  # device refs dropped
    with pytest.raises(RuntimeError):
        held.submit(CountRequest(k=3))
    # eviction also drops the graph registry entry (bounded host memory):
    # a bare fingerprint ref no longer resolves, the Graph object does
    with pytest.raises(KeyError):
        svc.submit(graph_fingerprint(a), CountRequest(k=3))
    rep = svc.submit(a, CountRequest(k=3)).result()     # re-admitted
    assert rep.count == bf[a.name][3]
    assert rep.cache["session"] == "miss"
    stats = svc.stats()
    assert stats["registered_graphs"] <= 2
    pool = stats["pool"]
    assert pool["evictions"] == 2 and pool["live"] == 1
    assert pool["queries"] == 3                         # retired stats kept


def test_batch_grouping_reuses_session_caches(graphs):
    g = graphs[2]
    svc = CliqueService(max_sessions=2)
    svc.submit_many([(g, CountRequest(k=4)),
                     (g, CountRequest(k=4, method="color", colors=3)),
                     (g, CountRequest(k=4, method="color", colors=5))])
    svc.drain()
    eng = svc.pool.peek(graph_fingerprint(g))
    st = eng.session_stats()
    assert st["plans"]["hits"] >= 2        # one k=4 plan served all three
    assert st["executables"]["hits"] >= 1  # colors traced, exec reused


def test_per_job_error_isolation(graphs, bf, monkeypatch):
    """An execution-time failure fails only its own job's tickets; the
    rest of the batch still runs on the same session."""
    g = graphs[0]
    svc = CliqueService()
    orig = CliqueEngine.submit

    def flaky(self, req):
        if req.k == 5:
            raise RuntimeError("boom")
        return orig(self, req)

    monkeypatch.setattr(CliqueEngine, "submit", flaky)
    bad = svc.submit(g, CountRequest(k=5))
    good = svc.submit(g, CountRequest(k=4))
    svc.drain()
    assert bad.done() and good.done()
    with pytest.raises(RuntimeError, match="boom"):
        bad.result()
    assert good.result().count == bf[g.name][4]
    stats = svc.stats()
    assert stats["failed"] == 1 and stats["executed"] == 1
    # invalid requests never enqueue: rejected at submit time
    with pytest.raises(ValueError):
        svc.submit(g, CountRequest(k=4, method="ni++"))


def test_unknown_graph_ref_and_eager_validation(graphs):
    svc = CliqueService()
    with pytest.raises(KeyError):
        svc.submit("deadbeef00000000", CountRequest(k=3))
    with pytest.raises(ValueError):
        svc.submit(graphs[0], CountRequest(k=3, backend="shard_map",
                                           return_per_node=True))
    ref = svc.register(graphs[0])
    assert svc.submit(ref, CountRequest(k=3)).result().count >= 0


def test_background_worker_and_threaded_submitters(graphs, bf):
    g = graphs[0]
    svc = CliqueService(max_sessions=2).start()
    results = {}

    def user(i):
        t = svc.submit(g, CountRequest(k=4))
        results[i] = t.result(timeout=120).count

    threads = [threading.Thread(target=user, args=(i,)) for i in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    svc.stop(close_pool=True)
    assert set(results.values()) == {bf[g.name][4]}
    stats = svc.stats()
    assert stats["submitted"] == 6 and stats["failed"] == 0
    assert stats["executed"] + stats["coalesced"] == 6
    assert stats["pool"]["live"] == 0                   # closed on stop


def test_pool_rejects_bad_capacity():
    with pytest.raises(ValueError):
        EnginePool(0)


def test_pool_standalone_get_evict(graphs):
    """EnginePool.get/evict/__contains__ — the single-user convenience
    API (the service drives lookup/build/admit itself, under its lock)."""
    a, b, _ = graphs
    pool = EnginePool(1)
    fa, fb = graph_fingerprint(a), graph_fingerprint(b)
    e1, resident = pool.get(a)
    assert not resident and fa in pool and len(pool) == 1
    e2, resident = pool.get(a, fa)
    assert resident and e2 is e1
    e3, _ = pool.get(b)                      # evicts + closes a's session
    assert fb in pool and fa not in pool
    assert e1.closed and not e3.closed
    assert pool.evict(fb) and not pool.evict(fb)
    assert e3.closed and len(pool) == 0
    assert pool.stats()["evictions"] == 2
    assert pool.stats()["queries"] == 0      # retired telemetry folded
