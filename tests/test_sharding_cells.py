"""Sharding rules + cell construction + multi-device lowering (subprocess)."""
import pytest

from conftest import run_with_devices

from repro.configs import get_config
from repro.configs.base import ParallelConfig


def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_spec
    cfg = get_config("yi-6b")

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    par = ParallelConfig()
    mesh = FakeMesh()
    # ffn weight: d_ff goes to model, d_model to data
    s = param_spec("layers/mlp/w_gate", (32, 4096, 11008), cfg, mesh, par)
    assert s == P(None, "data", "model")
    # stacked per-layer vectors: never shard the layer dim; the feature
    # dim may take the fsdp axis (ZeRO-style) but not tp
    s = param_spec("layers/ln1/scale", (32, 4096), cfg, mesh, par)
    assert s[0] is None and "model" not in tuple(s)
    # embedding: vocab on model
    s = param_spec("embed", (cfg.padded_vocab, 4096), cfg, mesh, par)
    assert s == P("model", "data")


def test_moe_expert_sharding():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import param_spec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # deepseek: 64 experts divide the 16-way tp axis → experts on model
    dcfg = get_config("deepseek-v2-lite-16b")
    s = param_spec("layers/moe/w_gate", (27, 64, 2048, 1408), dcfg,
                   FakeMesh(), ParallelConfig())
    assert s[1] == "model"
    # mixtral: 8 experts don't divide 16 → falls through to dim rules;
    # the spec must still be constructible and shard something
    mcfg = get_config("mixtral-8x7b")
    s = param_spec("layers/moe/w_gate", (32, 8, 4096, 14336), mcfg,
                   FakeMesh(), ParallelConfig())
    assert any(a is not None for a in s)


def test_input_specs_shapes():
    from repro.launch.cells import input_specs
    sp = input_specs("yi-6b", "train_4k")
    assert sp["batch"]["tokens"].shape == (256, 4096)
    sp = input_specs("yi-6b", "decode_32k")
    assert sp["token"].shape == (128,)
    cache = sp["cache"]
    assert cache["k"].shape == (32, 128, 32768, 4, 128)
    sp = input_specs("mixtral-8x7b", "long_500k")
    assert sp["cache"]["k"].shape[2] == 4096  # SWA ring, not 524288
    sp = input_specs("mamba2-370m", "long_500k")
    assert "state" in sp["cache"] and "k" not in sp["cache"]
    sp = input_specs("whisper-small", "prefill_32k")
    assert sp["batch"]["frames"].shape == (32, 1500, 768)


@pytest.mark.slow
def test_lower_and_compile_small_mesh_train_and_decode():
    """End-to-end cell lowering on an 8-device mesh (smoke of the
    dry-run machinery without 512 devices)."""
    run_with_devices("""
import jax
from repro.launch.mesh import make_mesh
from repro.launch.cells import build_cell, lower_cell
from repro.configs.base import ParallelConfig
from repro.launch.hlo_analysis import analyze_hlo
mesh = make_mesh((4, 2), ("data", "model"))
par = ParallelConfig(dp_axes=("data",))
for arch, shape in [("tinyllama-1.1b", "train_4k"),
                    ("deepseek-v2-lite-16b", "decode_32k"),
                    ("mamba2-370m", "long_500k"),
                    ("whisper-small", "prefill_32k")]:
    cell = build_cell(arch, shape, mesh, par)
    compiled = lower_cell(cell).compile()
    st = analyze_hlo(compiled.as_text())
    assert st.dot_flops > 0, (arch, shape)
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    print(arch, shape, "ok")
print("OK")
""", n_devices=8, timeout=900)


@pytest.mark.slow
def test_elastic_checkpoint_restore_across_meshes():
    """Save sharded state on a (4,2) mesh, restore onto (2,2) — the
    elastic-rescale path end to end."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp, tempfile
from repro.launch.mesh import make_mesh
from repro.configs import get_smoke_config
from repro.models import init_params, abstract_params
from repro.distributed.sharding import param_shardings
from repro.configs.base import ParallelConfig
from repro.checkpoint.manager import CheckpointManager
cfg = get_smoke_config("yi-6b")
par = ParallelConfig(dp_axes=("data",))
params = init_params(cfg, jax.random.PRNGKey(0))
mesh1 = make_mesh((4, 2), ("data", "model"))
sh1 = param_shardings(abstract_params(cfg), cfg, mesh1, par)
p1 = jax.tree.map(jax.device_put, params, sh1)
with tempfile.TemporaryDirectory() as d:
    m = CheckpointManager(d, async_save=False)
    m.save(1, p1)
    mesh2 = make_mesh((2, 2), ("data", "model"))
    sh2 = param_shardings(abstract_params(cfg), cfg, mesh2, par)
    p2, _ = m.restore(params, shardings=sh2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
""", n_devices=8, timeout=600)


@pytest.mark.xfail(
    strict=False,
    reason="jax 0.4.x SPMD partitioner emits HLO whose dot shapes "
           "analyze_hlo misparses (dot_flops off by ~1000x); passes on "
           "newer jax")
def test_hlo_analysis_on_multidevice_module():
    run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
L, D, F, B = 6, 256, 512, 32
def f(w1, w2, x):
    def body(c, ws):
        a, b = ws
        return c + jax.nn.relu(c @ a) @ b, ()
    y, _ = jax.lax.scan(body, x, (w1, w2))
    return jnp.mean(y ** 2)
args = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
        jax.ShapeDtypeStruct((L, F, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32))
sh = (NamedSharding(mesh, P(None, "data", "model")),
      NamedSharding(mesh, P(None, "model", "data")),
      NamedSharding(mesh, P("data", None)))
c = jax.jit(f, in_shardings=sh).lower(*args).compile()
st = analyze_hlo(c.as_text())
logical = L * 2 * 2 * B * D * F
assert abs(st.dot_flops - logical / 8) / (logical / 8) < 0.01, st.dot_flops
assert st.total_collective_bytes > 0
print("OK")
""", n_devices=8)
