"""Roofline math + MODEL_FLOPS formulas + dry-run record integrity."""
import glob
import json
import os

import pytest

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import HLOStats, analyze_hlo
from repro.launch.roofline import (compute_roofline, hbm_bytes_per_device,
                                   model_flops)

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def test_model_flops_train_matches_6nd():
    cfg = get_config("tinyllama-1.1b")
    shape = SHAPES["train_4k"]
    mf = model_flops(cfg, shape)
    dense_only = 6.0 * cfg.active_param_count() * shape.tokens
    assert mf >= dense_only
    assert mf < 1.5 * dense_only  # attention adds <50% at 4k


def test_model_flops_moe_uses_active_params():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert mf < 6.0 * cfg.param_count() * SHAPES["train_4k"].tokens


def test_decode_flops_scale_with_cache():
    cfg = get_config("yi-6b")
    f32k = model_flops(cfg, SHAPES["decode_32k"])
    # decode flops dominated by params at batch 128; attention grows
    assert f32k > 2.0 * cfg.param_count() * 128


def test_swa_caps_attention_flops():
    mix = get_config("mixtral-8x7b")
    full = model_flops(mix, SHAPES["prefill_32k"])
    import dataclasses
    nowin = dataclasses.replace(mix, sliding_window=0)
    assert model_flops(nowin, SHAPES["prefill_32k"]) > full


def test_roofline_terms_and_bottleneck():
    cfg = get_config("tinyllama-1.1b")
    hlo = HLOStats(dot_flops=1e15)
    hlo.collective_bytes["all-gather"] = 1e12
    r = compute_roofline("a", "train_4k", "m", cfg, SHAPES["train_4k"],
                         256, hlo)
    assert r.compute_s == pytest.approx(1e15 / 197e12)
    assert r.collective_s == pytest.approx(1e12 / 50e9)
    assert r.bottleneck == "collective"
    assert 0 <= r.roofline_fraction <= 1


def test_hbm_bytes_reasonable():
    cfg = get_config("command-r-35b")
    train = hbm_bytes_per_device(cfg, SHAPES["train_4k"], 256)
    dec = hbm_bytes_per_device(cfg, SHAPES["decode_32k"], 256)
    # train touches optimizer state; decode touches cache + weights once
    assert train > 24.0 * cfg.param_count() / 256
    assert dec > 4.0 * cfg.param_count() / 256


def test_analyze_hlo_tolerates_garbage():
    st = analyze_hlo("HloModule nothing\nENTRY %e () -> f32[] {\n}\n")
    assert st.dot_flops == 0


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN, "*.json")),
                    reason="dry-run artifacts not generated yet")
def test_dryrun_records_complete_and_fit():
    """Every runnable (arch × shape × mesh) has an ok record; every ok
    record fits the 16 GB budget (the §Dry-run deliverable)."""
    recs = [json.load(open(f))
            for f in glob.glob(os.path.join(DRYRUN, "*.json"))]
    by_status = {}
    for r in recs:
        by_status.setdefault(
            r.get("status", "skip" if not r["runnable"] else "?"),
            []).append(r)
    assert not by_status.get("error"), [
        (r["arch"], r["shape"]) for r in by_status.get("error", [])]
    ok = by_status.get("ok", [])
    if len(recs) >= 80:  # full sweep present
        assert len(ok) == 66           # 33 runnable cells × 2 meshes
        skips = [r for r in recs if not r["runnable"]]
        assert len(skips) == 14        # 7 full-attn long_500k × 2
    for r in ok:
        assert r["hlo"]["dot_flops"] > 0, (r["arch"], r["shape"])
        assert r["roofline"]["bottleneck"] in ("compute", "memory",
                                               "collective")
