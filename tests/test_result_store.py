"""CountReport JSON round-trip + the persistent ResultStore.

The round-trip contract is *bit-exactness* of every answer-bearing
field — ``estimate``/``count``, ``per_node`` (float64), ``profile``
(int64), ``cliques`` (int32), the CI fields — across save→load for
every method family. The store contract is the ledger's: atomic
writes, tolerant reads (corruption is a miss, never a crash), and
content addressing that keeps two graphs' answers to the same request
apart.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import clique_count_bruteforce
from repro.engine import (CliqueEngine, CountRequest, graph_fingerprint,
                          report_from_json, report_to_json)
from repro.graphs import barabasi_albert, erdos_renyi
from repro.serving.store import ResultStore, result_key


@pytest.fixture(scope="module")
def graphs():
    return (erdos_renyi(40, 0.25, seed=1),
            barabasi_albert(80, 5, seed=2))


@pytest.fixture(scope="module")
def engines(graphs):
    return tuple(CliqueEngine(g) for g in graphs)


def _roundtrip(report):
    # through actual JSON text, not just the dict: the store writes text
    return report_from_json(json.loads(json.dumps(report_to_json(report))))


def _assert_bit_exact(back, rep):
    assert back.estimate == rep.estimate          # float64 repr round-trip
    assert back.count == rep.count
    assert back.k == rep.k and back.method == rep.method
    assert back.backend == rep.backend
    assert back.mrc == rep.mrc                    # frozen scalar dataclass
    assert back.n_workers == rep.n_workers
    assert back.ci_low == rep.ci_low and back.ci_high == rep.ci_high
    assert back.achieved_rel_error == rep.achieved_rel_error
    assert back.escalations == rep.escalations
    for name in ("per_node", "profile", "cliques"):
        a, b = getattr(back, name), getattr(rep, name)
        if b is None:
            assert a is None
        else:
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(a, b)


# ---------------- round-trip, every method family ----------------

def test_roundtrip_exact(engines):
    rep = engines[0].submit(CountRequest(k=4))
    _assert_bit_exact(_roundtrip(rep), rep)


def test_roundtrip_per_node(engines):
    rep = engines[0].submit(CountRequest(k=3, return_per_node=True))
    assert rep.per_node is not None and rep.per_node.dtype == np.float64
    _assert_bit_exact(_roundtrip(rep), rep)


def test_roundtrip_sampled(engines):
    rep = engines[1].submit(CountRequest(k=3, method="color", colors=3,
                                         seed=7))
    _assert_bit_exact(_roundtrip(rep), rep)
    rep = engines[1].submit(CountRequest(k=3, method="edge", p=0.5,
                                         seed=7))
    _assert_bit_exact(_roundtrip(rep), rep)


def test_roundtrip_adaptive_ci_fields(engines):
    rep = engines[1].submit(CountRequest(k=4, method="auto",
                                         rel_error=0.5, seed=3))
    assert rep.ci_low is not None and rep.ci_high is not None
    back = _roundtrip(rep)
    _assert_bit_exact(back, rep)
    assert back.estimator["resolved"] == rep.estimator["resolved"]


def test_roundtrip_allk_profile(engines):
    rep = engines[0].submit(CountRequest(k="all"))
    assert rep.profile is not None and rep.profile.dtype == np.int64
    back = _roundtrip(rep)
    _assert_bit_exact(back, rep)
    assert back.k == "all"


def test_roundtrip_listing(engines):
    rep = engines[0].submit(CountRequest(k=3, mode="list"))
    assert rep.cliques is not None and rep.cliques.dtype == np.int32
    back = _roundtrip(rep)
    _assert_bit_exact(back, rep)
    assert back.listing == rep.listing


def test_from_json_rejects_foreign_schema(engines):
    obj = report_to_json(engines[0].submit(CountRequest(k=3)))
    obj["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        report_from_json(obj)


# ---------------- persistability / key stability ----------------

def test_predicate_listing_is_not_persistable():
    plain = CountRequest(k=3, mode="list")
    pred = CountRequest(k=3, mode="list",
                        predicate=lambda rows: rows[:, 0] >= 0)
    assert plain.is_persistable and not pred.is_persistable
    with pytest.raises(ValueError, match="persistable"):
        result_key(pred)


def test_result_key_is_process_stable():
    """The durable address must not depend on anything process-local:
    equal requests (fresh objects) → equal keys, and exact requests
    normalize seeds away just like coalescing does."""
    assert result_key(CountRequest(k=4, seed=1)) == \
        result_key(CountRequest(k=4, seed=2))
    assert result_key(CountRequest(k=4)) != result_key(CountRequest(k=5))
    assert result_key(CountRequest(k=4, method="color", seed=1)) != \
        result_key(CountRequest(k=4, method="color", seed=2))


# ---------------- the store ----------------

def test_store_roundtrip_and_counters(tmp_path, engines, graphs):
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graphs[0])
    req = CountRequest(k=4)
    assert store.get(fp, req) is None             # cold miss
    rep = engines[0].submit(req)
    assert store.put(fp, req, rep)
    back = store.get(fp, req)
    _assert_bit_exact(back, rep)
    s = store.stats()
    assert s["entries"] == 1 and s["hits"] == 1 and s["misses"] == 1
    # a fresh store over the same directory warms its index from disk
    again = ResultStore(str(tmp_path))
    _assert_bit_exact(again.get(fp, req), rep)


def test_store_key_collision_two_graphs_same_request(tmp_path, engines,
                                                     graphs):
    """Same request, different graphs: entries must not collide — each
    graph gets its own (different) answer back."""
    store = ResultStore(str(tmp_path))
    req = CountRequest(k=3)
    fps = [graph_fingerprint(g) for g in graphs]
    reps = [eng.submit(req) for eng in engines]
    assert reps[0].count != reps[1].count         # the collision would show
    for fp, rep in zip(fps, reps):
        store.put(fp, req, rep)
    for g, fp in zip(graphs, fps):
        assert store.get(fp, req).count == \
            clique_count_bruteforce(g, 3)


def test_store_tolerates_corrupt_entries(tmp_path, engines, graphs):
    """The ledger's torn-tail discipline: a corrupt entry is a miss (and
    is dropped), never an exception — and the store recovers on the
    next put."""
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graphs[0])
    req = CountRequest(k=4)
    rep = engines[0].submit(req)
    store.put(fp, req, rep)
    path = store._index[(fp, result_key(req))]
    for garbage in ('{"schema": 1, "truncated',       # torn write
                    '{"schema": 1, "fingerprint": "f", '
                    '"query_key": "q", "report": {}}',  # foreign/missing
                    ""):                               # empty file
        store.put(fp, req, rep)
        with open(path, "w") as f:
            f.write(garbage)
        assert store.get(fp, req) is None
        assert not os.path.exists(path)           # distrusted → dropped
    assert store.stats()["corrupt"] == 3
    store.put(fp, req, rep)
    _assert_bit_exact(store.get(fp, req), rep)


def test_store_eviction_oldest_first(tmp_path, engines, graphs):
    store = ResultStore(str(tmp_path), max_entries=2)
    fp = graph_fingerprint(graphs[0])
    reqs = [CountRequest(k=k) for k in (3, 4, 5)]
    reps = [engines[0].submit(r) for r in reqs]
    for i, (req, rep) in enumerate(zip(reqs, reps)):
        store.put(fp, req, rep)
        os.utime(store._index[(fp, result_key(req))], (i, i))
    assert len(store) == 2 and store.stats()["evictions"] == 1
    assert store.get(fp, reqs[0]) is None         # oldest evicted
    assert store.get(fp, reqs[2]).count == reps[2].count


def test_store_skips_unpersistable(tmp_path, engines, graphs):
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graphs[0])
    req = CountRequest(k=3, mode="list",
                       predicate=lambda rows: rows[:, 0] >= 0)
    rep = engines[0].submit(req)
    assert not store.put(fp, req, rep)
    assert store.get(fp, req) is None
    s = store.stats()
    assert s["entries"] == 0 and s["misses"] == 0  # not even counted


def test_store_graph_persistence(tmp_path, graphs):
    store = ResultStore(str(tmp_path))
    for g in graphs:
        store.save_graph(graph_fingerprint(g), g)
    loaded = dict(ResultStore(str(tmp_path)).load_graphs())
    assert set(loaded) == {graph_fingerprint(g) for g in graphs}
    for g in graphs:
        back = loaded[graph_fingerprint(g)]
        assert graph_fingerprint(back) == graph_fingerprint(g)


def test_store_rejects_bad_capacity(tmp_path):
    with pytest.raises(ValueError):
        ResultStore(str(tmp_path), max_entries=0)


def test_stored_sampled_reports_keep_their_seeded_estimate(tmp_path,
                                                           engines,
                                                           graphs):
    """Sampled entries are seed-specific (their keys carry the seed):
    two seeds → two entries, each returning its own estimate."""
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graphs[1])
    reqs = [CountRequest(k=3, method="color", colors=3, seed=s)
            for s in (1, 2)]
    reps = [engines[1].submit(r) for r in reqs]
    for req, rep in zip(reqs, reps):
        store.put(fp, req, rep)
    assert len(store) == 2
    for req, rep in zip(reqs, reps):
        assert store.get(fp, req).estimate == rep.estimate


def test_replace_refreshes_not_duplicates(tmp_path, engines, graphs):
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graphs[0])
    req = CountRequest(k=4)
    rep = engines[0].submit(req)
    store.put(fp, req, rep)
    store.put(fp, req, dataclasses.replace(rep))
    assert len(store) == 1
