"""Exact SI_k vs independent oracles."""
import numpy as np
import pytest

from repro.core import (check_lemma1, clique_count_bruteforce,
                        complete_graph_cliques, count_cliques,
                        build_oriented, triangle_count_matrix)
from repro.graphs import (barabasi_albert, complete_graph, empty_graph,
                          erdos_renyi, planted_cliques, relabel,
                          random_graph_for_tests)


@pytest.mark.parametrize("k", [3, 4, 5])
@pytest.mark.parametrize("n", [5, 9, 16])
def test_complete_graphs(n, k):
    res = count_cliques(complete_graph(n), k)
    assert res.count == complete_graph_cliques(n, k)


@pytest.mark.parametrize("k", [3, 4, 5, 6])
def test_er_vs_bruteforce(k):
    g = erdos_renyi(36, 0.35, seed=k)
    assert count_cliques(g, k).count == clique_count_bruteforce(g, k)


@pytest.mark.parametrize("seed", range(6))
def test_random_graphs_all_k(seed):
    g = random_graph_for_tests(seed)
    for k in (3, 4, 5):
        assert count_cliques(g, k).count == clique_count_bruteforce(g, k)


def test_triangles_match_matrix_oracle():
    g = barabasi_albert(250, 7, seed=3)
    assert count_cliques(g, 3).count == triangle_count_matrix(g)


def test_per_node_attribution_matches_bruteforce():
    g = erdos_renyi(40, 0.4, seed=11)
    for k in (3, 4, 5):
        res = count_cliques(g, k, return_per_node=True)
        _, pn = clique_count_bruteforce(g, k, return_per_node=True)
        np.testing.assert_array_equal(
            np.round(res.per_node).astype(np.int64), pn)


def test_empty_and_tiny():
    assert count_cliques(empty_graph(10), 3).count == 0
    g = erdos_renyi(4, 0.0, seed=0)
    assert count_cliques(g, 3).count == 0


def test_planted_cliques_dominate():
    g = planted_cliques(100, 0.02, [10, 8], seed=5)
    # background too sparse for 6-cliques: counts come from plants only
    assert count_cliques(g, 6).count == clique_count_bruteforce(g, 6)
    from math import comb
    assert count_cliques(g, 8).count >= comb(10, 8)


def test_relabel_invariance():
    g = erdos_renyi(30, 0.4, seed=2)
    rng = np.random.default_rng(0)
    g2 = relabel(g, rng.permutation(g.n))
    for k in (3, 4, 5):
        assert count_cliques(g, k).count == count_cliques(g2, k).count


def test_lemma1_bound_holds():
    for seed in range(4):
        g = barabasi_albert(300, 9, seed=seed)
        og = build_oriented(g)
        assert check_lemma1(g, og.out_deg)
        assert og.out_deg.max() <= 2 * np.sqrt(g.m)


def test_ni_plus_plus_matches_exact():
    g = barabasi_albert(200, 6, seed=1)
    exact = count_cliques(g, 3)
    nipp = count_cliques(g, 3, method="ni++")
    assert nipp.count == exact.count
    assert nipp.mrc.rounds == 2 and exact.mrc.rounds == 3


def test_pallas_engine_matches_jnp_engine():
    g = erdos_renyi(50, 0.3, seed=9)
    for k in (3, 4):
        a = count_cliques(g, k, engine="jnp").count
        b = count_cliques(g, k, engine="pallas").count
        assert a == b
