"""Distributed clique engine: multi-worker equality, split round,
balance, elastic worker counts. Multi-device cases run in subprocesses
with fake host devices (the main process must keep 1 device)."""
import numpy as np
import pytest

from conftest import run_with_devices

from repro.core import clique_count_bruteforce, count_cliques
from repro.core.distributed import count_cliques_distributed
from repro.core.plan import balance_report, build_plan, partition_for_workers
from repro.core.split import split_cost_model
from repro.core import build_oriented
from repro.graphs import barabasi_albert, erdos_renyi


def test_single_device_distributed_matches_exact():
    g = erdos_renyi(70, 0.25, seed=1)
    for k in (3, 4, 5):
        assert count_cliques_distributed(g, k).count == \
            clique_count_bruteforce(g, k)


def test_split_round_exactness_and_cost_model():
    g = barabasi_albert(250, 9, seed=2)
    bf = clique_count_bruteforce(g, 4)
    og = build_oriented(g)
    # pick a threshold that provably splits something (p90 of out-degs)
    thr = int(np.percentile(og.out_deg[og.out_deg >= 3], 90))
    res = count_cliques_distributed(g, 4, split_threshold=thr)
    assert res.count == bf
    cm = split_cost_model(og, 4, thr)
    assert cm["n_heavy"] > 0
    assert cm["split_max_unit_cost"] <= cm["base_max_unit_cost"]
    assert cm["speedup_bound"] >= 1.0


def test_partition_is_balanced_and_covers_all_nodes():
    g = barabasi_albert(400, 10, seed=3)
    og = build_oriented(g)
    plan = build_plan(og, 4)
    for w in (2, 4, 8):
        plans = partition_for_workers(plan, og, w)
        nodes = np.concatenate(
            [b.nodes[b.nodes >= 0] for p in plans for b in p.buckets])
        expect = np.concatenate(
            [b.nodes[b.nodes >= 0] for b in plan.buckets])
        assert sorted(nodes.tolist()) == sorted(expect.tolist())
        rep = balance_report(plan, og, w)
        assert rep["imbalance"] < 1.35, rep


def test_sampling_invariant_to_worker_count():
    """RNG keyed by node id ⇒ the estimate is identical for any W."""
    g = barabasi_albert(300, 8, seed=9)
    a = count_cliques(g, 4, method="color", colors=3, seed=5).estimate
    b = count_cliques_distributed(
        g, 4, method="color", colors=3, seed=5).estimate
    assert abs(a - b) <= 1e-3 * max(abs(a), 1.0)


@pytest.mark.slow
def test_eight_workers_exact_and_elastic():
    run_with_devices("""
from repro.graphs import barabasi_albert
from repro.core.distributed import count_cliques_distributed
from repro.core import clique_count_bruteforce
import jax, numpy as np
g = barabasi_albert(300, 8, seed=9)
bf = clique_count_bruteforce(g, 4)
full = count_cliques_distributed(g, 4)
assert full.n_workers == 8 and full.count == bf, (full.count, bf)
# elastic: 4-device sub-mesh of the same host
mesh4 = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("workers",))
sub = count_cliques_distributed(g, 4, mesh=mesh4)
assert sub.n_workers == 4 and sub.count == bf
# split round on 8 workers
s = count_cliques_distributed(g, 4, split_threshold=16)
assert s.count == bf
# sampling identical on 8 workers vs 4
e8 = count_cliques_distributed(g, 4, method="color", colors=3, seed=5)
e4 = count_cliques_distributed(g, 4, method="color", colors=3, seed=5,
                               mesh=mesh4)
assert abs(e8.estimate - e4.estimate) < 1e-3 * abs(e8.estimate or 1)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_degree_computation_distributed():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.graphs import erdos_renyi
from repro.graphs.degree import degrees_sharded, degrees_from_edges
g = erdos_renyi(100, 0.2, seed=0)
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("w",))
m = g.edges.shape[0]
pad = (-m) % 8
edges = np.concatenate([g.edges, np.full((pad, 2), -1)], 0).astype(np.int32)
fn = jax.jit(shard_map(
    lambda e: degrees_sharded(e, 100, "w"), mesh=mesh,
    in_specs=(P("w", None),), out_specs=P()))
got = np.asarray(fn(jnp.asarray(edges)))[:100]
want = np.asarray(degrees_from_edges(jnp.asarray(g.edges), 100))
assert (got == want).all()
print("OK")
""", n_devices=8)
