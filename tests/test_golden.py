"""Golden-count regression: the engine must reproduce the checked-in
exact counts for the seeded generator corpus.

The fixture (tests/fixtures/golden_counts.json, regenerated only by
scripts/regen_golden.py) pins both the corpus graphs (n, m per seeded
generator) and their exact q_3..q_5 — so a backend or planner refactor
that silently shifts results, or a generator change that silently
reshapes the corpus, fails here even if all backends still agree with
each other.
"""
import json
import os

import pytest

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import conformance_corpus

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "fixtures", "golden_counts.json")


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def test_corpus_matches_golden_shapes(golden):
    corpus = conformance_corpus()
    assert sorted(g.name for g in corpus) == sorted(golden), \
        "corpus changed: rerun scripts/regen_golden.py deliberately"
    for g in corpus:
        assert (g.n, g.m) == (golden[g.name]["n"], golden[g.name]["m"]), \
            f"{g.name}: generator output drifted for pinned seed"


def test_engine_counts_match_golden(golden):
    for g in conformance_corpus():
        eng = CliqueEngine(g)
        for k_str, expected in golden[g.name]["counts"].items():
            rep = eng.submit(CountRequest(k=int(k_str)))
            assert rep.count == expected, (g.name, k_str)
