"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the brief: sweep shapes/dtypes per kernel and assert_allclose
against the ref.py oracle.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.bitset import (pack_rows, triangles_bitset,
                                  triangles_bitset_ref)
from repro.kernels.cliques import (dag_count_pallas, dag_count_ref,
                                   kernel_flops)


def _random_dag(rng, B, D, density, dtype=np.float32):
    A = (rng.random((B, D, D)) < density).astype(dtype)
    return np.triu(A, 1)


@pytest.mark.parametrize("D", [8, 16, 64, 128])
@pytest.mark.parametrize("B", [1, 5, 16])
@pytest.mark.parametrize("r", [2, 3, 4])
def test_cliques_kernel_shape_sweep(D, B, r):
    rng = np.random.default_rng(D * 1000 + B * 10 + r)
    A = jnp.asarray(_random_dag(rng, B, D, 0.3))
    got = dag_count_pallas(A, r)
    want = dag_count_ref(A, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


@pytest.mark.parametrize("r", [3, 4, 5])
def test_cliques_kernel_matches_bruteforce_semantics(r):
    """Counts on K_D must be C(D, r)."""
    import math
    D = 10
    A = jnp.asarray(np.triu(np.ones((2, D, D), np.float32), 1))
    got = np.asarray(dag_count_pallas(A, r))
    assert got[0] == got[1] == math.comb(D, r)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cliques_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    A = jnp.asarray(_random_dag(rng, 4, 32, 0.3)).astype(dtype)
    got = dag_count_pallas(A.astype(jnp.float32), 3)
    want = dag_count_ref(jnp.asarray(np.asarray(A, np.float32)), 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)


def test_cliques_kernel_nonmultiple_batch_padding():
    rng = np.random.default_rng(7)
    A = jnp.asarray(_random_dag(rng, 7, 16, 0.4))   # B=7 not pow2
    np.testing.assert_allclose(np.asarray(dag_count_pallas(A, 3)),
                               np.asarray(dag_count_ref(A, 3)))


@pytest.mark.parametrize("D", [8, 32, 64, 96])
@pytest.mark.parametrize("B", [1, 6])
def test_bitset_kernel_sweep(D, B):
    rng = np.random.default_rng(D + B)
    A = jnp.asarray(_random_dag(rng, B, D, 0.35))
    got = triangles_bitset(A)
    want = triangles_bitset_ref(A)
    tri = dag_count_ref(A, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got), np.asarray(tri))


def test_pack_rows_roundtrip():
    rng = np.random.default_rng(3)
    A = jnp.asarray(_random_dag(rng, 2, 40, 0.5))   # D=40: ragged word
    bits = pack_rows(A)
    assert bits.shape == (2, 40, 2)
    # popcount of all rows == number of ones in A
    pc = jax.lax.population_count(bits).sum()
    assert int(pc) == int(A.sum())


def test_kernel_flops_monotone():
    assert kernel_flops(8, 64, 4) > kernel_flops(8, 64, 3)
    assert kernel_flops(8, 128, 3) > kernel_flops(8, 64, 3)
