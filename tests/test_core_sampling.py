"""Sampling estimators: unbiasedness, determinism, accuracy trends."""
import numpy as np
import pytest

from repro.core import count_cliques
from repro.core.mrc import theorem2_min_p, theorem3_max_colors
from repro.graphs import barabasi_albert, complete_graph


@pytest.fixture(scope="module")
def ba_graph():
    return barabasi_albert(400, 10, seed=5)


@pytest.fixture(scope="module")
def exact_counts(ba_graph):
    return {k: count_cliques(ba_graph, k).count for k in (3, 4)}


@pytest.mark.parametrize("method,kw", [
    ("edge", {"p": 0.5}), ("color", {"colors": 2}),
    ("color_smooth", {"colors": 2})])
def test_estimator_unbiased_k3(ba_graph, exact_counts, method, kw):
    ests = [count_cliques(ba_graph, 3, method=method, seed=s, **kw).estimate
            for s in range(12)]
    mean = float(np.mean(ests))
    exact = exact_counts[3]
    # CV at p=0.5 here is ~2%; 12 seeds → ±3σ ≈ 2%
    assert abs(mean - exact) / exact < 0.05, (mean, exact)


def test_estimator_deterministic_per_seed(ba_graph):
    a = count_cliques(ba_graph, 4, method="color", colors=3, seed=7)
    b = count_cliques(ba_graph, 4, method="color", colors=3, seed=7)
    assert a.estimate == b.estimate
    c = count_cliques(ba_graph, 4, method="color", colors=3, seed=8)
    assert a.estimate != c.estimate  # different seed, different sample


def test_sampling_probability_one_is_exact(ba_graph, exact_counts):
    res = count_cliques(ba_graph, 3, method="edge", p=1.0)
    assert res.count == exact_counts[3]
    res = count_cliques(ba_graph, 4, method="color", colors=1)
    assert res.count == exact_counts[4]


def test_color_beats_edge_at_equal_rate():
    """Paper §4 Discussion: at equal pair-sampling rate (p = 1/c), color
    sampling keeps far more cliques for k ≥ 4, hence lower variance."""
    g = barabasi_albert(500, 12, seed=3)
    exact = count_cliques(g, 4).count
    edge = [count_cliques(g, 4, method="edge", p=1 / 3, seed=s).estimate
            for s in range(10)]
    col = [count_cliques(g, 4, method="color", colors=3, seed=s).estimate
           for s in range(10)]
    rmse_e = np.sqrt(np.mean((np.array(edge) - exact) ** 2)) / exact
    rmse_c = np.sqrt(np.mean((np.array(col) - exact) ** 2)) / exact
    assert rmse_c < rmse_e, (rmse_c, rmse_e)


def test_complete_graph_estimates():
    g = complete_graph(24)
    exact = count_cliques(g, 5).count
    ests = [count_cliques(g, 5, method="color", colors=2, seed=s).estimate
            for s in range(20)]
    assert abs(np.mean(ests) - exact) / exact < 0.3


def test_theorem_parameter_helpers():
    p = theorem2_min_p(m=10000, qk=1e6, k=4, eps=0.1)
    assert 0 < p <= 1.0
    c = theorem3_max_colors(m=10000, qk=1e6, k=4, eps=0.1)
    assert c >= 1
    # more cliques → can sample more aggressively
    assert theorem2_min_p(10000, 1e8, 4) <= theorem2_min_p(10000, 1e5, 4)
    assert theorem3_max_colors(10000, 1e8, 4) >= \
        theorem3_max_colors(10000, 1e5, 4)


def test_mrc_volume_reduction_under_sampling(ba_graph):
    ex = count_cliques(ba_graph, 4).mrc
    sm = count_cliques(ba_graph, 4, method="color", colors=10).mrc
    assert sm.round3_pairs < ex.round3_pairs
    assert sm.sample_factor == pytest.approx(0.1)
