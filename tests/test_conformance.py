"""Cross-backend conformance: every execution backend must agree with
the brute-force oracle — exactly — on the whole generator corpus.

One engine session per corpus graph answers the same exact query on the
``local``, ``pallas``, ``shard_map``, and ``ooc`` (out-of-core
scheduler) backends; counts must match the oracle and per-node
attributions (local/pallas/ooc) must match the oracle's ≺-minimum
responsibility assignment bit-for-bit. This is the trust anchor under
the serving layer: a backend refactor that shifts any count on any
corpus graph fails here before it can ship.
"""
import numpy as np
import pytest

from repro.core import clique_count_bruteforce
from repro.engine import BACKENDS, CliqueEngine, CountRequest
from repro.estimator import (ColorCoding, EdgeSample, Sparsify,
                             WedgeSample)
from repro.graphs import conformance_corpus

KS = (3, 4, 5)


@pytest.fixture(scope="module")
def corpus():
    return conformance_corpus()


@pytest.fixture(scope="module")
def oracle(corpus):
    return {g.name: {k: clique_count_bruteforce(g, k, return_per_node=True)
                     for k in KS}
            for g in corpus}


def test_all_backends_match_bruteforce(corpus, oracle):
    for g in corpus:
        eng = CliqueEngine(g)
        for k in KS:
            expected, _ = oracle[g.name][k]
            counts = {b: eng.submit(CountRequest(k=k, backend=b)).count
                      for b in BACKENDS}
            assert counts == {b: expected for b in BACKENDS}, \
                (g.name, k, expected, counts)


def test_per_node_attributions_bit_for_bit(corpus, oracle):
    """local, pallas, and the ooc scheduler must reproduce the oracle's
    per-node counts exactly (shard_map doesn't expose per-node
    attribution)."""
    for g in corpus:
        eng = CliqueEngine(g)
        for k in KS:
            _, per_node = oracle[g.name][k]
            for b in ("local", "pallas", "ooc"):
                rep = eng.submit(CountRequest(k=k, backend=b,
                                              return_per_node=True))
                got = np.round(rep.per_node).astype(np.int64)
                np.testing.assert_array_equal(got, per_node,
                                              err_msg=f"{g.name} k={k} {b}")


def test_split_round_conformance(corpus, oracle):
    """The §6 split round must preserve exactness on every backend."""
    for g in corpus:
        eng = CliqueEngine(g)
        expected, _ = oracle[g.name][4]
        for b in BACKENDS:
            rep = eng.submit(CountRequest(k=4, backend=b,
                                          split_threshold=8))
            assert rep.count == expected, (g.name, b)


def test_sampled_methods_agree_across_backends(corpus):
    """Sampling is keyed by node id only, so for a fixed seed the
    estimate must be identical on every backend (and exact at p=1 /
    colors=1)."""
    g = corpus[1]   # the ER control
    eng = CliqueEngine(g)
    bf = clique_count_bruteforce(g, 4)
    for method, kw in [(EdgeSample(p=0.5), {}),
                       (ColorCoding(colors=3), {}),
                       (WedgeSample(samples=32), {}),
                       (Sparsify(q=0.7), {})]:
        ests = {b: eng.submit(CountRequest(k=4, method=method, seed=7,
                                           backend=b, **kw)).estimate
                for b in BACKENDS}
        assert len({round(e, 6) for e in ests.values()}) == 1, \
            (method, ests)
    assert eng.submit(CountRequest(k=4, method=EdgeSample(p=1.0),
                                   backend="shard_map")).count == bf
    assert eng.submit(CountRequest(k=4, method=ColorCoding(colors=1),
                                   backend="pallas")).count == bf


def test_sparsify_q1_is_exact_on_every_backend(corpus, oracle):
    """q=1 keeps every edge: the sparsified child *is* the graph, so
    the rescale is 1 and the count must equal the oracle bit-for-bit —
    the degenerate end of the DOULION unbiasedness ladder."""
    for g in corpus[:3]:
        eng = CliqueEngine(g)
        expected, _ = oracle[g.name][4]
        for b in BACKENDS:
            rep = eng.submit(CountRequest(k=4, method=Sparsify(q=1.0),
                                          seed=11, backend=b))
            assert rep.count == expected, (g.name, b)


def test_wedge_adaptive_ci_contains_bruteforce(corpus, oracle):
    """The wedge lever under a rel_error contract must report a CI that
    contains the truth (or resolve exact, which trivially does)."""
    g = corpus[0]
    eng = CliqueEngine(g)
    expected, _ = oracle[g.name][4]
    rep = eng.submit(CountRequest(k=4, method=WedgeSample(samples=32),
                                  rel_error=0.25, seed=3))
    assert rep.ci_low <= expected <= rep.ci_high
