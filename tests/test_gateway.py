"""ServingGateway: admission control, per-tenant quotas, deadlines,
store write-through / short-circuit, restart warm start, and graceful
shutdown.

Slow-execution scenarios monkeypatch ``CliqueEngine.submit`` with a
sleeping wrapper — the admission and deadline machinery only cares that
work is *in flight*, not what it computes.
"""
import asyncio
import threading
import time

import pytest

from repro.core import clique_count_bruteforce
from repro.engine import CliqueEngine, CountRequest, graph_fingerprint
from repro.graphs import barabasi_albert, erdos_renyi
from repro.serving.cliques import CancelledError, CliqueService
from repro.serving.gateway import (DeadlineExceeded, GatewayClosed,
                                   GatewayOverloaded, ServingGateway)


@pytest.fixture(scope="module")
def graphs():
    return (erdos_renyi(40, 0.25, seed=1),
            barabasi_albert(80, 5, seed=2))


@pytest.fixture(scope="module")
def bf(graphs):
    return {g.name: {k: clique_count_bruteforce(g, k) for k in (3, 4)}
            for g in graphs}


def _slow_submit(monkeypatch, delay_s: float):
    orig = CliqueEngine.submit

    def slow(self, req):
        time.sleep(delay_s)
        return orig(self, req)

    monkeypatch.setattr(CliqueEngine, "submit", slow)


# ---------------- store write-through / short-circuit ----------------

def test_miss_then_hit_short_circuits_the_service(tmp_path, graphs, bf):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path))
    t1 = gw.submit(g, CountRequest(k=4))
    assert t1.result(timeout=120).count == bf[g.name][4]
    assert not t1.from_store
    t2 = gw.submit(g, CountRequest(k=4))
    assert t2.from_store and t2.done()
    rep = t2.result()
    assert rep.count == bf[g.name][4]
    assert rep.cache["store"] == "hit"
    s = gw.stats()
    assert s["store"]["hits"] == 1 and s["store"]["misses"] == 1
    assert s["service"]["executed"] == 1          # hit never executed
    gw.shutdown()


def test_restarted_gateway_serves_from_store_and_warms_pool(
        tmp_path, graphs, bf):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path))
    first = gw.submit(g, CountRequest(k=4)).result(timeout=120)
    gw.shutdown()

    gw2 = ServingGateway(store_dir=str(tmp_path))
    s = gw2.stats()
    assert s["warmed_graphs"] == 1 and s["warmed_sessions"] == 1
    assert s["service"]["pool"]["warmed"] == 1
    # bit-exact across save → restart → load
    rep = gw2.submit(g, CountRequest(k=4)).result()
    assert rep.estimate == first.estimate
    assert gw2.stats()["service"]["executed"] == 0
    # a bare fingerprint ref resolves (the store re-registered it)
    fp = graph_fingerprint(g)
    assert gw2.submit(fp, CountRequest(k=4)).result().count == \
        bf[g.name][4]
    # a NEW query on the warmed graph is a session hit, not a rebuild
    rep3 = gw2.submit(fp, CountRequest(k=3)).result(timeout=120)
    assert rep3.count == bf[g.name][3]
    assert rep3.cache["session"] == "hit"
    gw2.shutdown()


def test_predicate_listing_served_but_never_persisted(tmp_path, graphs):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path))
    req = CountRequest(k=3, mode="list",
                       predicate=lambda rows: rows[:, 0] >= 0)
    assert gw.submit(g, req).result(timeout=120).cliques is not None
    again = gw.submit(g, req)
    assert not again.from_store                   # identity-keyed: re-run
    again.result(timeout=120)
    s = gw.stats()
    assert s["store"]["entries"] == 0
    assert s["service"]["executed"] == 2
    gw.shutdown()


def test_gateway_without_store(graphs, bf):
    g = graphs[1]
    gw = ServingGateway()
    assert gw.submit(g, CountRequest(k=3)).result(timeout=120).count == \
        bf[g.name][3]
    assert gw.stats()["store"] is None
    gw.shutdown()


# ---------------- admission control ----------------

def test_queue_depth_sheds(graphs, monkeypatch):
    _slow_submit(monkeypatch, 0.5)
    gw = ServingGateway(max_queue_depth=1)
    t1 = gw.submit(graphs[0], CountRequest(k=3))
    with pytest.raises(GatewayOverloaded, match="queue depth"):
        gw.submit(graphs[0], CountRequest(k=4))
    assert t1.result(timeout=120).count >= 0
    assert gw.stats()["shed"] == 1
    # capacity freed once the first query resolved
    assert gw.submit(graphs[0], CountRequest(k=4)).result(
        timeout=120).count >= 0
    gw.shutdown()


def test_tenant_quota_isolates_tenants(graphs, monkeypatch):
    _slow_submit(monkeypatch, 0.5)
    gw = ServingGateway(max_queue_depth=8, tenant_quota=1)
    ta = gw.submit(graphs[0], CountRequest(k=3), tenant="a")
    with pytest.raises(GatewayOverloaded, match="tenant"):
        gw.submit(graphs[0], CountRequest(k=4), tenant="a")
    tb = gw.submit(graphs[0], CountRequest(k=4), tenant="b")
    assert ta.result(timeout=120).count >= 0
    assert tb.result(timeout=120).count >= 0
    s = gw.stats()
    assert s["shed"] == 1 and s["shed_tenant"] == 1
    gw.shutdown()


def test_store_hits_bypass_admission(tmp_path, graphs, monkeypatch):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path), max_queue_depth=1)
    gw.submit(g, CountRequest(k=3)).result(timeout=120)
    _slow_submit(monkeypatch, 0.5)
    blocker = gw.submit(g, CountRequest(k=4))     # fills the queue
    # at capacity — but the persisted answer still serves instantly
    hit = gw.submit(g, CountRequest(k=3))
    assert hit.from_store and hit.result().count >= 0
    blocker.result(timeout=120)
    assert gw.stats()["shed"] == 0
    gw.shutdown()


# ---------------- deadlines ----------------

def test_deadline_expires_queued_ticket(graphs, monkeypatch):
    _slow_submit(monkeypatch, 0.6)
    gw = ServingGateway(monitor_poll_s=0.01)
    slow = gw.submit(graphs[0], CountRequest(k=3))
    # let the worker pick up the slow job first, so the doomed one lands
    # in a later batch and its expiry is visible at that batch's filter
    end = time.time() + 5.0
    while gw.stats()["service"]["queue_depth"] > 0 and time.time() < end:
        time.sleep(0.005)
    doomed = gw.submit(graphs[0], CountRequest(k=4), deadline_s=0.05)
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=120)
    assert slow.result(timeout=120).count >= 0
    assert gw.stats()["deadline_expired"] >= 1
    # the doomed job was stripped of its only waiter before its turn, so
    # the next drain skips it without touching an engine (poll: the skip
    # is counted when the worker reaches the now-empty job)
    end = time.time() + 5.0
    while gw.stats()["service"]["cancelled_jobs"] < 1 and time.time() < end:
        time.sleep(0.01)
    s = gw.stats()
    assert s["service"]["executed"] == 1
    assert s["service"]["cancelled_jobs"] >= 1
    gw.shutdown()


def test_generous_deadline_is_met(graphs, bf):
    g = graphs[0]
    gw = ServingGateway(default_deadline_s=120.0)
    assert gw.submit(g, CountRequest(k=4)).result().count == bf[g.name][4]
    assert gw.stats()["deadline_expired"] == 0
    gw.shutdown()


def test_monitor_expires_without_a_waiter(graphs, monkeypatch):
    """Nobody calls result(): the background monitor alone must expire
    the ticket and free its admission slot."""
    _slow_submit(monkeypatch, 0.6)
    gw = ServingGateway(monitor_poll_s=0.01, max_queue_depth=2)
    gw.submit(graphs[0], CountRequest(k=3))                    # occupies
    doomed = gw.submit(graphs[0], CountRequest(k=4), deadline_s=0.05)
    deadline = time.time() + 5.0
    while not doomed.done() and time.time() < deadline:
        time.sleep(0.01)
    assert doomed.done()
    with pytest.raises(DeadlineExceeded):
        doomed.result()
    assert gw.stats()["deadline_expired"] >= 1
    gw.shutdown()


def test_monitor_survives_a_tickets_cancel_exploding(graphs, monkeypatch):
    """One ticket whose cancel raises must not kill the deadline
    monitor — before the fix the thread died on the first exception and
    every later deadline went silently unenforced for the life of the
    gateway."""
    _slow_submit(monkeypatch, 0.8)
    gw = ServingGateway(monitor_poll_s=0.01, max_queue_depth=4)
    gw.submit(graphs[0], CountRequest(k=3))                    # occupies
    bomb = gw.submit(graphs[0], CountRequest(k=4), deadline_s=0.05)

    def exploding_cancel(exc):
        raise RuntimeError("ticket state torn down concurrently")

    monkeypatch.setattr(bomb._inner, "cancel", exploding_cancel)
    doomed = gw.submit(graphs[1], CountRequest(k=4), deadline_s=0.1)
    deadline = time.time() + 5.0
    while not doomed.done() and time.time() < deadline:
        time.sleep(0.01)
    # the later deadline was still enforced, past the exploding one
    assert doomed.done()
    with pytest.raises(DeadlineExceeded):
        doomed.result()
    assert gw.stats()["monitor_errors"] >= 1
    assert gw._monitor.is_alive()
    gw.shutdown()


# ---------------- ticket cancellation (service level) ----------------

def test_ticket_cancel_skips_job_without_engine_work(graphs):
    svc = CliqueService()
    t = svc.submit(graphs[0], CountRequest(k=3))
    assert t.cancel()
    assert not t.cancel()                          # idempotent: already done
    with pytest.raises(CancelledError):
        t.result()
    assert svc.drain() == 0                        # skipped, not executed
    s = svc.stats()
    assert s["cancelled"] == 1 and s["cancelled_jobs"] == 1
    assert s["executed"] == 0
    # a coalesced job survives losing ONE of its waiters
    t1 = svc.submit(graphs[0], CountRequest(k=3))
    t2 = svc.submit(graphs[0], CountRequest(k=3))
    assert t1.cancel()
    assert t2.result(timeout=120).count >= 0


def test_cancel_after_result_returns_false(graphs):
    svc = CliqueService()
    t = svc.submit(graphs[0], CountRequest(k=3))
    assert t.result(timeout=120).count >= 0
    assert not t.cancel()


# ---------------- shutdown / async ----------------

def test_graceful_shutdown_drains_then_refuses(tmp_path, graphs, bf):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path))
    t = gw.submit(g, CountRequest(k=4))
    gw.shutdown()                                  # drains queued work
    assert t.result(timeout=10).count == bf[g.name][4]
    with pytest.raises(GatewayClosed):
        gw.submit(g, CountRequest(k=3))
    gw.shutdown()                                  # idempotent
    assert gw.stats()["closed"]
    assert gw.stats()["service"]["pool"]["live"] == 0


def test_async_result_adapter(tmp_path, graphs, bf):
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path))

    async def drive():
        miss = gw.submit(g, CountRequest(k=4))
        hit = gw.submit(g, CountRequest(k=3))
        a, b = await asyncio.gather(miss.async_result(120),
                                    hit.async_result(120))
        return a.count, b.count

    ka, kb = asyncio.run(drive())
    assert ka == bf[g.name][4] and kb == bf[g.name][3]
    gw.shutdown()


def test_concurrent_tenants_under_load(tmp_path, graphs, bf):
    """Many threads, mixed tenants, quotas generous enough that nothing
    sheds: every query lands, the store absorbs the repeats."""
    g = graphs[0]
    gw = ServingGateway(store_dir=str(tmp_path), max_queue_depth=64,
                        tenant_quota=32)
    results: dict[int, int] = {}

    def user(i):
        t = gw.submit(g, CountRequest(k=3 + (i % 2)),
                      tenant=f"t{i % 3}")
        results[i] = t.result(timeout=120).count

    threads = [threading.Thread(target=user, args=(i,))
               for i in range(12)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert all(results[i] == bf[g.name][3 + (i % 2)] for i in range(12))
    s = gw.stats()
    assert s["shed"] == 0
    # at most one execution per distinct answer; the rest coalesced or hit
    assert s["service"]["executed"] <= 2
    gw.shutdown()


def test_gateway_rejects_bad_knobs():
    with pytest.raises(ValueError):
        ServingGateway(max_queue_depth=0)
    with pytest.raises(ValueError):
        ServingGateway(tenant_quota=0)
