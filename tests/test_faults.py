"""FaultDomain: injected-failure retry counts + the exponential
backoff schedule (deterministic jitter, cap), pinned.

The out-of-core scheduler's per-task retry loop is built on these
primitives (see ``repro.scheduler.driver``), so the schedule is a
contract, not an implementation detail.
"""
import threading

import pytest

from repro.runtime.faults import (FaultDomain, SimulatedFault,
                                  backoff_delay)


# ---------------- backoff schedule ----------------

def test_backoff_is_geometric_without_jitter():
    ds = [backoff_delay(a, base_s=0.1, factor=2.0, cap_s=100.0)
          for a in range(1, 6)]
    assert ds == pytest.approx([0.1, 0.2, 0.4, 0.8, 1.6])


def test_backoff_caps():
    assert backoff_delay(30, base_s=0.1, factor=2.0, cap_s=5.0) == 5.0
    # the cap applies to the geometric term; jitter rides on top but is
    # bounded by jitter * cap
    d = backoff_delay(30, base_s=0.1, factor=2.0, cap_s=5.0, jitter=0.25,
                      seed=3)
    assert 5.0 <= d <= 5.0 * 1.25


def test_backoff_jitter_is_deterministic_and_seeded():
    a = [backoff_delay(i, base_s=0.1, jitter=0.5, seed=7)
         for i in range(1, 8)]
    b = [backoff_delay(i, base_s=0.1, jitter=0.5, seed=7)
         for i in range(1, 8)]
    c = [backoff_delay(i, base_s=0.1, jitter=0.5, seed=8)
         for i in range(1, 8)]
    assert a == b                      # same seed → identical schedule
    assert a != c                      # different seed → decorrelated
    base = [backoff_delay(i, base_s=0.1) for i in range(1, 8)]
    for with_j, without in zip(a, base):
        assert without <= with_j < without * 1.5


def test_backoff_pinned_values():
    """Pin the exact schedule for one seed: a hash-function change that
    silently reshuffles every retry schedule should fail loudly."""
    got = [round(backoff_delay(i, base_s=1.0, factor=2.0, cap_s=30.0,
                               jitter=0.5, seed=42), 6)
           for i in (1, 2, 3)]
    expect = []
    import zlib
    for i in (1, 2, 3):
        d = min(1.0 * 2.0 ** (i - 1), 30.0)
        h = zlib.crc32(f"42:{i}".encode()) & 0xFFFFFFFF
        expect.append(round(d + d * 0.5 * (h / 2**32), 6))
    assert got == expect


# ---------------- FaultDomain retry semantics ----------------

def test_fault_domain_retry_count_and_sleep_schedule():
    fd = FaultDomain(fail_at=(0, 1, 2), max_retries=5, backoff_s=0.001,
                     backoff_factor=2.0)
    assert fd.run(lambda: "ok") == "ok"
    assert fd.calls == 4               # 3 injected failures + 1 success
    assert fd.sleeps == pytest.approx([0.001, 0.002, 0.004])


def test_fault_domain_gives_up_after_max_retries():
    fd = FaultDomain(fail_at=tuple(range(10)), max_retries=2,
                     backoff_s=0.0)
    with pytest.raises(SimulatedFault):
        fd.run(lambda: 1)
    assert fd.sleeps == []             # zero base → no sleeping


def test_maybe_fail_counts_and_raises():
    fd = FaultDomain(fail_at=(1,))
    fd.maybe_fail()                    # call 0: fine
    with pytest.raises(SimulatedFault):
        fd.maybe_fail()                # call 1: injected
    fd.maybe_fail()                    # call 2: fine again
    assert fd.calls == 3


def test_maybe_fail_is_thread_safe():
    """N threads × M calls must count exactly N·M attempts (the
    scheduler's workers share one injection domain)."""
    fd = FaultDomain()
    n_threads, per_thread = 8, 200

    def hammer():
        for _ in range(per_thread):
            fd.maybe_fail()

    ts = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert fd.calls == n_threads * per_thread
