"""Deterministic generator contracts (no hypothesis dependency — these
must run in every environment; tests/test_properties.py widens them to
randomized metamorphic checks where hypothesis is available)."""
import numpy as np
import pytest

from repro.graphs import (complete_graph, conformance_corpus,
                          erdos_renyi_m)


@pytest.mark.parametrize("n,m", [
    (10, 40),    # dense: the old 1.3× oversample deduped below m here
    (10, 45),    # m == C(n,2): must produce exactly K_10's edge set
    (30, 0),
    (50, 300),
    (200, 1500),
])
def test_erdos_renyi_m_delivers_exactly_m(n, m):
    g = erdos_renyi_m(n, m, seed=2)
    assert g.m == m and g.n == n
    # canonical invariants survive the resampling path
    assert np.all(g.edges[:, 0] < g.edges[:, 1])
    assert len(np.unique(g.edges[:, 0] * n + g.edges[:, 1])) == g.m


def test_erdos_renyi_m_saturated_is_complete():
    g = erdos_renyi_m(12, 66, seed=5)
    np.testing.assert_array_equal(g.edges, complete_graph(12).edges)


def test_erdos_renyi_m_infeasible_raises():
    with pytest.raises(ValueError):
        erdos_renyi_m(10, 46)


def test_erdos_renyi_m_seed_reproducible():
    a = erdos_renyi_m(40, 120, seed=7)
    b = erdos_renyi_m(40, 120, seed=7)
    np.testing.assert_array_equal(a.edges, b.edges)
    assert erdos_renyi_m(40, 120, seed=8).edges.tolist() != a.edges.tolist()


def test_conformance_corpus_is_stable():
    names = [g.name for g in conformance_corpus()]
    assert names == ["K10", "er_n48_p0.25", "er_n40_m120", "ba_n64_k6",
                     "planted_32_6_7", "K12_12", "planted_1200_12_16_40"]
    assert len(set(names)) == len(names)


def test_complete_bipartite_is_triangle_free():
    from repro.core import clique_count_bruteforce
    from repro.graphs import complete_bipartite
    g = complete_bipartite(5, 7)
    assert (g.n, g.m) == (12, 35)
    assert clique_count_bruteforce(g, 3) == 0
