"""Session engine: parity with the legacy entry points, cache
telemetry, per-request backend routing, multi-device shard_map."""
import numpy as np
import pytest

from conftest import run_with_devices

from repro.core import clique_count_bruteforce, count_cliques
from repro.engine import CliqueEngine, CountRequest
from repro.graphs import barabasi_albert, erdos_renyi


@pytest.fixture(scope="module")
def er_graph():
    return erdos_renyi(60, 0.3, seed=4)


def test_submit_matches_count_cliques_all_methods(er_graph):
    eng = CliqueEngine(er_graph)
    cases = [("exact", {}), ("edge", {"p": 0.5}),
             ("color", {"colors": 3}), ("color_smooth", {"colors": 3})]
    for method, kw in cases:
        for k in (3, 4):
            rep = eng.submit(CountRequest(k=k, method=method, seed=3, **kw))
            legacy = count_cliques(er_graph, k, method=method, seed=3, **kw)
            assert rep.estimate == pytest.approx(legacy.estimate,
                                                 rel=1e-6), (method, k)
    nipp = eng.submit(CountRequest(k=3, method="ni++"))
    assert nipp.count == clique_count_bruteforce(er_graph, 3)
    assert nipp.mrc.rounds == 2


def test_sampling_at_rate_one_is_exact(er_graph):
    """Independent oracle for the sampled tile path (parity with the
    legacy wrapper alone is tautological now that the wrapper routes
    through the engine): sampling at rate 1 must equal brute force."""
    eng = CliqueEngine(er_graph)
    for k in (3, 4):
        bf = clique_count_bruteforce(er_graph, k)
        assert eng.submit(CountRequest(k=k, method="edge",
                                       p=1.0)).count == bf
        assert eng.submit(CountRequest(k=k, method="color",
                                       colors=1)).count == bf


def test_exact_matches_bruteforce_and_per_node(er_graph):
    eng = CliqueEngine(er_graph)
    for k in (3, 4, 5):
        rep = eng.submit(CountRequest(k=k, return_per_node=True))
        bf, pn = clique_count_bruteforce(er_graph, k, return_per_node=True)
        assert rep.count == bf
        np.testing.assert_array_equal(
            np.round(rep.per_node).astype(np.int64), pn)


def test_second_query_reports_cache_hits(er_graph):
    eng = CliqueEngine(er_graph)
    r1 = eng.submit(CountRequest(k=4))
    assert r1.cache["plan"] == "miss"
    assert r1.cache["exec_misses"] >= 1
    r2 = eng.submit(CountRequest(k=4))
    assert r2.cache["plan"] == "hit"
    assert r2.cache["exec_misses"] == 0
    assert r2.cache["exec_hits"] >= 1
    assert r2.estimate == r1.estimate
    # different sampling params, same compiled executables (p/c traced)
    r3 = eng.submit(CountRequest(k=4, method="color", colors=5))
    r4 = eng.submit(CountRequest(k=4, method="color", colors=9))
    assert r3.cache["plan"] == "hit"
    assert r4.cache["exec_misses"] == 0 and r4.cache["exec_hits"] >= 1


def test_submit_many_session_sweep(er_graph):
    eng = CliqueEngine(er_graph)
    reqs = ([CountRequest(k=k) for k in (3, 4, 5)] +
            [CountRequest(k=4),
             CountRequest(k=4, method="color", colors=3, seed=1)])
    reps = eng.submit_many(reqs)
    for rep, k in zip(reps[:3], (3, 4, 5)):
        assert rep.count == clique_count_bruteforce(er_graph, k)
    assert reps[3].estimate == reps[1].estimate
    stats = eng.session_stats()
    assert stats["n_queries"] == len(reqs)
    assert stats["plans"]["hits"] >= 2       # repeat k=4 (exact + color)
    assert stats["executables"]["hits"] >= 1


def test_shard_map_backend_matches_local(er_graph):
    eng = CliqueEngine(er_graph)          # 1-device mesh in-process
    for method, kw in [("exact", {}), ("color", {"colors": 3})]:
        loc = eng.submit(CountRequest(k=4, method=method, seed=5, **kw))
        dist = eng.submit(CountRequest(k=4, method=method, seed=5,
                                       backend="shard_map", **kw))
        assert dist.backend == "shard_map" and loc.backend == "local"
        assert dist.estimate == pytest.approx(loc.estimate, rel=1e-5)
    # split round through the same session, both backends
    thr = 8
    a = eng.submit(CountRequest(k=4, split_threshold=thr))
    b = eng.submit(CountRequest(k=4, split_threshold=thr,
                                backend="shard_map"))
    assert a.count == b.count == clique_count_bruteforce(er_graph, 4)


def test_pallas_backend_matches_local(er_graph):
    eng = CliqueEngine(er_graph)
    for k in (3, 4):
        loc = eng.submit(CountRequest(k=k))
        pal = eng.submit(CountRequest(k=k, backend="pallas"))
        assert pal.count == loc.count


def test_request_validation(er_graph):
    eng = CliqueEngine(er_graph)
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=2))
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=4, method="ni++"))
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=3, method="nope"))
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=3, backend="hadoop"))
    with pytest.raises(ValueError):
        eng.submit(CountRequest(k=3, backend="shard_map",
                                return_per_node=True))


def test_sampling_deterministic_per_seed_across_backends():
    g = barabasi_albert(300, 8, seed=9)
    eng = CliqueEngine(g)
    a = eng.submit(CountRequest(k=4, method="color", colors=3, seed=5))
    b = eng.submit(CountRequest(k=4, method="color", colors=3, seed=5))
    assert a.estimate == b.estimate
    c = eng.submit(CountRequest(k=4, method="color", colors=3, seed=6))
    assert a.estimate != c.estimate


@pytest.mark.slow
def test_engine_shard_map_eight_workers():
    run_with_devices("""
from repro.engine import CliqueEngine, CountRequest
from repro.core import clique_count_bruteforce
from repro.graphs import barabasi_albert
g = barabasi_albert(300, 8, seed=9)
bf = clique_count_bruteforce(g, 4)
eng = CliqueEngine(g, backend="shard_map")
reps = eng.submit_many([CountRequest(k=4),
                        CountRequest(k=4, split_threshold=16),
                        CountRequest(k=4)])
assert reps[0].n_workers == 8
assert [r.count for r in reps] == [bf, bf, bf]
assert reps[2].cache["plan"] == "hit"
assert reps[2].cache["exec_misses"] == 0
local = CliqueEngine(g).submit(
    CountRequest(k=4, method="color", colors=3, seed=5)).estimate
dist = eng.submit(
    CountRequest(k=4, method="color", colors=3, seed=5)).estimate
assert abs(local - dist) < 1e-3 * max(abs(local), 1.0)
print("OK")
""", n_devices=8)
