"""The typed MethodSpec registry and the API-redesign compatibility
contract.

Three promises are pinned here:

- **Spec ↔ string equivalence** — every typed spec and its legacy
  string spelling resolve to the *same* ``query_key``, so coalescing
  and the persistent :class:`~repro.serving.store.ResultStore` treat
  them as one answer. A literal key tuple is pinned for the slot-reuse
  methods (wedge rides the ``colors`` slot, sparsify rides ``p``) so a
  layout drift fails loudly instead of silently orphaning every stored
  entry.
- **Deprecation shims** — legacy strings still work but warn; typed
  specs and the non-deprecated strings ("exact", "wedge", "sparsify")
  stay silent.
- **Store hit across the redesign** — an entry persisted by a
  pre-portfolio client (legacy string + kwargs) must still be *hit* by
  a typed-spec request after the redesign, byte-identical.
"""
import warnings

import pytest

from repro.engine import (CliqueEngine, CountRequest, graph_fingerprint)
from repro.estimator import (Auto, ColorCoding, DEPRECATED_STRINGS,
                             EdgeSample, Exact, NIPlusPlus, Sparsify,
                             WedgeSample, from_string)
from repro.graphs import barabasi_albert
from repro.serving.store import ResultStore


def _legacy(method, k=4, **kw):
    """Build a legacy-string request with the shim warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return CountRequest(k=k, method=method, **kw)


# ---------------- spec <-> legacy string equivalence ----------------

EQUIV = [
    (Exact(), _legacy("exact"), {}),
    (NIPlusPlus(), _legacy("ni++"), {}),
    (EdgeSample(p=0.25), _legacy("edge", p=0.25), {}),
    (ColorCoding(colors=5), _legacy("color", colors=5), {}),
    (ColorCoding(colors=5, smooth=True),
     _legacy("color_smooth", colors=5), {}),
    (WedgeSample(samples=96), _legacy("wedge", colors=96), {}),
    (Sparsify(q=0.4), _legacy("sparsify", p=0.4), {}),
    (Auto(), _legacy("auto", rel_error=0.1), {"rel_error": 0.1}),
    (Auto(rel_error=0.1, confidence=0.95),
     _legacy("auto", rel_error=0.1, confidence=0.95), {}),
]


@pytest.mark.parametrize("spec,legacy,extra",
                         EQUIV, ids=[type(s).__name__ + str(i)
                                     for i, (s, _, _) in enumerate(EQUIV)])
def test_spec_and_string_share_a_query_key(spec, legacy, extra):
    typed = CountRequest(k=4, method=spec, **extra)
    assert typed.query_key() == legacy.query_key()
    assert typed.method == legacy.method


def test_spec_roundtrips_through_request():
    req = CountRequest(k=4, method=WedgeSample(samples=96))
    assert isinstance(req.spec, WedgeSample)
    assert req.spec.samples == 96
    assert isinstance(CountRequest(k=4, method=Sparsify(q=0.4)).spec,
                      Sparsify)


def test_from_string_matches_specs_and_rejects_unknown():
    assert from_string("wedge", colors=32) == WedgeSample(samples=32)
    assert from_string("sparsify", p=0.3) == Sparsify(q=0.3)
    with pytest.raises(ValueError, match="unknown method"):
        from_string("frobnicate")


def test_wedge_key_normalization_is_pinned():
    """Every spelling of the same wedge query — typed, legacy colors
    kwarg — lands on one literal durable key. The ``p`` slot is pinned
    to its no-op value 1.0 (wedge has no pair mask), ``seed`` is kept.
    Changing this tuple invalidates persisted stores: do it knowingly."""
    pinned = (4, "wedge", 1.0, 64, 0, "local", "auto", False,
              None, None, None, None, None)
    assert CountRequest(k=4, method=WedgeSample(samples=64)).query_key() \
        == pinned
    assert _legacy("wedge", k=4, colors=64).query_key() == pinned
    # p is a dead knob for wedge: it must not fork the key
    assert _legacy("wedge", k=4, colors=64, p=0.125).query_key() == pinned


def test_sparsify_key_normalization_pins_dead_colors_slot():
    a = CountRequest(k=4, method=Sparsify(q=0.5)).query_key()
    b = _legacy("sparsify", k=4, p=0.5, colors=999).query_key()
    assert a == b and a[3] == 1     # colors slot pinned to no-op


# ---------------- deprecation shims ----------------

@pytest.mark.parametrize("name", DEPRECATED_STRINGS)
def test_legacy_strings_warn(name):
    kw = {"rel_error": 0.1} if name == "auto" else {}
    with pytest.warns(DeprecationWarning, match="typed spec"):
        CountRequest(k=4, method=name, **kw)


@pytest.mark.parametrize("method", ["exact", "wedge", "sparsify",
                                    EdgeSample(p=0.5), Auto()])
def test_non_deprecated_spellings_stay_silent(method):
    kw = ({"rel_error": 0.1}
          if isinstance(method, Auto) or method in ("wedge", "sparsify")
          else {})
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        CountRequest(k=4, method=method, **kw)


# ---------------- validation of the new methods ----------------

def test_wedge_rejects_split_threshold():
    with pytest.raises(ValueError, match="wedge"):
        CountRequest(k=4, method=WedgeSample(samples=8),
                     split_threshold=8).validate()


def test_sparsify_rejects_bad_q():
    for q in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            CountRequest(k=4, method="sparsify", p=q).validate()


# ---------------- store hit across the redesign ----------------

@pytest.fixture(scope="module")
def graph():
    return barabasi_albert(80, 5, seed=2)


@pytest.fixture(scope="module")
def engine(graph):
    return CliqueEngine(graph)


def test_store_entry_written_with_legacy_kwargs_still_hits(tmp_path,
                                                           engine, graph):
    """The PR 8 compatibility promise: a ResultStore entry persisted by
    a legacy-string client is *hit* by the typed-spec request after the
    redesign — same durable key, same bytes back."""
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graph)
    old = _legacy("color", k=3, colors=3, seed=7)     # pre-redesign client
    rep = engine.submit(old)
    assert store.put(fp, old, rep)
    new = CountRequest(k=3, method=ColorCoding(colors=3), seed=7)
    back = store.get(fp, new)
    assert back is not None, "typed-spec request missed a legacy entry"
    assert back.estimate == rep.estimate
    assert store.stats()["hits"] == 1


def test_store_hit_for_wedge_across_spellings(tmp_path, engine, graph):
    store = ResultStore(str(tmp_path))
    fp = graph_fingerprint(graph)
    old = _legacy("wedge", k=3, colors=32, seed=5)
    rep = engine.submit(old)
    store.put(fp, old, rep)
    back = store.get(fp, CountRequest(k=3, method=WedgeSample(samples=32),
                                      seed=5))
    assert back is not None and back.estimate == rep.estimate


# ---------------- portfolio telemetry ----------------

def test_auto_report_carries_the_portfolio_decision(engine):
    """satellite (b): ``CountReport.estimator`` must explain the method
    choice — per-lever certificates, pilot walls, ranking, winner, and
    the escalation path — not just the resolved method."""
    rep = engine.submit(CountRequest(k=4, method=Auto(), rel_error=0.5,
                                     seed=3))
    port = rep.estimator["portfolio"]
    assert set(port) >= {"certificates", "pilot", "winner", "ranking",
                         "path"}
    names = {c["lever"] for c in port["certificates"]}
    assert names >= {"edge", "color", "wedge", "sparsify"}
    for cert in port["certificates"]:
        assert {"level", "width_bound", "var_proxy", "cost_per_replicate",
                "projected_work"} <= set(cert)
    if rep.estimator["resolved"] == "sampled":
        assert port["winner"] in names
        assert any("wall" in p for p in port["pilot"])
    stats = engine.session_stats()["estimator"]
    assert isinstance(stats["winners"], dict)


def test_adaptive_wedge_and_sparsify_accept_rel_error(engine):
    """The controller races only the named lever for a non-auto method
    (single-lever portfolio) and still honors the CI contract fields."""
    for method in ("wedge", "sparsify"):
        rep = engine.submit(CountRequest(k=3, method=method,
                                         rel_error=0.5, seed=1))
        assert rep.ci_low is not None and rep.ci_high is not None
        assert rep.ci_low <= rep.estimate <= rep.ci_high
        rank = rep.estimator["portfolio"]["ranking"]
        assert method in rank or rep.estimator["resolved"] == "exact"
