"""Deliverable (f): per-architecture smoke tests — reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import SHAPES, ShapeConfig, cell_is_runnable
from repro.data.pipeline import make_pipeline
from repro.models import (decode_step, forward_train, init_cache,
                          init_params)

SHAPE = ShapeConfig("tiny", 32, 4, "train")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    params = init_params(cfg, KEY)
    batch = {k: jnp.asarray(v) for k, v in
             next(make_pipeline(cfg, SHAPE, seed=1)).items()}
    loss, mets = jax.jit(
        lambda p, b: forward_train(cfg, p, b, remat="none"))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    from repro.training.optimizer import OptConfig, init_opt_state
    from repro.training.train_step import make_train_step
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3), remat="none"))
    p2, o2, m2 = step(params, init_opt_state(params), batch)
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    B = 3
    cache = init_cache(cfg, B, 16)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(0)))(
            params, cache, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    """Exact figures from the assignment brief."""
    cfg = get_config(arch)
    expect = {
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, (got, expect)


def test_special_config_fields():
    assert get_config("deepseek-v2-lite-16b").kv_lora_rank == 512
    assert get_config("deepseek-v2-lite-16b").n_experts == 64
    assert get_config("deepseek-v2-lite-16b").n_experts_per_tok == 6
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("mixtral-8x7b").n_experts_per_tok == 2
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("whisper-small").encoder_layers == 12


def test_long_500k_skip_rule():
    runs = {a: cell_is_runnable(get_config(a), SHAPES["long_500k"])[0]
            for a in list_archs()}
    assert runs == {
        "hymba-1.5b": True, "mixtral-8x7b": True, "mamba2-370m": True,
        "command-r-35b": False, "qwen1.5-4b": False, "yi-6b": False,
        "tinyllama-1.1b": False, "whisper-small": False,
        "internvl2-76b": False, "deepseek-v2-lite-16b": False}


def test_param_counts_in_expected_range():
    """Analytic param counts should be within ~35% of the nameplate size
    (names are marketing; vocab padding and stubs shift things)."""
    expect = {"tinyllama-1.1b": 1.1e9, "yi-6b": 6e9, "mixtral-8x7b": 46e9,
              "command-r-35b": 35e9, "mamba2-370m": 370e6,
              "deepseek-v2-lite-16b": 16e9, "qwen1.5-4b": 4e9,
              "hymba-1.5b": 1.5e9, "internvl2-76b": 70e9,
              "whisper-small": 244e6}
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * want < got < 1.45 * want, (arch, got, want)
