"""The out-of-core scheduler: task compilation, shard slices, ledger
replay, fault recovery, speculation, and driver kill-and-resume.

The conformance suite (tests/test_conformance.py) already pins the
``ooc`` backend bit-exact against the brute-force oracle on the whole
corpus; this file tests the machinery those counts ride on — the
resume contract (same tasks at any worker count), the crash-safety of
the ledger, and the recovery paths (retry, speculation, resume) that
never get exercised on a clean run.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.engine import CliqueEngine, CountRequest
from repro.graphs import conformance_corpus, planted_cliques
from repro.runtime.faults import FaultDomain
from repro.scheduler import (SchedulerConfig, ShardStore, Task, TaskLedger,
                             TaskResult, compile_tasks,
                             csr_footprint_bytes, lpt_assign,
                             plan_signature, query_signature)
from repro.scheduler.store import _closure_slice


@pytest.fixture(scope="module")
def graph():
    return conformance_corpus()[1]       # the ER control graph


@pytest.fixture(scope="module")
def engine_and_tasks(graph):
    eng = CliqueEngine(graph)
    req = CountRequest(k=4)
    entry, _ = eng._plan_entry(req)
    tasks = compile_tasks(entry, eng.og, req, elem_budget=1 << 21,
                          target_tasks=8)
    return eng, entry, req, tasks


# ---------------- task compilation ----------------

def test_task_ids_are_deterministic(engine_and_tasks):
    eng, entry, req, tasks = engine_and_tasks
    again = compile_tasks(entry, eng.og, req, elem_budget=1 << 21,
                          target_tasks=8)
    assert [t.task_id for t in tasks] == [t.task_id for t in again]
    assert plan_signature("fp", tasks) == plan_signature("fp", again)


def test_tasks_partition_the_plan(engine_and_tasks):
    """Every real work unit appears in exactly one task."""
    eng, entry, req, tasks = engine_and_tasks
    from_tasks = np.sort(np.concatenate(
        [t.units for t in tasks if t.kind == "bucket"]))
    from_plan = np.sort(np.concatenate(
        [b.nodes[:b.n_real] for b in entry.plan.buckets]))
    np.testing.assert_array_equal(from_tasks, from_plan)


def test_chunking_is_worker_count_independent(engine_and_tasks):
    """The resume contract: task ids never depend on n_workers — a run
    killed at W=2 resumes at W=8 with every completed id still valid.
    (Guaranteed by construction: compile_tasks doesn't take a worker
    count; this pins that nobody adds one.)"""
    import inspect
    sig = inspect.signature(compile_tasks)
    assert "n_workers" not in sig.parameters
    assert "workers" not in sig.parameters


def test_lpt_assign_balances_and_covers(engine_and_tasks):
    _, _, _, tasks = engine_and_tasks
    deques = lpt_assign(tasks, 3)
    assigned = [t.task_id for d in deques for t in d]
    assert sorted(assigned) == sorted(t.task_id for t in tasks)
    loads = [sum(t.cost for t in d) for d in deques]
    # LPT guarantee: max load ≤ total/W + heaviest task
    heaviest = max(t.cost for t in tasks)
    assert max(loads) <= sum(loads) / 3 + heaviest + 1e-9


# ---------------- shard slices ----------------

def test_closure_slice_keeps_unit_rows_whole(graph):
    eng = CliqueEngine(graph)
    og = eng.og
    units = np.arange(0, og.n, 3, dtype=np.int32)
    offsets, nbrs_rank, nbrs_byid = _closure_slice(og, units)
    assert offsets.shape == (og.n + 1,)
    assert nbrs_rank.size == nbrs_byid.size
    for u in units:
        lo, hi = int(offsets[u]), int(offsets[u + 1])
        full = og.nbrs_rank[og.offsets[u]:og.offsets[u + 1]]
        # a unit's own row survives filtering intact: every neighbor is
        # in the closure by definition
        np.testing.assert_array_equal(nbrs_rank[lo:hi], full)
    # filtered rows stay sorted in both orders (binary-search invariant)
    for x in range(og.n):
        lo, hi = int(offsets[x]), int(offsets[x + 1])
        assert np.all(np.diff(nbrs_byid[lo:hi]) > 0)


def test_spill_reuse_and_staleness(tmp_path, engine_and_tasks):
    eng, entry, req, tasks = engine_and_tasks
    store = ShardStore(root=str(tmp_path), fingerprint="f" * 16,
                       plan_sig=plan_signature("f" * 16, tasks))
    first = store.ensure(eng.og, tasks)
    second = store.ensure(eng.og, tasks)
    assert first["spill"] == "built" and second["spill"] == "reused"
    assert first["spill_bytes"] == second["spill_bytes"]
    # a manifest for a different task set is not trusted
    stale = ShardStore(root=str(tmp_path), fingerprint="f" * 16,
                       plan_sig=store.plan_sig)
    assert stale.ensure(eng.og, tasks[:2])["spill"] == "built"


def test_slices_are_smaller_than_the_csr(tmp_path, engine_and_tasks):
    """The out-of-core claim at its smallest scale: no task's slice
    reaches the full single-host CSR footprint."""
    eng, entry, req, tasks = engine_and_tasks
    store = ShardStore(root=str(tmp_path), fingerprint="g" * 16,
                       plan_sig=plan_signature("g" * 16, tasks))
    tel = store.ensure(eng.og, tasks)
    assert tel["max_slice_bytes"] < csr_footprint_bytes(eng.og)


# ---------------- ledger ----------------

def test_ledger_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    led = TaskLedger(path, "sig-a")
    led.open_fresh()
    led.append("t1", TaskResult(task_sum=3.0, elapsed_s=0.5))
    led.append("t2", TaskResult(task_sum=4.0, elapsed_s=0.25,
                                unit_ids=np.array([7, 9]),
                                unit_vals=np.array([1.0, 3.0])))
    led.close()
    with open(path, "a") as f:
        f.write('{"task": "t3", "sum": 5')     # torn tail (SIGKILL)
    done = TaskLedger(path, "sig-a").load()
    assert set(done) == {"t1", "t2"}           # tail distrusted
    assert done["t1"].task_sum == 3.0
    np.testing.assert_array_equal(done["t2"].unit_ids, [7, 9])
    # foreign query signature → nothing is trusted
    assert TaskLedger(path, "sig-b").load() == {}


def test_query_signature_normalizes_exact_seed(graph):
    """Exact answers don't depend on the seed, so an exact run resumes
    under a different seed; sampled runs must not."""
    a = query_signature("fp", "ps", CountRequest(k=4, seed=1))
    b = query_signature("fp", "ps", CountRequest(k=4, seed=2))
    assert a == b
    c = query_signature("fp", "ps",
                        CountRequest(k=4, method="edge", p=0.5, seed=1))
    d = query_signature("fp", "ps",
                        CountRequest(k=4, method="edge", p=0.5, seed=2))
    assert c != d


# ---------------- driver recovery paths ----------------

def test_injected_fault_is_retried_and_answer_unchanged(tmp_path, graph):
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path),
        faults=FaultDomain(fail_at=(0, 3)), retry_backoff_s=0.001))
    golden = eng.submit(CountRequest(k=4)).count
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    assert rep.count == golden
    assert tel["retried"] >= 2


def test_exhausted_retries_raise_but_checkpoint(tmp_path, graph):
    """A task that keeps failing fails the query — after journaling
    everything that did finish, so the rerun only recounts the loser."""
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path), max_retries=1,
        retry_backoff_s=0.0,
        faults=FaultDomain(fail_at=tuple(range(100)))))
    with pytest.raises(RuntimeError, match="resume=True"):
        eng.submit(CountRequest(k=4, backend="ooc"))
    eng2 = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path), resume=True))
    golden = eng2.submit(CountRequest(k=4)).count
    rep = eng2.submit(CountRequest(k=4, backend="ooc"))
    assert rep.count == golden


def test_resume_skips_completed_tasks_across_worker_counts(tmp_path,
                                                           graph):
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path)))
    golden = eng.submit(CountRequest(k=4)).count
    first = eng.submit(CountRequest(k=4, backend="ooc"))
    t1 = first.cache["scheduler"]
    assert first.count == golden and t1["run"] == t1["tasks"]
    # resume at a different worker count: nothing recounted
    eng2 = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=5, spill_dir=str(tmp_path), resume=True))
    second = eng2.submit(CountRequest(k=4, backend="ooc"))
    t2 = second.cache["scheduler"]
    assert second.count == golden
    assert t2["run"] == 0 and t2["resumed"] == t2["tasks"]
    assert t2["spill"] == "reused"


def test_resume_preserves_per_node_attribution(tmp_path, graph):
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=3, spill_dir=str(tmp_path)))
    ref = eng.submit(CountRequest(k=4, return_per_node=True))
    first = eng.submit(CountRequest(k=4, backend="ooc",
                                    return_per_node=True))
    np.testing.assert_array_equal(first.per_node, ref.per_node)
    eng2 = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path), resume=True))
    resumed = eng2.submit(CountRequest(k=4, backend="ooc",
                                       return_per_node=True))
    assert resumed.cache["scheduler"]["run"] == 0
    np.testing.assert_array_equal(resumed.per_node, ref.per_node)


def test_straggler_speculation_first_result_wins(tmp_path, graph):
    """Delay only execution 0 of one task; the speculative re-execution
    (execution ≥ 1, undelayed) must land first and the run must not
    wait out the injected delay."""
    eng_probe = CliqueEngine(graph)
    req = CountRequest(k=4)
    entry, _ = eng_probe._plan_entry(req)
    cfg_probe = SchedulerConfig()
    tasks = compile_tasks(entry, eng_probe.og, req,
                          elem_budget=cfg_probe.tile_elem_budget,
                          target_tasks=8)
    hot = tasks[0].task_id
    delay = 6.0
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=4, spill_dir=str(tmp_path), target_tasks=8,
        speculation_min_s=0.05, speculation_factor=2.0, poll_s=0.005,
        delay_hook=lambda tid, ei: delay if (tid == hot and ei == 0)
        else 0.0))
    golden = eng.submit(CountRequest(k=4)).count
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    assert rep.count == golden
    assert tel["speculated"] >= 1 and tel["speculation_wins"] >= 1, tel
    assert tel["wall_s"] < delay, tel["wall_s"]


def test_speculation_can_be_disabled(tmp_path, graph):
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path), speculate=False))
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    assert rep.cache["scheduler"]["speculated"] == 0


# ---------------- driver/ledger robustness (bugfix sweep) ----------------

def _mk_task(tid: str, cost: float = 1.0, n_units: int = 4) -> Task:
    return Task(task_id=tid, kind="bucket", capacity=8, tile_repr="dense",
                units=np.arange(n_units, dtype=np.int32), pivots=None,
                cost=cost)


def _open_ledger(tmp_path) -> TaskLedger:
    led = TaskLedger(str(tmp_path / "ledger.jsonl"), "sig")
    led.open_fresh()
    return led


def test_failed_speculation_does_not_poison_healthy_original(tmp_path):
    """A speculative duplicate that exhausts its own retries must lose
    quietly while the healthy original still grinds — before the fix it
    set ``Driver.failure`` on give-up and the whole run raised even
    though every task still had a live path to a result."""
    import threading

    tasks = [_mk_task(f"t{i}") for i in range(4)] + \
        [_mk_task("victim", cost=4.0)]
    exec_of: dict[tuple[str, int], int] = {}

    def hook(tid, ei):
        # record which execution this thread is running so the fake
        # run_task below can fail speculative executions only
        exec_of[(tid, threading.get_ident())] = ei
        return 0.8 if (tid == "victim" and ei == 0) else 0.0

    def run_task(task):
        if task.task_id == "victim" and \
                exec_of.get((task.task_id, threading.get_ident()), 0) >= 1:
            raise RuntimeError("speculative replica is poisoned")
        return TaskResult(task_sum=float(task.cost),
                          elapsed_s=0.01), 0

    from repro.scheduler.driver import Driver
    cfg = SchedulerConfig(n_workers=2, speculation_min_done=3,
                          speculation_min_s=0.05, speculation_factor=1.0,
                          poll_s=0.005, max_retries=1,
                          retry_backoff_s=0.001, retry_backoff_cap_s=0.01,
                          delay_hook=hook)
    ledger = _open_ledger(tmp_path)
    driver = Driver(tasks, run_task, cfg, ledger, {})
    results = driver.run()           # before the fix: RuntimeError
    ledger.close()
    assert set(results) == {t.task_id for t in tasks}
    assert driver.stats["speculated"] >= 1
    assert driver.stats["abandoned_failures"] >= 1
    assert driver.failure is None


def test_lost_work_raises_instead_of_partial_aggregate(tmp_path):
    """The monitor's break path (queues drained, nothing running, no
    recorded failure, tasks missing results) must raise — before the fix
    it returned the partial dict and ``aggregate`` summed a silently
    wrong count."""
    import collections

    from repro.scheduler.driver import Driver
    tasks = [_mk_task(f"t{i}") for i in range(3)]
    ledger = _open_ledger(tmp_path)
    driver = Driver(tasks, lambda t: (TaskResult(1.0, 0.01), 0),
                    SchedulerConfig(n_workers=1, speculate=False,
                                    poll_s=0.005), ledger, {})
    # simulate lost work: the queues drained away without results
    driver.deques = [collections.deque() for _ in driver.deques]
    with pytest.raises(RuntimeError, match="partial"):
        driver.run()
    ledger.close()


def test_ledger_fsync_failure_degrades_to_in_memory(tmp_path, monkeypatch):
    """An OSError inside the journal write (disk full at fsync) must not
    propagate — before the fix it killed the completing worker inside
    the completion lock, silently shrinking the pool."""
    led = _open_ledger(tmp_path)

    def boom(fd):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr("repro.scheduler.ledger.os.fsync", boom)
    led.append("t1", TaskResult(task_sum=3.0, elapsed_s=0.1))  # no raise
    led.append("t2", TaskResult(task_sum=4.0, elapsed_s=0.1))
    assert led.errors == 2
    monkeypatch.undo()
    led.close()
    # whatever reached the file before/despite the failure replays fine
    assert isinstance(TaskLedger(led.path, "sig").load(), dict)


def test_ledger_errors_surface_in_scheduler_telemetry(tmp_path, graph):
    eng = CliqueEngine(graph, ooc=SchedulerConfig(
        n_workers=2, spill_dir=str(tmp_path)))
    tel = eng.submit(CountRequest(k=4, backend="ooc")).cache["scheduler"]
    assert tel["ledger_errors"] == 0
    assert tel["abandoned_failures"] == 0
    assert tel["commit_dups"] == 0
    assert tel["ledger_warnings"] == 0


@pytest.mark.parametrize("header", [
    '{"query_sig": "si',        # torn mid-header (crash during write)
    '3\n{"task": "t1", "sum": 1.0, "elapsed_s": 0.1}\n',  # valid non-dict
    '[1, 2]\n',
    '"sig"\n'])
def test_ledger_torn_header_is_a_fresh_ledger(tmp_path, header):
    """A torn or non-dict first line must read as an empty ledger —
    before the fix a *valid-JSON* non-dict header (``3``, ``[1]``)
    raised AttributeError out of ``load()`` and killed the resume that
    the journal exists to serve."""
    path = str(tmp_path / "ledger.jsonl")
    with open(path, "w") as f:
        f.write(header)
    led = TaskLedger(path, "sig")
    assert led.load() == {}
    assert led.replay_warnings >= 1


def test_ledger_non_dict_record_keeps_trusted_prefix(tmp_path):
    """Records after the header get the torn-tail treatment: the first
    malformed line (non-dict JSON included) ends the trusted prefix
    instead of raising."""
    led = _open_ledger(tmp_path)
    led.append("t1", TaskResult(task_sum=3.0, elapsed_s=0.1))
    led.close()
    with open(led.path, "a") as f:
        f.write('[1, 2]\n'
                '{"task": "t2", "sum": 9.0, "elapsed_s": 0.1}\n')
    led2 = TaskLedger(led.path, "sig")
    done = led2.load()
    assert set(done) == {"t1"} and done["t1"].task_sum == 3.0
    assert led2.replay_warnings == 1
    # open_append rewrites the trusted prefix; the garbage is gone
    led2.open_append(done)
    led2.close()
    led3 = TaskLedger(led.path, "sig")
    assert set(led3.load()) == {"t1"}
    assert led3.replay_warnings == 0


def test_ledger_record_missing_fields_ends_replay(tmp_path):
    led = _open_ledger(tmp_path)
    led.append("t1", TaskResult(task_sum=3.0, elapsed_s=0.1))
    led.close()
    with open(led.path, "a") as f:
        f.write('{"task": "t2"}\n')      # no "sum": half-written record
    led2 = TaskLedger(led.path, "sig")
    assert set(led2.load()) == {"t1"}
    assert led2.replay_warnings == 1


def test_completion_core_first_committed_wins(tmp_path):
    """The distributed commit protocol in miniature: the first result
    for a task is journaled and final; later duplicates (lease races,
    speculation losers, zombie hosts) are counted, not applied."""
    from repro.scheduler import CompletionCore
    led = _open_ledger(tmp_path)
    core = CompletionCore([_mk_task("a"), _mk_task("b")], led, {},
                          SchedulerConfig())
    assert core.commit("a", TaskResult(task_sum=1.0, elapsed_s=0.01))
    assert not core.commit("a", TaskResult(task_sum=999.0,
                                           elapsed_s=0.01))
    assert core.commit_dups == 1
    assert core.results["a"].task_sum == 1.0
    assert not core.finished()
    assert core.commit("b", TaskResult(task_sum=2.0, elapsed_s=0.01))
    assert core.finished()
    led.close()
    # exactly one journal line per task: the duplicate never hit disk
    with open(led.path) as f:
        assert sum(1 for _ in f) == 3    # header + a + b


def test_fixed_batches_skips_empty_input():
    from repro.scheduler.driver import _fixed_batches
    assert list(_fixed_batches(np.zeros(0, np.int32), 8, -1)) == []
    tiles = list(_fixed_batches(np.arange(5, dtype=np.int32), 4, -1))
    assert [t.tolist() for t in tiles] == [[0, 1, 2, 3], [4, -1, -1, -1]]


def test_zero_unit_task_does_zero_device_work(graph, monkeypatch):
    """A task with an empty ``units`` array must not dispatch a device
    call of pure padding — before the fix ``_fixed_batches`` yielded one
    all-fill tile per empty task."""
    import dataclasses
    import types

    from repro.engine import backends as backends_mod
    from repro.scheduler import driver as driver_mod
    from repro.scheduler.store import SliceCSR

    calls = []

    def fake_tile_executable(eng, backend, repr_, cap, r, method):
        def fn(csr, tile, key, p=0.0, c=0):
            calls.append(np.asarray(tile))
            return np.zeros(np.asarray(tile).shape[0], np.float32)
        return fn

    monkeypatch.setattr(backends_mod, "tile_executable",
                        fake_tile_executable)
    eng = CliqueEngine(graph)
    sl = SliceCSR(offsets=np.zeros(graph.n + 1, np.int32),
                  nbrs_rank=np.zeros(0, np.int32),
                  nbrs_byid=np.zeros(0, np.int32),
                  out_deg=np.zeros(graph.n, np.int32))
    store = types.SimpleNamespace(load=lambda tid: sl)
    run = driver_mod._make_runner(eng, store, CountRequest(k=4), key=None,
                                  cfg=SchedulerConfig())
    empty = _mk_task("empty", cost=0.0, n_units=0)
    res, _ = run(empty)
    assert res.task_sum == 0.0 and calls == []
    res2, _ = run(dataclasses.replace(empty, task_id="full",
                                      units=np.arange(3, dtype=np.int32)))
    assert len(calls) == 1           # non-empty tasks still execute


# ---------------- request validation ----------------

def test_ooc_rejects_listing_and_adaptive():
    with pytest.raises(ValueError, match="ooc"):
        CountRequest(k=4, mode="list", backend="ooc").validate()
    with pytest.raises(ValueError, match="ooc"):
        CountRequest(k=4, method="auto", backend="ooc").validate()


# ---------------- kill-and-resume (SIGKILL, subprocess) ----------------

CHILD = textwrap.dedent("""
    import sys
    from repro.engine import CliqueEngine, CountRequest
    from repro.graphs import planted_cliques
    from repro.scheduler import SchedulerConfig

    g = planted_cliques(400, 0.02, [8, 8, 9], seed=5)
    cfg = SchedulerConfig(n_workers=2, spill_dir=sys.argv[1],
                          target_tasks=12, speculate=False,
                          delay_hook=lambda tid, ei: 0.4)
    eng = CliqueEngine(g, ooc=cfg)
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    print("FULL_RUN_DONE", rep.count, flush=True)
""")


def _ledger_lines(spill_dir: str) -> int:
    total = 0
    for dirpath, _, files in os.walk(spill_dir):
        for f in files:
            if f.startswith("ledger-"):
                with open(os.path.join(dirpath, f)) as fh:
                    total = max(total, sum(1 for _ in fh) - 1)  # header
    return total


@pytest.mark.slow
def test_driver_killed_mid_run_resumes_without_recounting(tmp_path):
    g = planted_cliques(400, 0.02, [8, 8, 9], seed=5)
    golden = CliqueEngine(g).submit(CountRequest(k=4)).count

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, "-c", CHILD, str(tmp_path)],
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    try:
        deadline = time.time() + 120
        while time.time() < deadline:
            if proc.poll() is not None:
                out, err = proc.communicate()
                pytest.fail("driver finished before it could be killed: "
                            f"{out!r} {err!r}")
            if _ledger_lines(str(tmp_path)) >= 2:
                break
            time.sleep(0.05)
        else:
            pytest.fail("no ledger progress to kill into")
        os.kill(proc.pid, signal.SIGKILL)   # no atexit, no flush, nothing
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
    journaled = _ledger_lines(str(tmp_path))
    assert journaled >= 2

    eng = CliqueEngine(g, ooc=SchedulerConfig(
        n_workers=4, spill_dir=str(tmp_path), resume=True,
        target_tasks=12))
    rep = eng.submit(CountRequest(k=4, backend="ooc"))
    tel = rep.cache["scheduler"]
    assert rep.count == golden
    assert tel["resumed"] >= 2                       # trusted the journal
    assert tel["run"] == tel["tasks"] - tel["resumed"]   # no recounting
    assert tel["spill"] == "reused"
