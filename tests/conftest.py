"""Shared test utilities.

IMPORTANT: no XLA_FLAGS here — smoke tests and benchmarks must see the
single real CPU device. Multi-device tests spawn subprocesses that set
``xla_force_host_platform_device_count`` themselves.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def pytest_addoption(parser):
    parser.addoption(
        "--stat", action="store_true", default=False,
        help="run the full statistical-calibration sweeps (tier-1 runs "
             "only the 20-seed smoke)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--stat"):
        return
    skip = pytest.mark.skip(reason="full calibration sweep; pass --stat")
    for item in items:
        if "stat" in item.keywords:
            item.add_marker(skip)


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
            f"STDERR:\n{out.stderr[-4000:]}")
    return out.stdout


@pytest.fixture
def tmp_workdir(tmp_path):
    return str(tmp_path)
