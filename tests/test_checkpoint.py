"""Checkpointing: atomicity, GC, resume parity, elastic restore."""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5)},
            "d": (jnp.ones((2,)), jnp.zeros((3,), jnp.int32))}


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree(2.5)
    m.save(7, t)
    got, manifest = m.restore(t)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(float(s)))
    assert m.latest_step() == 4
    assert m.all_steps() == [3, 4]  # GC kept only 2
    got, _ = m.restore(_tree())
    assert float(np.asarray(got["a"][0, 0])) == 4.0


def test_async_save_then_wait(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=True)
    m.save(1, _tree(1.0))
    m.wait()
    assert m.latest_step() == 1


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs must never be listed as checkpoints."""
    m = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_00000009"))
    assert m.all_steps() == []


def test_restore_with_shardings_moves_to_current_mesh(tmp_path):
    """Elastic path: restore with explicit (trivial) shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    m = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree(3.0)
    m.save(1, t)
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    got, _ = m.restore(t, shardings=sh)
    assert got["a"].sharding == NamedSharding(mesh, P())


def test_manifest_contents(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=False)
    m.save(3, _tree(), extra={"arch": "yi-6b"})
    with open(os.path.join(str(tmp_path), "step_00000003",
                           "manifest.json")) as f:
        man = json.load(f)
    assert man["extra"]["arch"] == "yi-6b"
    assert man["n_arrays"] == len(jax.tree.leaves(_tree()))


def test_missing_checkpoint_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        m.restore(_tree())
