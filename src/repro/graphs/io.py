"""Graph persistence (npz) + SNAP-format text ingestion.

The SNAP library's text format (``# comment`` header lines, one
``src\tdst`` pair per line) is supported so the framework can ingest the
paper's real datasets when run outside this container.
"""
from __future__ import annotations

import os

import numpy as np

from .formats import Graph, from_edges


def save_npz(g: Graph, path: str) -> None:
    tmp = path + ".tmp"
    np.savez_compressed(tmp, n=np.int64(g.n), edges=g.edges, name=g.name)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_npz(path: str) -> Graph:
    with np.load(path, allow_pickle=False) as z:
        return from_edges(z["edges"], n=int(z["n"]), name=str(z["name"]))


def load_snap_txt(path: str, name: str | None = None) -> Graph:
    """Parse a SNAP edge-list text file (comments start with '#')."""
    edges = np.loadtxt(path, dtype=np.int64, comments="#").reshape(-1, 2)
    return from_edges(edges, name=name or os.path.basename(path))


def save_snap_txt(g: Graph, path: str) -> None:
    with open(path, "w") as f:
        f.write(f"# {g.name}: n={g.n} m={g.m} (undirected, canonical)\n")
        np.savetxt(f, g.edges, fmt="%d\t%d")
