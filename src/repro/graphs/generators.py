"""Synthetic graph generators.

SNAP datasets are not available offline, so the benchmark suite generates
synthetic families with the structural properties that matter for the
paper's algorithms: heavy-tailed degree distributions (RMAT / Barabási–
Albert) that stress the "curse of the last reducer", Erdős–Rényi controls,
and planted-clique instances with known exact counts for validation.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .formats import Graph, from_edges, union


def complete_graph(n: int, name: Optional[str] = None) -> Graph:
    """K_n: exactly C(n,k) k-cliques — closed-form oracle."""
    idx = np.arange(n, dtype=np.int64)
    u, v = np.meshgrid(idx, idx, indexing="ij")
    mask = u < v
    return from_edges(np.stack([u[mask], v[mask]], 1), n=n,
                      name=name or f"K{n}")


def empty_graph(n: int) -> Graph:
    return from_edges(np.zeros((0, 2), np.int64), n=n, name=f"empty{n}")


def complete_bipartite(n1: int, n2: int,
                       name: Optional[str] = None) -> Graph:
    """K_{n1,n2}: triangle-free, so q_k = 0 for every k ≥ 3 while the
    degrees (and the planner's capacity classes) stay substantial — the
    adversarial zero-count case for estimators and their confidence
    intervals."""
    u = np.repeat(np.arange(n1, dtype=np.int64), n2)
    v = n1 + np.tile(np.arange(n2, dtype=np.int64), n1)
    return from_edges(np.stack([u, v], 1), n=n1 + n2,
                      name=name or f"K{n1}_{n2}")


def erdos_renyi(n: int, p: float, seed: int = 0,
                name: Optional[str] = None) -> Graph:
    """G(n, p) via per-pair Bernoulli on the upper triangle."""
    rng = np.random.default_rng(seed)
    idx = np.arange(n, dtype=np.int64)
    u, v = np.meshgrid(idx, idx, indexing="ij")
    mask = (u < v) & (rng.random((n, n)) < p)
    return from_edges(np.stack([u[mask], v[mask]], 1), n=n,
                      name=name or f"er_n{n}_p{p}")


def erdos_renyi_m(n: int, m: int, seed: int = 0,
                  name: Optional[str] = None) -> Graph:
    """G(n, m): exactly m distinct edges, uniform over edge sets.

    Resamples until m distinct non-loop pairs have been seen (a fixed
    1.3× oversample can dedup below m on dense targets), then keeps a
    uniform m-subset, so ``g.m == m`` always. Raises for m > C(n, 2).
    """
    max_m = n * (n - 1) // 2
    if m > max_m:
        raise ValueError(f"m={m} exceeds C({n},2)={max_m} distinct edges")
    rng = np.random.default_rng(seed)
    if 4 * m >= max_m:
        # dense target: rejection mixes slowly, but materializing all
        # C(n,2) pairs is only O(m) here — take a uniform m-subset.
        lo, hi = np.triu_indices(n, 1)
        pick = rng.choice(max_m, size=m, replace=False)
        keys = lo[pick].astype(np.int64) * n + hi[pick]
    else:
        keys = np.zeros(0, dtype=np.int64)  # canonical lo*n+hi, dedup'd
        while len(keys) < m:
            batch = max(64, 2 * (m - len(keys)))
            u = rng.integers(0, n, size=batch, dtype=np.int64)
            v = rng.integers(0, n, size=batch, dtype=np.int64)
            ok = u != v
            lo = np.minimum(u, v)[ok]
            hi = np.maximum(u, v)[ok]
            keys = np.union1d(keys, lo * np.int64(n) + hi)
        if len(keys) > m:
            keys = rng.choice(keys, size=m, replace=False)
    g = from_edges(np.stack([keys // n, keys % n], 1), n=n,
                   name=name or f"er_n{n}_m{m}")
    assert g.m == m
    return g


def barabasi_albert(n: int, attach: int, seed: int = 0,
                    name: Optional[str] = None) -> Graph:
    """Preferential attachment: heavy-tailed degrees, many cliques."""
    rng = np.random.default_rng(seed)
    targets = list(range(attach))
    repeated: list[int] = []
    src_all, dst_all = [], []
    for v in range(attach, n):
        for t in targets:
            src_all.append(v)
            dst_all.append(t)
        repeated.extend(targets)
        repeated.extend([v] * attach)
        # next targets: preferential sample from the degree-weighted list
        targets = [repeated[i] for i in
                   rng.integers(0, len(repeated), size=attach)]
    e = np.stack([np.array(src_all, np.int64), np.array(dst_all, np.int64)], 1)
    return from_edges(e, n=n, name=name or f"ba_n{n}_k{attach}")


def rmat(scale: int, edge_factor: int = 8,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         seed: int = 0, name: Optional[str] = None) -> Graph:
    """R-MAT power-law generator (Graph500 parameters by default).

    n = 2**scale nodes, ~edge_factor * n undirected edges after dedup.
    Produces the skewed high-neighborhood distributions of web/social
    graphs (webBerkStan / asSkitter analogues at reduced scale).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        # quadrant choice: (0,0) w.p. a, (0,1) w.p. b, (1,0) w.p. c, (1,1) d
        right = (r >= a) & (r < ab) | (r >= abc)
        down = r >= ab
        src = (src << 1) | down.astype(np.int64)
        dst = (dst << 1) | right.astype(np.int64)
    # scramble labels so locality doesn't correlate with degree
    perm = rng.permutation(n).astype(np.int64)
    return from_edges(np.stack([perm[src], perm[dst]], 1), n=n,
                      name=name or f"rmat_s{scale}_e{edge_factor}")


def planted_cliques(n_background: int, p_background: float,
                    clique_sizes: list[int], seed: int = 0,
                    name: Optional[str] = None) -> Graph:
    """Sparse ER background with vertex-disjoint planted cliques appended
    as fresh nodes. With a sufficiently sparse background the planted
    cliques dominate counts for k >= 4; exact counts remain verifiable by
    the brute-force oracle at test scale.
    """
    g = erdos_renyi(n_background, p_background, seed=seed)
    for i, s in enumerate(clique_sizes):
        g = union(g, complete_graph(s), name="planted")
    return Graph(n=g.n, edges=g.edges, degrees=g.degrees,
                 name=name or f"planted_{clique_sizes}")


def random_graph_for_tests(seed: int, max_n: int = 48,
                           density: Optional[float] = None) -> Graph:
    """Small random graph for property tests (oracle-checkable)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, max_n))
    p = density if density is not None else float(rng.uniform(0.05, 0.6))
    return erdos_renyi(n, p, seed=seed + 1, name=f"test_s{seed}")


def conformance_corpus() -> list[Graph]:
    """The fixed generator corpus behind the cross-backend conformance
    suite and the golden-count fixture (`tests/fixtures/golden_counts.json`,
    regenerated by `scripts/regen_golden.py`). Seeds are pinned: changing
    any entry invalidates the checked-in golden counts.

    Small enough that the brute-force oracle covers every pinned k, but
    spanning the structures that stress different code paths: closed-form
    K_n, ER controls (both G(n,p) and exact-m), heavy-tailed BA, planted
    cliques whose counts the background can't mask, a triangle-free
    bipartite graph (q_k = 0 for k ≥ 3 — the estimator's zero-count CI
    case), and a larger planted-clique instance whose exact k=5 count is
    expensive enough that the adaptive estimator's sampled path must
    genuinely engage (it is the benchmark graph for
    benchmarks/estimator_accuracy.py).
    """
    return [
        complete_graph(10),
        erdos_renyi(48, 0.25, seed=11),
        erdos_renyi_m(40, 120, seed=7),
        barabasi_albert(64, 6, seed=3),
        planted_cliques(32, 0.08, [6, 7], seed=5,
                        name="planted_32_6_7"),
        complete_bipartite(12, 12),
        planted_cliques(1200, 0.02, [12, 16, 40], seed=9,
                        name="planted_1200_12_16_40"),
    ]


# --- the benchmark suite: scaled analogues of the paper's Figure 1 ----------

def paper_suite(scale_shift: int = 0) -> list[Graph]:
    """Three graphs echoing webBerkStan / asSkitter / liveJournal roles:
    a dense-web-like RMAT (high clustering, heavy tail), a sparser
    skitter-like RMAT, and a larger BA graph. scale_shift grows them.
    """
    return [
        rmat(12 + scale_shift, edge_factor=16, a=0.65, b=0.15, c=0.15,
             seed=7, name="webBerk-like"),
        rmat(13 + scale_shift, edge_factor=8, seed=11, name="skitter-like"),
        barabasi_albert(6000 * (1 << scale_shift), attach=12, seed=13,
                        name="lj-like"),
    ]
