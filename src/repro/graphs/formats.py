"""Graph containers and canonicalization.

The framework's graph substrate keeps graphs on the host as numpy arrays
(construction, planning) and moves dense padded batches to the device at
compute boundaries. A :class:`Graph` stores each undirected edge exactly
once in canonical (min_label, max_label) form; parallel edges and self
loops are removed at construction, matching the paper's preprocessing
("we preprocessed all graphs so that they are undirected ... each edge
endpoint is associated with its degree").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph as a canonical edge list.

    Attributes:
      n: number of nodes (labels are 0..n-1; isolated nodes allowed).
      edges: (m, 2) int64, canonicalized u < v, lexicographically sorted,
        deduplicated, no self loops.
      degrees: (n,) int64 — degree of each node (precomputed, as the paper
        assumes: "each edge contains the information relative to the
        degrees of its endpoints").
      name: optional human-readable name for benchmark tables.
    """

    n: int
    edges: np.ndarray
    degrees: np.ndarray
    name: str = "graph"

    @property
    def m(self) -> int:
        return int(self.edges.shape[0])

    def __post_init__(self):
        assert self.edges.ndim == 2 and self.edges.shape[1] == 2
        assert self.degrees.shape == (self.n,)

    def storage_mb(self) -> float:
        """Uncompressed storage as the paper's Figure 1 reports (both
        directions of each edge, as text is approximated by 2 int64)."""
        return 2 * self.m * 2 * 8 / 1e6

    def adjacency_sets(self):
        """Host-side adjacency sets (for oracles / tiny graphs only)."""
        adj = [set() for _ in range(self.n)]
        for u, v in self.edges:
            adj[int(u)].add(int(v))
            adj[int(v)].add(int(u))
        return adj


def from_edges(edges, n: Optional[int] = None, name: str = "graph") -> Graph:
    """Canonicalize an arbitrary (possibly directed / duplicated / self-loop)
    edge array into a :class:`Graph`.
    """
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if e.size == 0:
        n = int(n or 0)
        return Graph(n=n, edges=np.zeros((0, 2), np.int64),
                     degrees=np.zeros((n,), np.int64), name=name)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    keep = lo != hi  # drop self loops
    lo, hi = lo[keep], hi[keep]
    if n is None:
        n = int(hi.max()) + 1 if hi.size else 0
    # dedup via sort over composite key
    key = lo * np.int64(n) + hi
    order = np.argsort(key, kind="stable")
    key = key[order]
    uniq = np.ones(key.shape[0], dtype=bool)
    uniq[1:] = key[1:] != key[:-1]
    lo, hi = lo[order][uniq], hi[order][uniq]
    edges2 = np.stack([lo, hi], axis=1)
    degrees = np.bincount(edges2.reshape(-1), minlength=n).astype(np.int64)
    return Graph(n=int(n), edges=edges2, degrees=degrees, name=name)


def relabel(g: Graph, perm: np.ndarray, name: Optional[str] = None) -> Graph:
    """Apply a node permutation (new_label = perm[old_label]).

    Clique counts are invariant under relabeling — used by property tests.
    """
    perm = np.asarray(perm, dtype=np.int64)
    assert perm.shape == (g.n,)
    e = perm[g.edges]
    return from_edges(e, n=g.n, name=name or (g.name + "+relabel"))


def subgraph(g: Graph, nodes: np.ndarray, name: Optional[str] = None) -> Graph:
    """Node-induced subgraph, relabeled to 0..len(nodes)-1."""
    nodes = np.asarray(nodes, dtype=np.int64)
    inv = -np.ones(g.n, dtype=np.int64)
    inv[nodes] = np.arange(len(nodes), dtype=np.int64)
    src, dst = inv[g.edges[:, 0]], inv[g.edges[:, 1]]
    keep = (src >= 0) & (dst >= 0)
    return from_edges(np.stack([src[keep], dst[keep]], 1), n=len(nodes),
                      name=name or (g.name + "+induced"))


def union(a: Graph, b: Graph, name: str = "union") -> Graph:
    """Disjoint union of two graphs (labels of b shifted by a.n)."""
    eb = b.edges + a.n
    return from_edges(np.concatenate([a.edges, eb], 0), n=a.n + b.n, name=name)
