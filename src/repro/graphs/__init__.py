"""Graph substrate: containers, generators, io, degree computation."""
from .formats import Graph, from_edges, relabel, subgraph, union
from .generators import (barabasi_albert, complete_bipartite,
                         complete_graph, conformance_corpus, empty_graph,
                         erdos_renyi, erdos_renyi_m, paper_suite,
                         planted_cliques, random_graph_for_tests, rmat)
from .io import load_npz, load_snap_txt, save_npz, save_snap_txt

__all__ = [
    "Graph", "from_edges", "relabel", "subgraph", "union",
    "barabasi_albert", "complete_bipartite", "complete_graph",
    "conformance_corpus",
    "empty_graph", "erdos_renyi", "erdos_renyi_m", "paper_suite",
    "planted_cliques",
    "random_graph_for_tests", "rmat",
    "load_npz", "load_snap_txt", "save_npz", "save_snap_txt",
]
