"""Degree computation, MapReduce-style, on device.

The paper treats degree computation as a cheap preprocessing round
("it is well known that it can be done very easily and quickly in
MapReduce"). Here it is a scatter-add (`segment_sum`), and the
distributed variant is the same scatter-add per edge shard followed by a
`psum` over the workers axis — the moral equivalent of the MR combiner +
reducer pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


import functools


@functools.partial(jax.jit, static_argnums=(1,))
def degrees_from_edges(edges: jax.Array, n: int) -> jax.Array:
    """edges: (m, 2) int; returns (n,) int32 degree vector."""
    flat = edges.reshape(-1)
    return jnp.zeros((n,), jnp.int32).at[flat].add(1)


def degrees_sharded(edges_shard: jax.Array, n: int,
                    axis_name: str) -> jax.Array:
    """Per-shard scatter-add + all-reduce. Call inside shard_map."""
    local = jnp.zeros((n,), jnp.int32).at[edges_shard.reshape(-1)].add(
        jnp.where(edges_shard.reshape(-1) >= 0, 1, 0))
    return jax.lax.psum(local, axis_name)
