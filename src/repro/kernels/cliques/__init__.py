from .ops import dag_count_pallas, kernel_bytes, kernel_flops
from .ref import dag_count_ref

__all__ = ["dag_count_pallas", "dag_count_ref", "kernel_flops",
           "kernel_bytes"]
