"""Pallas TPU kernel: batched k-clique counting in oriented adjacencies.

This is the paper's round-3 reducer rebuilt for the MXU. Each grid step
loads a tile of TB adjacencies (TB, D, D) into VMEM and evaluates the
pivot/matmul identities:

  r=3 :  Σ (AᵀA) ∘ A                       — one D×D×D matmul on the MXU
  r=4 :  Σ_v Σ (BᵥᵀBᵥ) ∘ Bᵥ,  Bᵥ = A ∘ (A[v] ⊗ A[v])   — D matmuls
  r=5 :  two pivot levels                   — D² masked matmuls

Tiling: D is padded by the planner to a multiple of the 128-lane MXU
width; TB is chosen by ops.py so the working set (input tile + one D×D
temp + accumulator) stays within the VMEM budget. Counts accumulate in
f32 — exact for counts < 2²⁴ per subgraph-pivot, and the engine's
per-node totals are summed in f64 on the host. The f32 path is validated
against integer oracles in tests.

The kernel runs under ``interpret=True`` on CPU (this container) and
compiles to Mosaic on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _triangles_2d(a: jax.Array) -> jax.Array:
    """Increasing triangles of one D×D upper-tri adjacency: Σ (aᵀa) ∘ a."""
    m = jax.lax.dot_general(a, a, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return jnp.sum(m * a)


def _count_one(a: jax.Array, r: int) -> jax.Array:
    """r-clique count of a single D×D adjacency (recursion on pivots)."""
    if r == 2:
        return jnp.sum(a)
    if r == 3:
        return _triangles_2d(a)
    D = a.shape[0]

    def pivot(v, acc):
        row = jax.lax.dynamic_slice_in_dim(a, v, 1, axis=0)  # (1, D)
        bv = a * row * jnp.transpose(row)
        return acc + _count_one(bv, r - 1)

    return jax.lax.fori_loop(0, D, pivot, jnp.float32(0.0))


def _cliques_kernel(a_ref, out_ref, *, r: int):
    tb = a_ref.shape[0]

    def body(i, _):
        out_ref[i] = _count_one(a_ref[i], r)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


@functools.partial(jax.jit, static_argnames=("r", "tile_b", "interpret"))
def dag_count_kernel(A: jax.Array, r: int, tile_b: int,
                     interpret: bool = False) -> jax.Array:
    """pallas_call wrapper: A (B, D, D) f32 → (B,) f32 r-clique counts.

    B must be a multiple of tile_b (ops.py pads).
    """
    B, D, _ = A.shape
    assert B % tile_b == 0, (B, tile_b)
    grid = (B // tile_b,)
    return pl.pallas_call(
        functools.partial(_cliques_kernel, r=r),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_b, D, D), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(A)
