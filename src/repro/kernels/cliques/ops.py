"""Jitted public wrapper for the clique-counting kernel.

Chooses the batch tile so the VMEM working set fits, pads the batch, and
falls back to interpret mode off-TPU. VMEM budget: input tile TB·D²·4B
plus ~2 D×D f32 temps must fit in ~12 MB of the 16 MB VMEM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .kernel import dag_count_kernel

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def pick_tile(D: int) -> int:
    per_mat = D * D * 4
    tb = max(1, (VMEM_BUDGET_BYTES - 2 * per_mat) // per_mat)
    # power-of-two, capped: huge tiles don't help once the MXU is busy
    t = 1
    while t * 2 <= min(tb, 256):
        t *= 2
    return t


def dag_count_pallas(A: jax.Array, r: int) -> jax.Array:
    """(B, D, D) f32 strictly-upper-tri adjacencies → (B,) f32 counts."""
    B, D, _ = A.shape
    interpret = jax.default_backend() != "tpu"
    tb = pick_tile(D)
    pad = (-B) % tb
    if pad:
        A = jnp.concatenate(
            [A, jnp.zeros((pad, D, D), A.dtype)], axis=0)
    out = dag_count_kernel(A.astype(jnp.float32), r, tb,
                           interpret=interpret)
    return out[:B]


def kernel_flops(B: int, D: int, r: int) -> float:
    """Analytic FLOPs (for the roofline table)."""
    if r == 2:
        return float(B) * D * D
    if r == 3:
        return B * (2.0 * D ** 3 + 2.0 * D * D)
    return D * (B * 4.0 * D * D + kernel_flops(B, D, r - 1))


def kernel_bytes(B: int, D: int) -> float:
    """HBM traffic: one pass over the adjacencies + the counts."""
    return float(B) * D * D * 4 + B * 4
