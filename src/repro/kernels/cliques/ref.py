"""Pure-jnp oracle for the clique-counting kernel.

Identical math to ``repro.core.count.dag_count`` but kept separate so the
kernel test compares two independently-written implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dag_count_ref(A: jax.Array, r: int) -> jax.Array:
    """r-clique counts per batch element; A (B, D, D) strictly upper-tri."""
    if r == 2:
        return jnp.sum(A, axis=(1, 2))
    if r == 3:
        M = jnp.matmul(jnp.swapaxes(A, 1, 2), A)  # (AᵀA)
        return jnp.sum(M * A, axis=(1, 2))
    B, D, _ = A.shape
    out = jnp.zeros((B,), jnp.float32)
    for v in range(D):  # unrolled on purpose: the oracle favors clarity
        row = A[:, v, :]
        Bv = A * row[:, :, None] * row[:, None, :]
        out = out + dag_count_ref(Bv, r - 1)
    return out
