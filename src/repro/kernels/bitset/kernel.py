"""Pallas TPU kernel: bitset AND+popcount triangle counting (NI++ path).

The k=3 fast path of the engine — and the NI++ baseline's inner loop
([34]) — reduces to: for every oriented edge (i, j), |Γ⁺(i) ∩ Γ⁺(j)|.
With rows bit-packed into uint32 lanes this is pure VPU integer work
(AND + population_count), 32 adjacency entries per lane op, no MXU
involvement — the right trade for k=3 where the matmul identity wastes
multiplies on a 0/1 matrix.

Layout: (TB, D, W) uint32 row tiles in VMEM, W = D/32 words. Per grid
step the kernel loops rows i, ANDs row i against all rows, popcounts,
and dots the result with the *unpacked* indicator of row i (recovered
in-register from the packed row, no second input needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_row(row_bits: jax.Array, D: int) -> jax.Array:
    """(W,) uint32 → (D,) f32 indicator. In-register unpack."""
    W = row_bits.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    bits = (row_bits[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(W * 32)[:D].astype(jnp.float32)


def _bitset_kernel(bits_ref, out_ref, *, D: int):
    tb, _, W = bits_ref.shape

    def per_mat(b, _):
        mat = bits_ref[b]  # (D, W) uint32

        def per_row(i, acc):
            row = jax.lax.dynamic_slice_in_dim(mat, i, 1, axis=0)  # (1, W)
            inter = jnp.bitwise_and(mat, row)                      # (D, W)
            pc = jax.lax.population_count(inter)
            common = jnp.sum(pc.astype(jnp.float32), axis=1)       # (D,)
            ind = _unpack_row(row[0], D)                           # (D,)
            return acc + jnp.sum(common * ind)

        out_ref[b] = jax.lax.fori_loop(0, D, per_row, jnp.float32(0.0))
        return 0

    jax.lax.fori_loop(0, tb, per_mat, 0)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def triangles_bitset_kernel(bits: jax.Array, tile_b: int,
                            interpret: bool = False) -> jax.Array:
    """bits: (B, D, W) uint32 packed rows → (B,) f32 triangle counts."""
    B, D, W = bits.shape
    assert B % tile_b == 0
    return pl.pallas_call(
        functools.partial(_bitset_kernel, D=D),
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, D, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(bits)
