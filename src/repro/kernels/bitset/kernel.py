"""Pallas TPU kernel: packed AND+popcount clique counting, every r.

The round-3 reducer is pure 0/1 adjacency work, so the packed tile
pipeline hands this kernel (TB, D, W) uint32 row tiles (W = ⌈D/32⌉) and
it evaluates the pivot recursion without ever unpacking the matrix:

  r=2 :  Σ popcount(rows)                       — the packed edge count
  r=3 :  Σ_i Σ_j A[i,j]·popcount(row_i & row_j) — AND+popcount per edge
  r≥4 :  pivot v: rows AND row_v, select rows where bit i of row_v is
         set (recovered in-register — no second input), recurse

Everything is VPU integer work, 32 adjacency entries per lane op, no
MXU involvement — the right trade for small r (the matmul identity
wastes multiplies on a 0/1 matrix) and for huge capacities (the packed
tile is 32× smaller in VMEM, so the batch stays wide).

The kernel runs under ``interpret=True`` on CPU (this container) and
compiles to Mosaic on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_row(row_bits: jax.Array, D: int) -> jax.Array:
    """(W,) uint32 → (D,) f32 indicator. In-register unpack."""
    W = row_bits.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    bits = (row_bits[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(W * 32)[:D].astype(jnp.float32)


def _count_one_bits(mat: jax.Array, r: int, D: int) -> jax.Array:
    """r-clique count of one (D, W) packed adjacency."""
    if r == 2:
        return jnp.sum(jax.lax.population_count(mat).astype(jnp.float32))
    if r == 3:
        def edge_level(i, acc):
            row = jax.lax.dynamic_slice_in_dim(mat, i, 1, axis=0)  # (1, W)
            inter = jnp.bitwise_and(mat, row)                      # (D, W)
            common = jnp.sum(jax.lax.population_count(inter)
                             .astype(jnp.float32), axis=1)         # (D,)
            return acc + jnp.sum(common * _unpack_row(row[0], D))

        return jax.lax.fori_loop(0, D, edge_level, jnp.float32(0.0))

    def pivot(v, acc):
        row = jax.lax.dynamic_slice_in_dim(mat, v, 1, axis=0)      # (1, W)
        colmask = jnp.bitwise_and(mat, row)                        # (D, W)
        sel = _unpack_row(row[0], D) > 0.0                         # (D,)
        bv = jnp.where(sel[:, None], colmask, jnp.uint32(0))
        return acc + _count_one_bits(bv, r - 1, D)

    return jax.lax.fori_loop(0, D, pivot, jnp.float32(0.0))


def _profile_one_bits(mat: jax.Array, rmax: int, D: int) -> jax.Array:
    """Clique-size profile of one (D, W) packed adjacency: (rmax−1,) f32
    with entry j = number of (j+2)-cliques — the Pivoter-carried variant
    of :func:`_count_one_bits` (one traversal at depth rmax, every level
    prepends its own edge count; see ``repro.core.count.dag_profile``)."""
    edges = jnp.sum(jax.lax.population_count(mat).astype(jnp.float32))
    if rmax == 2:
        return edges[None]
    if rmax == 3:
        def edge_level(i, acc):
            row = jax.lax.dynamic_slice_in_dim(mat, i, 1, axis=0)  # (1, W)
            inter = jnp.bitwise_and(mat, row)                      # (D, W)
            common = jnp.sum(jax.lax.population_count(inter)
                             .astype(jnp.float32), axis=1)         # (D,)
            return acc + jnp.sum(common * _unpack_row(row[0], D))

        tri = jax.lax.fori_loop(0, D, edge_level, jnp.float32(0.0))
        return jnp.stack([edges, tri])

    def pivot(v, acc):
        row = jax.lax.dynamic_slice_in_dim(mat, v, 1, axis=0)      # (1, W)
        colmask = jnp.bitwise_and(mat, row)                        # (D, W)
        sel = _unpack_row(row[0], D) > 0.0                         # (D,)
        bv = jnp.where(sel[:, None], colmask, jnp.uint32(0))
        return acc + _profile_one_bits(bv, rmax - 1, D)

    sub = jax.lax.fori_loop(0, D, pivot, jnp.zeros(rmax - 2, jnp.float32))
    return jnp.concatenate([edges[None], sub])


def _bits_kernel(bits_ref, out_ref, *, r: int, D: int):
    tb = bits_ref.shape[0]

    def per_mat(b, _):
        out_ref[b] = _count_one_bits(bits_ref[b], r, D)
        return 0

    jax.lax.fori_loop(0, tb, per_mat, 0)


@functools.partial(jax.jit, static_argnames=("r", "tile_b", "interpret"))
def count_bits_kernel(bits: jax.Array, r: int, tile_b: int,
                      interpret: bool = False) -> jax.Array:
    """bits: (B, D, W) uint32 packed rows → (B,) f32 r-clique counts.

    B must be a multiple of tile_b (ops.py pads).
    """
    B, D, W = bits.shape
    assert B % tile_b == 0, (B, tile_b)
    return pl.pallas_call(
        functools.partial(_bits_kernel, r=r, D=D),
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, D, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=interpret,
    )(bits)


def _pbits_kernel(bits_ref, out_ref, *, rmax: int, D: int):
    tb = bits_ref.shape[0]

    def per_mat(b, _):
        out_ref[b] = _profile_one_bits(bits_ref[b], rmax, D)
        return 0

    jax.lax.fori_loop(0, tb, per_mat, 0)


@functools.partial(jax.jit, static_argnames=("rmax", "tile_b", "interpret"))
def profile_bits_kernel(bits: jax.Array, rmax: int, tile_b: int,
                        interpret: bool = False) -> jax.Array:
    """bits: (B, D, W) uint32 packed rows → (B, rmax−1) f32 clique-size
    profiles (column j = count of (j+2)-cliques).

    B must be a multiple of tile_b (ops.py pads).
    """
    B, D, W = bits.shape
    assert B % tile_b == 0, (B, tile_b)
    L = rmax - 1
    return pl.pallas_call(
        functools.partial(_pbits_kernel, rmax=rmax, D=D),
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, D, W), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L), jnp.float32),
        interpret=interpret,
    )(bits)


def triangles_bitset_kernel(bits: jax.Array, tile_b: int,
                            interpret: bool = False) -> jax.Array:
    """Back-compat alias: the original triangles-only entry point."""
    return count_bits_kernel(bits, 3, tile_b, interpret=interpret)
