from .ops import triangles_bitset
from .ref import pack_rows, triangles_bitset_ref

__all__ = ["triangles_bitset", "pack_rows", "triangles_bitset_ref"]
