from .ops import dag_count_bits_pallas, triangles_bitset
from .ref import pack_rows, triangles_bitset_ref, unpack_rows

__all__ = ["dag_count_bits_pallas", "pack_rows", "triangles_bitset",
           "triangles_bitset_ref", "unpack_rows"]
