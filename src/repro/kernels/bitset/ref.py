"""Pure-jnp oracle for the bitset kernel.

``pack_rows``/``unpack_rows`` here are written independently of the
engine's :func:`repro.core.extract.pack_adjacency` on purpose, so the
round-trip and conformance tests compare two implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_rows(A: jax.Array) -> jax.Array:
    """Pack (B, D, D) 0/1 adjacency into (B, D, W) uint32 bitset rows,
    W = ceil(D/32); bit j of word w in row i is A[i, 32w + j]."""
    B, D, _ = A.shape
    W = (D + 31) // 32
    pad = W * 32 - D
    a = jnp.pad(A, ((0, 0), (0, 0), (0, pad))).astype(jnp.uint32)
    a = a.reshape(B, D, W, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(a << shifts[None, None, None, :], axis=-1,
                   dtype=jnp.uint32)


def unpack_rows(bits: jax.Array, D: int) -> jax.Array:
    """Inverse of :func:`pack_rows`: (B, D, W) uint32 → (B, D, D) f32."""
    B, D_rows, W = bits.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    a = (bits[:, :, :, None] >> shifts) & jnp.uint32(1)
    return a.reshape(B, D_rows, W * 32)[:, :, :D].astype(jnp.float32)


def triangles_bitset_ref(A: jax.Array) -> jax.Array:
    """Increasing-triangle counts via AND+popcount on packed rows.

    For each directed pair (i, j) with A[i,j]=1: popcount(row_i & row_j)
    counts the common out-neighbors; strict upper-triangularity makes
    every common out-neighbor have index > j, so each triangle is counted
    once.
    """
    bits = pack_rows(A)
    inter = jnp.bitwise_and(bits[:, :, None, :], bits[:, None, :, :])
    pc = jax.lax.population_count(inter).astype(jnp.float32).sum(-1)
    return jnp.sum(pc * A, axis=(1, 2))
