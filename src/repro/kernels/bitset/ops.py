"""Jitted wrapper for the bitset triangle kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import triangles_bitset_kernel
from .ref import pack_rows

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def triangles_bitset(A: jax.Array) -> jax.Array:
    """(B, D, D) 0/1 f32 adjacencies → (B,) f32 triangle counts."""
    B, D, _ = A.shape
    bits = pack_rows(A)
    W = bits.shape[-1]
    per_mat = D * W * 4
    tb = max(1, min(256, VMEM_BUDGET_BYTES // max(per_mat, 1)))
    t = 1
    while t * 2 <= tb:
        t *= 2
    pad = (-B) % t
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((pad, D, W), bits.dtype)], axis=0)
    interpret = jax.default_backend() != "tpu"
    return triangles_bitset_kernel(bits, t, interpret=interpret)[:B]
