"""Jitted public wrappers for the packed bitset counting kernel.

Chooses the batch tile so the VMEM working set fits (packed tiles are
D·W·4 = D²/8 bytes per matrix — 32× smaller than the dense f32 kernel's,
so the batch stays wide even at D = 4096), pads the batch, and falls
back to interpret mode off-TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.extract import packed_words
from .kernel import count_bits_kernel, profile_bits_kernel
from .ref import pack_rows

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def pick_tile_bits(D: int) -> int:
    per_mat = D * packed_words(D) * 4
    tb = max(1, (VMEM_BUDGET_BYTES - 2 * per_mat) // max(per_mat, 1))
    # power-of-two, capped: huge tiles don't help once the VPU is busy
    t = 1
    while t * 2 <= min(tb, 256):
        t *= 2
    return t


def dag_count_bits_pallas(bits: jax.Array, r: int) -> jax.Array:
    """(B, D, W) uint32 packed adjacencies → (B,) f32 r-clique counts."""
    B, D, _ = bits.shape
    interpret = jax.default_backend() != "tpu"
    tb = pick_tile_bits(D)
    pad = (-B) % tb
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((pad,) + bits.shape[1:], bits.dtype)], axis=0)
    return count_bits_kernel(bits, r, tb, interpret=interpret)[:B]


def dag_profile_bits_pallas(bits: jax.Array, rmax: int) -> jax.Array:
    """(B, D, W) uint32 packed adjacencies → (B, rmax−1) f32 clique-size
    profiles (the one-pass all-k path). Same tiling/padding contract as
    :func:`dag_count_bits_pallas`; padded all-zero matrices contribute
    all-zero profile rows."""
    B, D, _ = bits.shape
    interpret = jax.default_backend() != "tpu"
    tb = pick_tile_bits(D)
    pad = (-B) % tb
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((pad,) + bits.shape[1:], bits.dtype)], axis=0)
    return profile_bits_kernel(bits, rmax, tb, interpret=interpret)[:B]


def dag_list_bits_pallas(bits: jax.Array, r: int, *, chunk: int,
                         start) -> tuple[jax.Array, jax.Array]:
    """Emit variant of :func:`dag_count_bits_pallas` — the packed
    listing path for the pallas backend.

    The pivot masking (row-broadcast AND + row-bit select) is the same
    packed recursion the count kernel runs, but per-clique emission is a
    dynamic-index scatter into a shared row buffer, which has no
    efficient Mosaic lowering today (a VMEM-compacting emit kernel is on
    the ROADMAP). So the enumeration itself runs as the XLA recursion
    from :func:`repro.core.count.dag_list_bits` on every backend; this
    wrapper only pins the pallas-path entry point; no batch padding is
    applied (the XLA recursion has no tile-shape constraint — the
    Mosaic kernel, when it lands, should pad with all-zero matrices,
    which contribute no cliques and leave stream positions intact).
    """
    from ...core.count import dag_list_bits
    return dag_list_bits(bits, r, chunk=chunk, start=start)


def triangles_bitset(A: jax.Array) -> jax.Array:
    """(B, D, D) 0/1 f32 adjacencies → (B,) f32 triangle counts (the
    original triangles-only entry point, now a pack + r=3 call).

    Analytic op/byte bookkeeping for this kernel lives with the shared
    identity: ``repro.core.count.dag_count_bits_ops`` /
    ``tile_unit_bytes`` — no duplicate copies here."""
    return dag_count_bits_pallas(pack_rows(A), 3)
