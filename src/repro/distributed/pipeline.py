"""Pipeline parallelism: GPipe-style stage loop over a mesh axis.

The layer stack is split into ``n_stages`` contiguous groups; every
stage's parameters live on one slice of the ``pipe`` axis, microbatches
flow stage-to-stage via `jax.lax.ppermute` inside a shard_map. The
schedule is the classic (n_micro + n_stages − 1)-tick loop: tick t feeds
microbatch t to stage 0 while stage s works on microbatch t−s; bubbles
at the edges are the usual GPipe cost, (S−1)/(M+S−1).

This is the optional cross-pod layout (stages over the `pod` axis) —
zero3/fsdp_seq remain the measured defaults; the test suite validates
numerical equivalence with the non-pipelined forward at smoke scale.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def pipeline_forward(block_fn, n_stages: int, n_micro: int,
                     mesh: Mesh, axis: str = "pod"):
    """Build a pipelined forward for a stacked-layer model.

    block_fn(stage_params, x) → x, where stage_params holds that stage's
    layers (leading axis L/n_stages). Returns fn(params_stacked, x) with
    params sharded stage-major over ``axis`` and x sharded over
    microbatches.

    params_stacked leaves: (L, ...) with L % n_stages == 0 — reshaped to
    (n_stages, L/n_stages, ...); x: (B, ...) with B % n_micro == 0.
    """
    assert mesh.shape[axis] == n_stages

    def fn(params, x):
        B = x.shape[0]
        mb = B // n_micro
        stages = jax.tree.map(
            lambda p: p.reshape((n_stages, p.shape[0] // n_stages)
                                + p.shape[1:]), params)

        def body(stage_params, xm):
            # stage_params: (1, L/S, ...) this stage's slice
            # xm: (n_micro, mb, ...) all microbatches, replicated view
            sp = jax.tree.map(lambda p: p[0], stage_params)
            idx = jax.lax.axis_index(axis)

            def tick(t, carry):
                buf, out = carry
                # stage s processes microbatch (t - s) when in range
                m = t - idx
                active = (m >= 0) & (m < n_micro)
                cur = jnp.where(
                    idx == 0,
                    xm[jnp.clip(m, 0, n_micro - 1)],
                    buf)
                res = block_fn(sp, cur)
                res = jnp.where(active, res, buf)
                # last stage banks its result; others pass it right
                out = jnp.where(
                    (idx == n_stages - 1) & active,
                    out.at[jnp.clip(m, 0, n_micro - 1)].set(res), out)
                nxt = jax.lax.ppermute(
                    res, axis, [(i, i + 1) for i in range(n_stages - 1)])
                return (nxt, out)

            # carries must inherit the pipe-varying type of the params
            # (see layers.vzeros): derive a varying zero from a leaf
            vz = (jax.tree.leaves(sp)[0].reshape(-1)[0] * 0) \
                .astype(xm.dtype)
            buf0 = jnp.zeros_like(xm[0]) + vz
            out0 = jnp.zeros_like(xm) + vz
            _, out = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick,
                                       (buf0, out0))
            # out is stage-varying; the caller slices the last stage's
            # block (claiming replication statically is not possible)
            return out

        xm = x.reshape((n_micro, mb) + x.shape[1:])
        pspec = jax.tree.map(lambda _: P(axis), stages)
        from ..core.compat import shard_map
        run = shard_map(body, mesh=mesh,
                        in_specs=(pspec, P()), out_specs=P(axis))
        out = run(stages, xm)           # (S·n_micro, mb, ...)
        out = out[(n_stages - 1) * n_micro:]   # last stage's block
        return out.reshape(x.shape)

    return fn


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead — the napkin number for §Perf decisions."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
