"""Sharding rules: parameters (FSDP × TP), batches, caches, optimizer.

The rules are *derived from the mesh at call time* — nothing is baked to
a device count — which is what makes the framework elastic: the same
checkpoint restores onto any mesh by re-running these rules and
device_put-ing with the new shardings.

Parameter rule (per 2-D+ leaf): the dimension matching a known
tensor-parallel size goes to ``model``; the largest remaining dimension
divisible by the fsdp axis goes to ``data`` (ZeRO-3-style parameter
sharding; optimizer moments inherit it, giving ZeRO-1/2 for free).
1-D leaves (norm scales, biases) stay replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ParallelConfig


def _tp_dims(cfg: ModelConfig) -> set[int]:
    """Sizes that identify a tensor-parallel dimension of a weight."""
    dims = {cfg.d_ff, cfg.padded_vocab, cfg.moe_d_ff,
            cfg.n_heads * cfg.hd, cfg.n_kv_heads * cfg.hd, cfg.dinner,
            cfg.moe_d_ff * max(cfg.n_shared_experts, 1)}
    if cfg.use_mla:
        dims |= {cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim),
                 cfg.n_heads * cfg.qk_nope_dim,
                 cfg.n_heads * cfg.v_head_dim}
    if cfg.family == "ssm" or cfg.hybrid:
        dims |= {2 * cfg.dinner + 2 * cfg.ssm_state + cfg.n_ssm_heads,
                 cfg.dinner + 2 * cfg.ssm_state}
    dims.discard(0)
    return dims


def param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, par: ParallelConfig) -> P:
    """Choose a PartitionSpec for one parameter leaf."""
    tp = par.tp_axis if par.tp_axis in mesh.axis_names else None
    fsdp = par.fsdp_axis if par.fsdp_axis in mesh.axis_names else None
    tp_size = mesh.shape[tp] if tp else 1
    fsdp_size = mesh.shape[fsdp] if fsdp else 1
    if len(shape) < 2:
        return P()
    # stacked layer leaves carry a leading L axis — never shard it
    offset = 1 if path.startswith("layers") or ".layers" in path else 0
    dims = list(shape[offset:])
    spec: list = [None] * len(shape)
    tp_dims = _tp_dims(cfg)
    # MoE expert tensors: experts axis is the natural EP/TP axis
    if cfg.n_experts and len(dims) >= 2 and dims[0] == cfg.n_experts:
        if tp and cfg.n_experts % tp_size == 0:
            spec[offset] = tp
            # FSDP the largest remaining dim
            rest = [(d, i) for i, d in enumerate(dims[1:], start=1)]
            for d, i in sorted(rest, reverse=True):
                if fsdp and d % fsdp_size == 0:
                    spec[offset + i] = fsdp
                    break
            return P(*spec)
    # Prefer a tp dim that is NOT d_model: llama-style archs have
    # n_heads·head_dim == d_model, and matching the contraction dim
    # would put tensor parallelism on the wrong side of the matmul.
    tp_at: Optional[int] = None
    candidates = [i for i, d in enumerate(dims)
                  if tp and d in tp_dims and d % tp_size == 0
                  and d != cfg.d_model]
    if candidates:
        tp_at = candidates[-1]
    if tp_at is not None:
        spec[offset + tp_at] = tp
    for d, i in sorted(((d, i) for i, d in enumerate(dims)
                        if i != tp_at), reverse=True):
        if fsdp and d % fsdp_size == 0:
            spec[offset + i] = fsdp
            break
    return P(*spec)


def _paths(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out[k] = _paths(v, f"{prefix}/{k}" if prefix else k)
        return out
    return prefix


def param_shardings(abstract, cfg: ModelConfig, mesh: Mesh,
                    par: ParallelConfig):
    """NamedSharding pytree matching an abstract param pytree."""
    paths = _paths(abstract)

    def leaf(path, leaf_aval):
        return NamedSharding(
            mesh, param_spec(path, leaf_aval.shape, cfg, mesh, par))

    return jax.tree.map(leaf, paths, abstract)


def batch_sharding(mesh: Mesh, par: ParallelConfig, global_batch: int):
    """Batch dim over dp axes (dropping axes that don't divide)."""
    axes = [a for a in par.dp_axes if a in mesh.axis_names]
    use: list[str] = []
    prod = 1
    for a in axes:
        if global_batch % (prod * mesh.shape[a]) == 0:
            use.append(a)
            prod *= mesh.shape[a]
    dp = tuple(use) if use else None

    def leaf_spec(leaf_aval):
        return NamedSharding(
            mesh, P(dp, *([None] * (len(leaf_aval.shape) - 1))))

    return leaf_spec


def cache_shardings(abstract_cache, cfg: ModelConfig, mesh: Mesh,
                    par: ParallelConfig, global_batch: int):
    """Decode caches: (L, B, C, ...) — batch over dp, seq/capacity over tp
    (works for every kv-head count, unlike head sharding)."""
    dpfn = batch_sharding(mesh, par, global_batch)
    dp = dpfn(jax.ShapeDtypeStruct((global_batch,), np.float32)).spec[0]
    tp = par.tp_axis if par.tp_axis in mesh.axis_names else None
    tpsz = mesh.shape[tp] if tp else 1

    def leaf(x):
        if x.ndim >= 4 and x.shape[2] % tpsz == 0 and x.shape[2] >= tpsz:
            # (L, B, C, ...) KV/latent caches: shard capacity over tp
            return NamedSharding(
                mesh, P(None, dp, tp, *([None] * (x.ndim - 3))))
        if x.ndim >= 3:
            return NamedSharding(
                mesh, P(None, dp, *([None] * (x.ndim - 2))))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf, abstract_cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
