"""Gradient compression: int8 quantized all-reduce with error feedback.

A DP-bandwidth trick for interconnect-bound scales: gradients are
quantized per-leaf to int8 with a per-leaf scale, all-reduced in int8
(4× fewer bytes on the wire than f32, 2× vs bf16), dequantized, and the
quantization error is carried into the next step (error feedback keeps
the scheme convergent — the residual is *added* to the next gradient
before quantization).

This path is explicit `shard_map` over the dp axis (pjit autodiff hides
the all-reduce, so we take manual control where the bytes matter).
Tests verify (1) exact error-feedback bookkeeping and (2) end-to-end
training parity within tolerance on a smoke config.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis: str):
    """Per-leaf error-feedback int8 all-reduce. Call inside shard_map.

    Returns (reduced_grads_f32, new_residuals)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = quantize_int8(g)
        local_deq = dequantize_int8(q, s)
        new_r = g - local_deq
        # int8 wire format: reduce the quantized payload; scales are
        # per-shard so reduce the dequantized-but-int8-rounded values.
        reduced = jax.lax.psum(local_deq, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return reduced / n, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def make_compressed_dp_step(cfg, oc, mesh, axis: str = "data",
                            remat: str = "none"):
    """Data-parallel train step with int8 error-feedback gradient
    all-reduce, as a shard_map over ``axis``. Params/opt-state are
    replicated; the batch is sharded on its leading dim."""
    from ..models import forward_train
    from .optimizer import adamw_update

    def loss_fn(params, batch):
        return forward_train(cfg, params, batch, remat=remat)

    def sharded_step(params, opt_state, residuals, batch):
        (loss, mets), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, residuals = compressed_psum(grads, residuals, axis)
        loss = jax.lax.pmean(loss, axis)
        mets = jax.tree.map(lambda x: jax.lax.pmean(x, axis), mets)
        params, opt_state, onorm = adamw_update(oc, params, grads,
                                                opt_state)
        mets = dict(mets)
        mets.update(onorm)
        return params, opt_state, residuals, (loss, mets)

    from ..core.compat import shard_map_unchecked
    step = shard_map_unchecked(
        sharded_step, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()))
    return jax.jit(step)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes_saved(params) -> dict:
    """Napkin math for EXPERIMENTS §Perf: per-step all-reduce bytes."""
    n = sum(p.size for p in jax.tree.leaves(params))
    return {"f32_bytes": 4 * n, "int8_bytes": n, "ratio": 4.0}
