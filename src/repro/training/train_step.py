"""The jitted train step: loss → grads → AdamW, with optional gradient
accumulation (scan over microbatches) so huge global batches fit.

Overlap note: gradients are produced per-layer inside the backward scan;
with FSDP shardings XLA's latency-hiding scheduler overlaps the
reduce-scatter/all-gather pairs with the next layer's compute — we keep
the structure collective-friendly (one scan body, uniform shapes) rather
than hand-scheduling. The int8-compressed DP variant lives in
``training/compression.py``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import forward_train
from ..models.layers import NO_SHARD, ShardCtx
from .optimizer import OptConfig, OptState, adamw_update


def make_train_step(cfg: ModelConfig, oc: OptConfig,
                    ctx: ShardCtx = NO_SHARD, remat: str = "full",
                    grad_accum: int = 1):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). Batch leaves have leading dim global_batch; with
    grad_accum > 1 they are reshaped to (A, B/A, ...) and scanned."""

    def loss_fn(params, microbatch):
        return forward_train(cfg, params, microbatch, ctx=ctx, remat=remat)

    def train_step(params, opt_state: OptState, batch: dict):
        if grad_accum == 1:
            (loss, mets), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            resh = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def micro(carry, mb):
                acc = carry
                (lv, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (lv, m)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metss) = jax.lax.scan(micro, zero, resh)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = jnp.mean(losses)
            mets = jax.tree.map(jnp.mean, metss)
        params, opt_state, onorm = adamw_update(oc, params, grads,
                                                opt_state)
        mets = dict(mets)
        mets.update(onorm)
        mets["loss"] = loss
        return params, opt_state, mets

    return train_step
