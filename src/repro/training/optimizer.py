"""AdamW with global-norm clipping and cosine schedule (self-contained —
no optax in this container).

Moments inherit parameter shardings, so with the ZeRO-style rules in
``distributed.sharding`` the optimizer state is automatically sharded
over (fsdp × tp); there is no separate optimizer-partitioning machinery
to keep consistent.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    """step + first/second moments + f32 master weights.

    Model params live in bf16 (so FSDP gathers and grad reductions move
    half the bytes — §Perf iteration 3); the optimizer owns the f32
    master copy and re-casts after each update (standard mixed
    precision)."""
    step: jax.Array
    mu: dict
    nu: dict
    master: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros), master=master)


def schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    return oc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(oc: OptConfig, params, grads,
                 state: OptState) -> tuple[dict, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(oc, step)
    b1c = 1.0 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + oc.eps)
        if w.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * w
        w = w - lr * delta
        return w.astype(p.dtype), m, v, w

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_w = tdef.flatten_up_to(state.master)
    new = [upd(p, g, m, v, w) for p, g, m, v, w in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    params2 = tdef.unflatten([t[0] for t in new])
    mu2 = tdef.unflatten([t[1] for t in new])
    nu2 = tdef.unflatten([t[2] for t in new])
    master2 = tdef.unflatten([t[3] for t in new])
    return params2, OptState(step=step, mu=mu2, nu=nu2, master=master2), \
        {"grad_norm": gnorm, "lr": lr}
