"""Training loop: steps × (data → train_step) with checkpoint/restart.

The loop is deliberately boring — all cleverness lives below it. What it
guarantees:
  * restart-safety: (params, opt_state, pipeline state) checkpoint
    atomically every ``ckpt_every`` steps; `resume()` restores all three
    and the token stream replays identically (tested);
  * preemption handling: a `should_stop` callback (SIGTERM hook on real
    pods, injected flag in tests) triggers a final synchronous save;
  * metrics: scalar dict per step, appended to a JSONL file.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import PipelineState, SyntheticLM


class Trainer:
    def __init__(self, cfg, train_step: Callable, pipeline: SyntheticLM,
                 workdir: str, ckpt_every: int = 50, keep_n: int = 2,
                 batch_shardings=None):
        self.cfg = cfg
        self.train_step = train_step
        self.pipe = pipeline
        self.ckpt = CheckpointManager(os.path.join(workdir, "ckpt"),
                                      keep_n=keep_n)
        self.workdir = workdir
        self.ckpt_every = ckpt_every
        self.metrics_path = os.path.join(workdir, "metrics.jsonl")
        self.batch_shardings = batch_shardings
        os.makedirs(workdir, exist_ok=True)

    def _state_tree(self, params, opt_state):
        return {"params": params, "opt": opt_state,
                "pipe": {"seed": np.int64(self.pipe.state.seed),
                         "next_step": np.int64(self.pipe.state.next_step)}}

    def resume(self, params, opt_state, shardings=None):
        step = self.ckpt.latest_step()
        if step is None:
            return params, opt_state, 0
        tree, manifest = self.ckpt.restore(
            self._state_tree(params, opt_state), shardings=shardings)
        self.pipe.state = PipelineState(
            seed=int(tree["pipe"]["seed"]),
            next_step=int(tree["pipe"]["next_step"]))
        return tree["params"], tree["opt"], int(manifest["step"])

    def fit(self, params, opt_state, n_steps: int,
            start_step: int = 0,
            should_stop: Optional[Callable[[int], bool]] = None):
        mfile = open(self.metrics_path, "a")
        step = start_step
        for step in range(start_step, n_steps):
            batch = next(self.pipe)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            if self.batch_shardings is not None:
                batch = {k: jax.device_put(v, self.batch_shardings(v))
                         for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, mets = self.train_step(params, opt_state,
                                                      batch)
            mets = {k: float(np.asarray(v)) for k, v in mets.items()}
            mets["step"] = step
            mets["step_time_s"] = time.perf_counter() - t0
            mfile.write(json.dumps(mets) + "\n")
            mfile.flush()
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1,
                               self._state_tree(params, opt_state))
            if should_stop is not None and should_stop(step):
                self.ckpt.save(step + 1,
                               self._state_tree(params, opt_state),
                               block=True)
                break
        self.ckpt.wait()
        mfile.close()
        return params, opt_state, step + 1
