"""Accuracy-targeted adaptive estimation: the controller behind
``CountRequest(method="auto", rel_error=..., confidence=...)``.

The paper's headline claim for the sampling algorithms is "very accurate
solutions with high probability" — but SI_k/SIC_k make the *user* pick
the operating point (``p`` / ``colors``) blind. This module closes the
loop the way Kolda et al. do for wedge sampling: the caller states an
accuracy contract ("q_k within 5% relative error at 99% confidence") and
the controller finds the cheapest operating point that meets it.

How it works
------------
1. **Density certificates** — one cheap per-node edge count over the
   cached plan (the r=2 tile, reusing the session's executables) yields
   e_u = |E(G⁺(u))| for every work unit. That single number classifies
   each unit *before any sampling*: e_u = C(d_u,2) means the unit is a
   clique and its contribution C(d_u, k−1) is deterministic under
   neighborhood subsampling; e_u < C(k−1,2) means the unit cannot hold a
   single (k−1)-clique under any mask; everything else gets a rigorous
   per-node support bound from the Kruskal–Katona extremal count
   (max r-cliques in a graph with e edges).
2. **Pilot** — a few replicates at the coarsest operating point the
   certificates deem feasible (hopeless levels are skipped without
   running them). Replicates share compiled tile executables, so
   escalation recompiles nothing the session didn't already have.
3. **Confidence interval** — per-node sampling keys make per-node
   estimates independent across nodes *and* replicates, so per-node
   attribution is the replicate structure: ``Var(total) = Σ_u Var(X_u)``,
   estimated by per-node sample variance summed over nodes (thousands of
   degrees of freedom from a 2-replicate pilot). The half-width is an
   empirical-Bernstein bound

       hw = sqrt(2·V̂·L/R) + 3·M·L/max(R−1, 1),  L = ln(3/(1−confidence))

   where M is the *certified* support width — the largest Kruskal–Katona
   bound over the still-stochastic units, never the observed range. A
   zero-width interval therefore only happens when every unit is
   certified deterministic, in which case it is exact, not lucky.
4. **Escalation** — while the CI misses the target, the controller adds
   replicates when the projected count is small, else escalates
   geometrically: ``method="edge"`` doubles ``p`` toward 1,
   ``method="color"`` halves ``colors`` toward 1, and ``method="auto"``
   doubles the kept capacity of the subset estimator
   (:func:`repro.core.count.subset_tile_values` — SIC_k's smoothed
   coloring taken to its compute-saving conclusion, the only lever that
   shrinks the dense tile cost rather than just the variance).
5. **Exact fall-through** — before every spend the controller consults a
   work model; once the projected sampled work passes the exact plan
   cost (actual tile FLOPs for the subset lever; the paper's MRC
   round-3 volume shrink for the mask levers, whose dense tiles cost the
   same regardless of ``p``/``colors``), it runs the exact query instead
   and reports a zero-width interval. Tiny graphs and
   rare-count targets (rel_error · q_k below what any certificate can
   promise) resolve exact — "auto" degrades to correctness, never to a
   wrong bar.

Every query reports ``ci_low``/``ci_high``/``achieved_rel_error``/
``escalations`` plus an ``estimator`` telemetry dict on its
:class:`~repro.engine.CountReport`. See ``docs/estimator.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .core.count import (_subset_tile, _tile_batches, dag_count_flops,
                         pick_tile_repr, subset_unit_bytes)


@dataclasses.dataclass(frozen=True)
class EstimatorPolicy:
    """Controller knobs (engine-wide; requests carry only the target)."""
    default_rel_error: float = 0.05   # when method="auto" sets no target
    pilot_replicates: int = 2         # replicates per new operating point
    max_replicates_per_level: int = 24  # beyond this, escalate instead
    init_kept: int = 8                # subset lever: starting capacity
    init_p: float = 1.0 / 16.0        # edge lever: starting rate
    init_colors: int = 16             # color lever: starting color count
    max_escalations: int = 16         # hard cap → exact fall-through
    work_slack: float = 0.9           # sampled budget vs exact work


DEFAULT_POLICY = EstimatorPolicy()


# --------------------------------------------------------------------------
# certificates and the confidence interval
# --------------------------------------------------------------------------

def _falling_comb(n: np.ndarray, r: int) -> np.ndarray:
    """C(n, r) for float arrays via falling factorials, 0 where n < r."""
    out = np.ones_like(n, dtype=np.float64)
    for i in range(r):
        out *= np.maximum(n - i, 0.0)
    return out / math.factorial(r)


def kruskal_katona_bound(edges: np.ndarray, r: int) -> np.ndarray:
    """Max number of r-cliques in any graph with ``edges`` edges: the
    colex graphs are extremal, giving C(x, r) + C(j, r−1) for
    e = C(x, 2) + j, 0 ≤ j < x."""
    e = np.maximum(np.asarray(edges, np.float64), 0.0)
    x = np.floor((1.0 + np.sqrt(1.0 + 8.0 * e)) / 2.0)
    j = e - x * (x - 1.0) / 2.0
    return _falling_comb(x, r) + _falling_comb(j, r - 1)


def empirical_bernstein(X: np.ndarray, confidence: float, M: float
                        ) -> tuple[float, float, float]:
    """(estimate, half_width, V̂) for replicate matrix X of shape (R, n):
    R independent replicates of the n per-node estimates, with certified
    per-node support width ≤ M.

    The variance of the total is the sum of per-node variances (per-node
    keys decorrelate nodes), so V̂ pools (R−1) degrees of freedom from
    every node. The range term uses the *certified* width M, not the
    observed range — R lucky all-zero replicates of a rare-clique unit
    cannot fake a tight interval. M = 0 means every unit is certified
    deterministic and the interval honestly collapses to a point.
    """
    R = X.shape[0]
    est = float(X.sum(axis=1).mean())
    V = float(X.var(axis=0, ddof=1).sum()) if R > 1 else float("inf")
    L = math.log(3.0 / max(1.0 - confidence, 1e-12))
    if not np.isfinite(V):
        return est, float("inf"), V
    hw = math.sqrt(2.0 * V * L / R) + 3.0 * M * L / max(R - 1, 1)
    return est, hw, V


def _replicates_to_target(V: float, M: float, confidence: float,
                          target_hw: float) -> int:
    """Smallest R with sqrt(2VL/R) + 3ML/(R−1) ≤ target (solve the
    quadratic in 1/sqrt(R), then pay the −1 back)."""
    if target_hw <= 0.0 or not np.isfinite(V):
        return 1 << 30
    L = math.log(3.0 / max(1.0 - confidence, 1e-12))
    a, b = math.sqrt(2.0 * V * L), 3.0 * M * L
    root = (a + math.sqrt(a * a + 4.0 * target_hw * b)) / (2.0 * target_hw)
    return max(1, int(math.ceil(root * root)) + 1)


# --------------------------------------------------------------------------
# per-plan density certificates (cached on the PlanEntry)
# --------------------------------------------------------------------------

class _Certificates:
    """Per-unit (d_u, e_u) and what they certify for order r = k−1."""

    def __init__(self, deg: np.ndarray, edges: np.ndarray, in_plan:
                 np.ndarray, r: int) -> None:
        self.deg, self.edges, self.in_plan, self.r = deg, edges, in_plan, r
        need = r * (r - 1) / 2.0
        self.complete = in_plan & (edges >= deg * (deg - 1.0) / 2.0)
        self.zero = in_plan & (edges < need)
        self.stochastic = in_plan & ~self.complete & ~self.zero
        # deterministic structural lower bound on the true q_k: clique
        # units contribute exactly C(d, r), everything else ≥ 0
        self.det_lower = float(_falling_comb(deg[self.complete], r).sum())
        self.kk = np.zeros_like(deg)
        self.kk[self.stochastic] = kruskal_katona_bound(
            edges[self.stochastic], r)


def _certificates(eng, backend, entry, r: int,
                  choice: str = "auto") -> _Certificates:
    """Compute (once per plan entry per backend kind) each unit's
    out-neighborhood edge count via the exact r=2 tile — one extraction
    pass, no counting recursion — and derive the certificates.

    ``choice`` is the request's forced tile representation; the cached
    certificate *values* are representation-independent (both paths are
    bit-exact), so the cache key deliberately omits it."""
    from .engine.backends import tile_executable
    kind = backend.kind
    cache = entry._aux.setdefault("certificates", {})
    cert = cache.get((kind, r))
    if cert is not None:
        return cert
    n = eng.og.n
    edges = np.zeros(n, np.float64)
    in_plan = np.zeros(n, bool)
    for b in entry.plan.buckets:
        # r=2 is a pure popcount — the packed representation always wins
        # (unless the request forces dense)
        repr_ = pick_tile_repr(r=2, capacity=b.capacity, choice=choice,
                               elem_budget=backend.budget)
        fn = tile_executable(eng, kind, repr_, b.capacity, 2, "exact")
        for tile in _tile_batches(b.nodes, b.capacity, backend.budget,
                                  repr_):
            vals = np.asarray(jax.block_until_ready(
                fn(eng.csr, jnp.asarray(tile), jax.random.PRNGKey(0),
                   p=1.0, c=1)), np.float64)
            sel = tile >= 0
            np.add.at(edges, tile[sel], vals[sel])
            in_plan[tile[sel]] = True
    deg = eng.og.out_deg.astype(np.float64)
    cert = _Certificates(deg, edges, in_plan, r)
    cache[(kind, r)] = cert
    return cert


# --------------------------------------------------------------------------
# escalation levers
# --------------------------------------------------------------------------

class _SubsetLever:
    """method="auto": escalate the kept neighborhood capacity S. Units
    with |Γ⁺(u)| ≤ S are counted exactly (and cached across replicates
    and queries — they are key-independent); heavier units run only if
    the certificates left them stochastic — clique units contribute
    their known C(d, r) and zero-certified units nothing, so a replicate
    touches just the genuinely uncertain tail, at O((S/D)^{k−2}) of its
    exact tile cost. S ≥ max |Γ⁺(u)| is exact."""

    name = "subset"

    def __init__(self, eng, backend, entry, r: int, cert: _Certificates,
                 policy: EstimatorPolicy, choice: str = "auto") -> None:
        self.eng, self.backend, self.entry, self.r = eng, backend, entry, r
        self.kind = backend.kind
        self.cert = cert
        self.policy = policy
        self.choice = choice          # request-forced tile representation
        deg = eng.og.out_deg
        self.dmax = max((int(deg[b.nodes[b.nodes >= 0]].max())
                         for b in entry.plan.buckets if b.n_real), default=0)
        # per-bucket split of the heavy units: the certified-deterministic
        # per-node contribution (computed once, numpy) and the stochastic
        # node list a replicate actually has to sample — pure functions of
        # (plan, certificates, r), so cached on the entry across queries
        parts = entry._aux.get(("subset_parts", r))
        if parts is None:
            det_parts: dict[int, np.ndarray] = {}
            stoch_nodes: dict[int, np.ndarray] = {}
            det_all = np.zeros(eng.og.n, np.float64)
            det_all[cert.complete] = _falling_comb(
                cert.deg[cert.complete], r)
            for bi, b in enumerate(entry.plan.buckets):
                real = b.nodes[b.nodes >= 0]
                det = np.zeros(eng.og.n, np.float64)
                det[real] = det_all[real]
                det_parts[bi] = det
                stoch = real[cert.stochastic[real]].astype(np.int32)
                pad = (-len(stoch)) % 8
                stoch_nodes[bi] = np.concatenate(
                    [stoch, np.full(pad, -1, np.int32)])
            parts = entry._aux[("subset_parts", r)] = (det_parts,
                                                      stoch_nodes)
        self._det_parts, self._stoch_nodes = parts

    def levels(self, start: int) -> Iterator[int]:
        S = start
        while True:
            yield S
            S *= 2

    def start_level(self) -> int:
        """Never subsample below r kept neighbors: with S < r every
        r-clique is destroyed (a certified-zero lie, not an estimate),
        so deep-k queries start at the first power-of-two level that can
        still hold a clique."""
        S = self.policy.init_kept
        while S < self.r:
            S *= 2
        return S

    def is_exact(self, S: int) -> bool:
        return S >= self.dmax

    def width_bound(self, S: int) -> float:
        """Certified support width: only stochastic units with d > S are
        subsampled; their estimate is w·Y with Y ≤ the Kruskal–Katona
        count for min(C(S,2), e_u) edges. Clique units are deterministic
        under subsampling (every S-subset of a clique is a clique) and
        zero-certified units stay zero, so both have width 0."""
        c = self.cert
        sampled = c.stochastic & (c.deg > S)
        if not sampled.any():
            return 0.0
        d = c.deg[sampled]
        s = np.minimum(d, float(S))
        w = np.ones_like(d)
        for i in range(self.r):
            w *= np.maximum(d - i, 1.0) / np.maximum(s - i, 1.0)
        cap_e = np.minimum(s * (s - 1.0) / 2.0, c.edges[sampled])
        return float((w * kruskal_katona_bound(cap_e, self.r)).max())

    def _bucket_flops(self, cap: int, batch: int, S: int) -> float:
        S = min(cap, S)
        n_iters = self.eng.og.lookup_iters
        return (8.0 * batch * cap                     # score + select
                + 4.0 * batch * S * S * n_iters       # pair lookups
                + dag_count_flops(S, batch, self.r))  # count

    def cost(self, S: int) -> float:
        """Marginal per-replicate work: only the stochastic units of the
        heavy buckets run; the cap ≤ S exact parts are key-independent
        and cached after the first replicate (priced separately)."""
        return sum(self._bucket_flops(b.capacity,
                                      len(self._stoch_nodes[bi]), S)
                   for bi, b in enumerate(self.entry.plan.buckets)
                   if b.capacity > S)

    def fixed_cost(self, S: int) -> float:
        """One-off work at this level: exact tiles for buckets the cache
        doesn't hold yet."""
        exact_parts = self.entry._aux.setdefault("subset_exact", {})
        return sum(self._bucket_flops(b.capacity, b.batch, b.capacity)
                   for bi, b in enumerate(self.entry.plan.buckets)
                   if b.capacity <= S
                   and (self.kind, self.r, bi) not in exact_parts)

    def exact_work(self) -> float:
        return sum(self._bucket_flops(b.capacity, b.batch, b.capacity)
                   for b in self.entry.plan.buckets)

    def replicate(self, S: int, key: jax.Array) -> np.ndarray:
        from .engine.backends import tile_executable
        eng, r, kind = self.eng, self.r, self.kind
        exact_parts = self.entry._aux.setdefault("subset_exact", {})
        per_node = np.zeros(eng.og.n, np.float64)
        for bi, b in enumerate(self.entry.plan.buckets):
            if b.capacity <= S:
                part = exact_parts.get((kind, r, bi))
                if part is None:
                    part = np.zeros(eng.og.n, np.float64)
                    repr_ = pick_tile_repr(r=r, capacity=b.capacity,
                                           choice=self.choice,
                                           elem_budget=self.backend.budget)
                    fn = tile_executable(eng, kind, repr_, b.capacity, r,
                                         "exact")
                    for tile in _tile_batches(b.nodes, b.capacity,
                                              self.backend.budget, repr_):
                        _accumulate(part, fn(eng.csr, jnp.asarray(tile),
                                             key, p=1.0, c=1), tile)
                    exact_parts[(kind, r, bi)] = part
                per_node += part
            else:
                per_node += self._det_parts[bi]
                nodes = self._stoch_nodes[bi]
                if not len(nodes):
                    continue
                repr_ = "dense" if self.choice == "dense" else "bits"
                fn = eng.executables.get(
                    ("subset", kind, repr_, b.capacity, S, r),
                    lambda cap=b.capacity, S=S, repr_=repr_:
                    functools.partial(
                        _subset_tile, capacity=cap, kept=S,
                        n_iters=eng.og.lookup_iters, r=r, engine=kind,
                        tile_repr=repr_))
                # subset units never materialize D² — account the (S, S)
                # compacted tile + capacity-wide gather, not capacity²
                for tile in _tile_batches(
                        nodes, b.capacity, self.backend.budget,
                        unit_bytes=subset_unit_bytes(b.capacity, S)):
                    _accumulate(per_node,
                                fn(eng.csr, jnp.asarray(tile), key), tile)
        return per_node


class _MaskLever:
    """method="edge"/"color" with a rel_error target: escalate the
    method's own knob through the standard masked tile path. ``p`` and
    ``colors`` are traced, so every escalation reuses the session's
    compiled executables — escalation recompiles nothing. The dense tile
    cost does not shrink with the mask, so the work model prices
    replicates by the paper's MRC round-3 volume shrink (the quantity
    the sampling theorems actually buy) rather than by tile FLOPs."""

    def __init__(self, eng, backend, entry, req, cert: _Certificates,
                 policy: EstimatorPolicy) -> None:
        self.eng, self.backend, self.entry = eng, backend, entry
        self.req, self.cert, self.policy = req, cert, policy
        self.name = req.method
        self.r = req.k - 1

    def levels(self, start) -> Iterator[float]:
        if self.name == "edge":
            p = start
            while True:
                yield min(1.0, p)
                p *= 2.0
        else:
            c = start
            while True:
                yield max(1, c)
                c //= 2

    def start_level(self):
        return (self.policy.init_p if self.name == "edge"
                else self.policy.init_colors)

    def is_exact(self, level) -> bool:
        return level >= 1.0 if self.name == "edge" else level <= 1

    def _scale(self, level) -> float:
        """Largest per-node rescale factor the mask applies."""
        r = self.r
        if self.name == "edge":
            return float(level) ** -(r * (r - 1) / 2.0)
        return float(level) ** (r - 1)

    def width_bound(self, level) -> float:
        """Every non-zero-certified unit is stochastic under a mask
        (even a clique unit), with masked count ≤ its Kruskal–Katona
        bound and rescale ≤ the mask's scale."""
        c = self.cert
        live = c.stochastic | c.complete
        if not live.any():
            return 0.0
        kk = np.where(c.complete, _falling_comb(c.deg, self.r), c.kk)
        return float(kk[live].max()) * self._scale(level)

    def _factor(self, level) -> float:
        return float(level) if self.name == "edge" else 1.0 / float(level)

    def cost(self, level) -> float:
        return self.entry.plan.total_cost * self._factor(level)

    def fixed_cost(self, level) -> float:
        return 0.0

    def exact_work(self) -> float:
        return self.entry.plan.total_cost

    def replicate(self, level, key: jax.Array) -> np.ndarray:
        child = dataclasses.replace(
            self.req, rel_error=None, return_per_node=True,
            p=float(level) if self.name == "edge" else self.req.p,
            colors=int(level) if self.name == "color" else self.req.colors)
        _, per_node = self.backend.run(self.eng, self.entry, child, key)
        return per_node


def _accumulate(per_node: np.ndarray, vals, tile) -> None:
    vals = np.asarray(jax.block_until_ready(vals), np.float64)
    sel = tile >= 0
    np.add.at(per_node, tile[sel], vals[sel])


# --------------------------------------------------------------------------
# the controller
# --------------------------------------------------------------------------

def run_adaptive(eng, backend, entry, req,
                 policy: Optional[EstimatorPolicy] = None
                 ) -> tuple[float, Optional[np.ndarray], dict]:
    """Drive one accuracy-targeted query on an engine session. Returns
    ``(estimate, per_node, info)``; ``info`` carries the CI fields and
    controller telemetry the engine folds into the CountReport."""
    policy = policy or DEFAULT_POLICY
    if not isinstance(req.k, int):
        # CountRequest.validate rejects k="all" adaptive requests before
        # the engine dispatches here; keep the guard anyway so a caller
        # reaching the controller directly gets an answerable error, not
        # a type crash on r = k − 1 below
        raise ValueError('adaptive queries target one q_k; k="all" is '
                         "exact-only")
    if backend.name not in ("local", "pallas"):
        raise ValueError("adaptive (accuracy-targeted) queries need the "
                         "per-node replicate structure; use the local or "
                         "pallas backend")
    rel = req.rel_error if req.rel_error is not None \
        else policy.default_rel_error
    conf = req.confidence
    r = req.k - 1
    L = math.log(3.0 / max(1.0 - conf, 1e-12))
    cert = _certificates(eng, backend, entry, r, req.engine)
    if req.method == "auto":
        lever = _SubsetLever(eng, backend, entry, r, cert, policy,
                             req.engine)
    else:
        lever = _MaskLever(eng, backend, entry, req, cert, policy)
    exact_work = lever.exact_work()
    budget = policy.work_slack * exact_work
    base_key = jax.random.PRNGKey(req.seed)
    spent, esc, reps_total = 0.0, 0, 0
    stats = getattr(eng, "adaptive_stats", None)
    if stats is not None:
        stats["queries"] += 1

    def info(resolved: str, level, est: float, hw: float) -> dict:
        achieved = hw / max(abs(est), 1.0)
        if stats is not None:
            stats["escalations"] += esc
            stats["replicates"] += reps_total
            stats["sampled" if resolved == "sampled"
                  else "fallthroughs"] += 1
        return {
            "resolved": resolved, "lever": lever.name, "level": level,
            "ci_low": est - hw, "ci_high": est + hw,
            "achieved_rel_error": achieved, "escalations": esc,
            "replicates": reps_total, "rel_error_target": rel,
            "confidence": conf, "spent_work": spent,
            "exact_work": exact_work,
        }

    def fall_through() -> tuple[float, Optional[np.ndarray], dict]:
        child = dataclasses.replace(req, method="exact", rel_error=None)
        est, per_node = backend.run(eng, entry, child, base_key)
        return est, per_node, info("exact", None, est, 0.0)

    def run_replicate(X: list, level) -> None:
        nonlocal spent, reps_total
        key = jax.random.fold_in(base_key, reps_total)
        X.append(lever.replicate(level, key))
        reps_total += 1
        spent += lever.cost(level)

    # prescreen: the certificates' structural lower bound on q_k prices
    # each level's range floor before any replicate runs, so the pilot
    # starts at the coarsest level that could possibly certify the
    # target (only a *lower* bound on the estimate can be trusted here —
    # if nothing is certified, start coarse and let the pilot reveal it)
    start = lever.start_level()
    if cert.det_lower > 0.0:
        floor_target = rel * max(cert.det_lower, 1.0)
        for level in lever.levels(start):
            if lever.is_exact(level):
                break
            floor = 3.0 * lever.width_bound(level) * L \
                / max(policy.pilot_replicates - 1, 1)
            if floor <= floor_target:
                start = level
                break
            start = level  # remember the last pre-exact level

    for level in lever.levels(start):
        if esc >= policy.max_escalations or lever.is_exact(level):
            return fall_through()
        fixed = lever.fixed_cost(level)
        if spent + fixed + policy.pilot_replicates * lever.cost(level) \
                > budget:
            return fall_through()
        spent += fixed
        M = lever.width_bound(level)
        X: list[np.ndarray] = []
        for _ in range(policy.pilot_replicates):
            run_replicate(X, level)
        while True:
            est, hw, V = empirical_bernstein(np.stack(X), conf, M)
            if hw <= rel * max(abs(est), 1.0):
                per_node = (np.mean(np.stack(X), axis=0)
                            if req.return_per_node else None)
                return est, per_node, info("sampled", level, est, hw)
            need = _replicates_to_target(V, M, conf,
                                         rel * max(abs(est), 1.0))
            if need > policy.max_replicates_per_level:
                break                      # cheaper to escalate the lever
            extra = need - len(X)
            if extra <= 0:
                break
            if spent + extra * lever.cost(level) > budget:
                return fall_through()
            for _ in range(extra):
                run_replicate(X, level)
        esc += 1
    return fall_through()                  # not reached (levels infinite)
