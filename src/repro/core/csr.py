"""Oriented CSR + sorted edge-key table.

The oriented CSR is the materialized output of the paper's Round 1: for
each node u, the list Γ⁺(u), stored *sorted by rank* so that induced
adjacencies extracted later are strictly upper-triangular in local index
space. The sorted edge-key table (key = src·n + dst, rank-oriented)
replaces Round 2's shuffle-join with O(log m) vectorized binary search.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.formats import Graph
from .order import orient_edges, ranks


@dataclasses.dataclass(frozen=True)
class OrientedGraph:
    """Host-side oriented representation (device arrays are cut from it)."""

    n: int
    m: int
    node_ranks: np.ndarray    # (n,) int64 dense ≺ ranks
    rank_to_node: np.ndarray  # (n,) inverse permutation
    offsets: np.ndarray       # (n+1,) int32 CSR offsets, indexed by node id
    nbrs_rank: np.ndarray     # (m,) int32 out-neighbors, rank-sorted per row
    nbrs_byid: np.ndarray     # (m,) int32 out-neighbors, id-sorted per row
    out_deg: np.ndarray       # (n,) int64
    degrees: np.ndarray       # (n,) int64 undirected degrees

    @property
    def lookup_iters(self) -> int:
        """Binary-search iteration count covering the longest CSR row."""
        d = int(self.out_deg.max()) if self.n else 0
        return max(1, int(np.ceil(np.log2(max(d, 1) + 1))) + 1)

    def gamma_plus(self, u: int) -> np.ndarray:
        return self.nbrs_rank[self.offsets[u]:self.offsets[u + 1]]


def build_oriented(g: Graph) -> OrientedGraph:
    """Round 1, TPU-style: two lexsorts instead of a shuffle.

    The same CSR is stored twice: rank-sorted rows (so extracted induced
    adjacencies are strictly upper-triangular in local index space) and
    id-sorted rows (so Round 2's edge-existence join is a per-row binary
    search in pure int32 — no 64-bit packed keys, safe for any n < 2^31).
    """
    assert g.n < 2**31 and g.m < 2**31
    r = ranks(g.degrees)
    src, dst = orient_edges(g, r)
    order_rank = np.lexsort((r[dst], src))
    order_id = np.lexsort((dst, src))
    out_deg = np.bincount(src, minlength=g.n).astype(np.int64)
    offsets = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(out_deg, out=offsets[1:])
    inv = np.empty(g.n, dtype=np.int64)
    inv[r] = np.arange(g.n, dtype=np.int64)
    return OrientedGraph(n=g.n, m=g.m, node_ranks=r, rank_to_node=inv,
                         offsets=offsets.astype(np.int32),
                         nbrs_rank=dst[order_rank].astype(np.int32),
                         nbrs_byid=dst[order_id].astype(np.int32),
                         out_deg=out_deg, degrees=g.degrees.copy())
