"""Execution planning: degree buckets, cost model, worker partitioning.

Hadoop gives each reducer a ragged input; XLA wants static shapes. The
planner groups the "reduce 3" work units (one per node u with
|Γ⁺(u)| ≥ k−1) into power-of-two *capacity classes* and pads each unit to
its class capacity. Lemma 1 caps the largest class at 2√m.

The planner is also where the paper's "curse of the last reducer"
(Fig. 6) becomes a first-class feature: work units carry an analytic cost
(|Γ⁺(u)|^{k−1}, the paper's local-work bound), and the worker partitioner
does LPT-style balancing so the slowest shard is provably within a small
factor of the mean — the framework's straggler mitigation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .csr import OrientedGraph

DEFAULT_CAPACITIES = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A batch of same-capacity work units."""

    capacity: int        # D: padded |Γ⁺(u)| for every node in the bucket
    nodes: np.ndarray    # (B,) int32 node ids, -1 = padding
    n_real: int          # number of non-padding nodes

    @property
    def batch(self) -> int:
        return int(self.nodes.shape[0])


@dataclasses.dataclass(frozen=True)
class Plan:
    k: int
    buckets: tuple[Bucket, ...]
    n_units: int                 # eligible nodes (|Γ⁺| ≥ k−1)
    total_cost: float            # Σ |Γ⁺(u)|^{k−1}  (paper's work bound)
    pad_cost: float              # Σ D_u^{k−1} − total_cost (padding waste)
    max_capacity: int

    def cost_summary(self) -> dict:
        return {"n_units": self.n_units, "total_cost": self.total_cost,
                "pad_cost": self.pad_cost,
                "pad_frac": self.pad_cost / max(self.total_cost, 1.0),
                "buckets": [(b.capacity, b.n_real) for b in self.buckets]}


def unit_cost(out_deg: np.ndarray, k: int) -> np.ndarray:
    """Analytic cost of counting (k−1)-cliques in a D-node DAG: D^{k−1}.

    Matches both the paper's reduce-3 bound O(|Γ⁺(u)|^{k−1}) and the FLOP
    count of the matmul-pivot kernel (D³ for triangles, ×D per extra
    pivot level).
    """
    d = np.maximum(out_deg.astype(np.float64), 1.0)
    return d ** max(k - 1, 2)


def build_plan(og: OrientedGraph, k: int,
               capacities: Sequence[int] = DEFAULT_CAPACITIES,
               batch_align: int = 8,
               max_capacity: Optional[int] = None) -> Plan:
    """Assign every eligible node to the smallest capacity class ≥ |Γ⁺(u)|.

    Nodes larger than ``max_capacity`` stay in an oversized class created
    on the fly (the distributed engine instead reroutes them through the
    §6 split round).
    """
    assert k >= 3
    deg = og.out_deg
    eligible = np.nonzero(deg >= k - 1)[0].astype(np.int32)
    n_units = int(eligible.size)
    caps = sorted(set(int(c) for c in capacities))
    dmax = int(deg[eligible].max()) if n_units else 0
    while caps[-1] < dmax:
        caps.append(caps[-1] * 2)
    if max_capacity is not None:
        caps = [c for c in caps if c <= max_capacity] or [max_capacity]
    buckets = []
    total_cost = 0.0
    pad_cost = 0.0
    for i, cap in enumerate(caps):
        lo = caps[i - 1] if i > 0 else 0
        if max_capacity is not None and cap == caps[-1]:
            sel = eligible[deg[eligible] > lo]  # oversized units clamp here
        else:
            sel = eligible[(deg[eligible] > lo) & (deg[eligible] <= cap)]
        if sel.size == 0:
            continue
        # order by cost descending so tile-level batches are homogeneous
        sel = sel[np.argsort(-deg[sel], kind="stable")].astype(np.int32)
        pad = (-len(sel)) % batch_align
        nodes = np.concatenate([sel, np.full(pad, -1, np.int32)])
        buckets.append(Bucket(capacity=cap, nodes=nodes, n_real=len(sel)))
        total_cost += float(unit_cost(deg[sel], k).sum())
        pad_cost += float(len(sel) * float(cap) ** (k - 1)
                          - unit_cost(deg[sel], k).sum())
    return Plan(k=k, buckets=tuple(buckets), n_units=n_units,
                total_cost=total_cost, pad_cost=pad_cost,
                max_capacity=max(b.capacity for b in buckets) if buckets else 0)


@dataclasses.dataclass(frozen=True)
class DepthGroup:
    """A batch of same-capacity work units sharing a recursion depth —
    the all-k plan's unit of execution (one profile executable per
    (capacity, rmax))."""

    capacity: int
    rmax: int            # profile recursion depth for every unit here
    nodes: np.ndarray    # (B,) int32 node ids, -1 = padding

    @property
    def n_real(self) -> int:
        return int((self.nodes >= 0).sum())


def regroup_by_depth(plan: Plan, depth: np.ndarray,
                     batch_align: int = 8) -> tuple[DepthGroup, ...]:
    """Re-bucket a plan's units by (capacity, per-unit depth).

    ``depth[u]`` is the recursion depth unit ``u`` should run at (its
    certificate-clamped clique-number bound); units with depth < 3 are
    dropped — their whole contribution is host-computable from the edge
    certificate. Grouping by exact depth is what makes the one-pass
    profile cheaper than the deepest per-k pass: a bucket's light units
    never pay the heavy units' D^rmax recursion.
    """
    groups = []
    for b in plan.buckets:
        real = b.nodes[:b.n_real]
        du = depth[real]
        for r in sorted(int(x) for x in np.unique(du)):
            if r < 3:
                continue
            sel = real[du == r].astype(np.int32)
            pad = (-len(sel)) % batch_align
            nodes = np.concatenate([sel, np.full(pad, -1, np.int32)])
            groups.append(DepthGroup(capacity=b.capacity, rmax=r,
                                     nodes=nodes))
    return tuple(groups)


def partition_for_workers(plan: Plan, og: OrientedGraph,
                          n_workers: int) -> list[Plan]:
    """Split a plan into ``n_workers`` balanced sub-plans (LPT greedy).

    Every sub-plan has identical bucket capacities and batch sizes
    (padding with -1), so a `shard_map` over the workers axis sees fully
    static, identical shapes on every device — stragglers are prevented
    *by construction*, the planner's answer to the paper's Fig. 6.
    """
    per_worker_buckets: list[dict[int, list[np.ndarray]]] = [
        {} for _ in range(n_workers)]
    loads = np.zeros(n_workers, dtype=np.float64)
    for b in plan.buckets:
        real = b.nodes[:b.n_real]
        costs = unit_cost(og.out_deg[real], plan.k)
        order = np.argsort(-costs, kind="stable")  # LPT: heaviest first
        assign = [[] for _ in range(n_workers)]
        for idx in order:
            w = int(np.argmin(loads))
            assign[w].append(real[idx])
            loads[w] += costs[idx]
        width = max(len(a) for a in assign)
        width += (-width) % 8
        for w in range(n_workers):
            arr = np.full(width, -1, np.int32)
            arr[:len(assign[w])] = np.array(assign[w], np.int32)
            per_worker_buckets[w].setdefault(b.capacity, []).append(arr)
    plans = []
    for w in range(n_workers):
        bs = []
        for cap, arrs in sorted(per_worker_buckets[w].items()):
            nodes = np.concatenate(arrs) if arrs else np.zeros(0, np.int32)
            bs.append(Bucket(capacity=cap, nodes=nodes,
                             n_real=int((nodes >= 0).sum())))
        plans.append(Plan(k=plan.k, buckets=tuple(bs), n_units=plan.n_units,
                          total_cost=plan.total_cost, pad_cost=plan.pad_cost,
                          max_capacity=plan.max_capacity))
    return plans


def balance_report(plan: Plan, og: OrientedGraph, n_workers: int) -> dict:
    """Predicted straggler profile: per-worker analytic cost after LPT."""
    plans = partition_for_workers(plan, og, n_workers)
    loads = []
    for p in plans:
        tot = 0.0
        for b in p.buckets:
            real = b.nodes[b.nodes >= 0]
            tot += float(unit_cost(og.out_deg[real], plan.k).sum())
        loads.append(tot)
    loads = np.array(loads)
    mean = float(loads.mean()) if len(loads) else 0.0
    return {"n_workers": n_workers, "max": float(loads.max(initial=0.0)),
            "mean": mean,
            "imbalance": float(loads.max(initial=0.0) / mean) if mean else 1.0}
