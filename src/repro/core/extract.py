"""Round 2, TPU-native: batched induced-subgraph extraction.

Hadoop's round 2 shuffles every candidate 2-path ⟨(x,y); u⟩ to a reducer
that joins it against the edge set. On a TPU the join direction flips:
for a batch of nodes U we gather each Γ⁺(u) row from the oriented CSR and
answer all |Γ⁺(u)|² pair-existence queries with a vectorized binary
search over the id-sorted CSR rows (log₂ d̂ gathers). The output is a
strictly upper-triangular dense adjacency per node — the input the
counting kernel (round 3) consumes.

Everything here is int32 (safe for n, m < 2³¹) and static-shaped: the
plan's bucket capacity D and tile batch B are compile-time constants.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .csr import OrientedGraph


class DeviceCSR(NamedTuple):
    """Device-resident oriented CSR (the distributed engine shards or
    replicates these arrays; replication matches the paper's O(m) local
    space for round-3 reducers)."""
    offsets: jax.Array    # (n+1,) int32
    nbrs_rank: jax.Array  # (m,) int32 rank-sorted rows
    nbrs_byid: jax.Array  # (m,) int32 id-sorted rows
    out_deg: jax.Array    # (n,) int32


def to_device(og: OrientedGraph) -> DeviceCSR:
    return DeviceCSR(offsets=jnp.asarray(og.offsets, jnp.int32),
                     nbrs_rank=jnp.asarray(og.nbrs_rank, jnp.int32),
                     nbrs_byid=jnp.asarray(og.nbrs_byid, jnp.int32),
                     out_deg=jnp.asarray(og.out_deg, jnp.int32))


def edge_lookup(csr: DeviceCSR, x: jax.Array, y: jax.Array,
                n_iters: int) -> jax.Array:
    """Vectorized membership test: is y ∈ Γ⁺(x)? (oriented edge (x,y)).

    Per-query binary search over the id-sorted CSR row of x. ``n_iters``
    must cover the longest row (⌈log₂(d̂+1)⌉+1); extra iterations are
    no-ops because updates freeze once lo == hi.
    """
    m = csr.nbrs_byid.shape[0]
    xs = jnp.maximum(x, 0)
    lo = csr.offsets[xs]
    hi0 = csr.offsets[xs + 1]
    hi = hi0

    def body(_, lh):
        lo, hi = lh
        cont = lo < hi
        mid = (lo + hi) // 2
        v = csr.nbrs_byid[jnp.clip(mid, 0, m - 1)]
        go_right = v < y
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    found = (lo < hi0) & (csr.nbrs_byid[jnp.clip(lo, 0, m - 1)] == y)
    return found & (x >= 0) & (y >= 0)


@functools.partial(jax.jit, static_argnames=("capacity",))
def gather_neighbors(csr: DeviceCSR, nodes: jax.Array, *,
                     capacity: int) -> tuple[jax.Array, jax.Array]:
    """Γ⁺ rows for a node batch, padded to ``capacity`` with -1.

    Returns (nbrs (B, D) int32 rank-sorted, valid (B, D) bool).
    """
    m = csr.nbrs_rank.shape[0]
    valid_node = nodes >= 0
    safe = jnp.maximum(nodes, 0)
    start = csr.offsets[safe]
    deg = csr.offsets[safe + 1] - start
    col = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    in_row = (col < jnp.minimum(deg, capacity)[:, None]) & valid_node[:, None]
    idx = jnp.clip(start[:, None] + col, 0, max(m - 1, 0))
    nb = jnp.where(in_row, csr.nbrs_rank[idx], -1) if m else \
        jnp.full((nodes.shape[0], capacity), -1, jnp.int32)
    return nb, in_row


@functools.partial(jax.jit, static_argnames=("capacity", "n_iters"))
def extract_adjacency(csr: DeviceCSR, nodes: jax.Array, *, capacity: int,
                      n_iters: int) -> tuple[jax.Array, jax.Array]:
    """Dense oriented adjacency of G⁺(u) for each u in the batch.

    Returns (A (B, D, D) float32 strictly upper-triangular, nbrs (B, D)).
    A[b, i, j] = 1 iff edge (nbrs[b,i], nbrs[b,j]) exists; rank-sortedness
    of the rows makes A upper-triangular by construction, so the counting
    identities enumerate each clique exactly once as an increasing tuple.
    """
    nb, in_row = gather_neighbors(csr, nodes, capacity=capacity)
    D = capacity
    x = jnp.broadcast_to(nb[:, :, None], nb.shape + (D,))
    y = jnp.broadcast_to(nb[:, None, :], (nb.shape[0], D, D))
    tri = jnp.triu(jnp.ones((D, D), bool), 1)[None]
    found = edge_lookup(csr, jnp.where(tri, x, -1), y, n_iters)
    return (found & tri).astype(jnp.float32), nb


def packed_words(capacity: int) -> int:
    """uint32 words per packed adjacency row: W = ⌈D/32⌉."""
    return (capacity + 31) // 32


def pack_adjacency(A: jax.Array) -> jax.Array:
    """Pack a (B, D, D) 0/1 adjacency (bool or float) into (B, D, W)
    uint32 bitset rows; bit j of word w in row i is A[i, 32w + j]."""
    B, D, _ = A.shape
    W = packed_words(D)
    a = jnp.pad(A.astype(bool), ((0, 0), (0, 0), (0, W * 32 - D)))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(jnp.where(a.reshape(B, D, W, 32),
                             jnp.uint32(1) << shifts, jnp.uint32(0)),
                   axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("capacity", "n_iters"))
def extract_adjacency_bits(csr: DeviceCSR, nodes: jax.Array, *,
                           capacity: int, n_iters: int
                           ) -> tuple[jax.Array, jax.Array]:
    """Packed oriented adjacency of G⁺(u) for each u in the batch.

    Returns (bits (B, D, W) uint32, nbrs (B, D) int32): bit j of word w
    in row i is the edge (nbrs[b,i], nbrs[b,32w+j]).

    Unlike :func:`extract_adjacency`, the dense (B, D, D) adjacency is
    never materialized — not even transiently: the binary-search
    lookups run one 32-column word at a time (a (B, D, 32) working set,
    loop-carried search bounds included) and each word is packed into
    its uint32 lane as it is answered. Both the tile that flows to the
    counting kernel (B·D²/8 bytes vs the dense path's 4·B·D²) and the
    extraction's peak working set stay 32× smaller, which is what lets
    the engine batch 32× more units per dispatch at large capacities.
    """
    nb, _ = gather_neighbors(csr, nodes, capacity=capacity)
    B, D = nb.shape
    W = packed_words(D)
    nb_pad = jnp.pad(nb, ((0, 0), (0, W * 32 - D)), constant_values=-1)
    rows = jnp.arange(D, dtype=jnp.int32)[None, :, None]
    lanes = jnp.arange(32, dtype=jnp.int32)[None, None, :]
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def word(w, bits):
        cols = jax.lax.dynamic_slice_in_dim(nb_pad, w * 32, 32, axis=1)
        # strict upper triangle in global column index; padded columns
        # carry -1 neighbors, which edge_lookup rejects on its own
        tri = (w * 32 + lanes) > rows                      # (1, D, 32)
        x = jnp.where(tri, nb[:, :, None], -1)             # (B, D, 32)
        found = edge_lookup(csr, x, cols[:, None, :], n_iters)
        packed = jnp.sum(jnp.where(found, jnp.uint32(1) << shifts,
                                   jnp.uint32(0)), axis=-1,
                         dtype=jnp.uint32)                 # (B, D)
        return jax.lax.dynamic_update_slice_in_dim(
            bits, packed[:, :, None], w, axis=2)

    # init carry derived from nb so it inherits nb's varying-manual-axes
    # type under shard_map (cf. dag_count's init)
    init = jnp.broadcast_to((nb[:, :, None] * 0).astype(jnp.uint32),
                            (B, D, W))
    bits = jax.lax.fori_loop(0, W, word, init)
    return bits, nb


def extraction_shuffle_bytes(og: OrientedGraph) -> float:
    """Communication volume the *paper's* round 2 would shuffle:
    Σ_u C(|Γ⁺(u)|, 2) pairs + m edge markers, 8 bytes each — the
    O(m^{3/2}) total-space term we compare against in benchmarks."""
    d = og.out_deg.astype(np.float64)
    return float((d * (d - 1) / 2).sum() + og.m) * 8.0
