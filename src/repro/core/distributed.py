"""Distributed clique engine: shard_map over a ``workers`` mesh axis.

Mapping of the paper's machinery onto the pod:

  - reducers            → per-device batched tiles (static shapes)
  - shuffle             → none needed: the oriented CSR is replicated
                          (one all-gather; local space O(m), exactly the
                          paper's reduce-3 local-space bound)
  - partial counts      → `psum` over the workers axis
  - speculative exec.   → LPT cost balancing in the planner +
                          §6 split round for oversized subgraphs
  - sampling            → RNG keyed by node id only, so the estimate is
                          *bit-identical under any re-partitioning* —
                          elasticity does not perturb results.

The engine is elastic by construction: the worker count is read off the
mesh at call time, and any plan re-partitions to any worker count.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..graphs.formats import Graph
from .count import (color_mask, dag_count, edge_sample_mask,
                    smoothed_colors)
from .csr import build_oriented
from .extract import extract_adjacency, gather_neighbors, to_device
from .plan import build_plan, partition_for_workers
from .split import split_heavy
from . import mrc as mrc_mod


def _apply_sampling(A, nodes, key, out_deg, *, method: str, p: float,
                    c: int, r: int):
    """Shared sampling logic; returns (A_masked, per-node scale)."""
    D = A.shape[-1]
    scale = jnp.ones((nodes.shape[0],), jnp.float32)
    if method == "edge":
        A = A * edge_sample_mask(key, nodes, D, p)
        scale = scale * np.float32(1.0 / p ** (r * (r - 1) / 2.0))
    elif method in ("color", "color_smooth"):
        if method == "color_smooth":
            ncol = smoothed_colors(out_deg, c, r + 1)
        else:
            ncol = jnp.full(nodes.shape, c, jnp.int32)
        A = A * color_mask(key, nodes, D, ncol)
        scale = scale * ncol.astype(jnp.float32) ** np.float32(r - 1)
    return A, scale


def _worker_bucket_sum(csr, nodes_shard, key, *, capacity, n_iters, r,
                       method, p, c, tile_b, axis):
    """Runs on each worker: count its shard of one capacity class.

    nodes_shard: (1, T·tile_b) on this device — reshaped to tiles and
    folded with `lax.map` so the compiled program is one tile body.
    """
    nodes = nodes_shard.reshape(-1, tile_b)

    def one_tile(tile_nodes):
        A, _ = extract_adjacency(csr, tile_nodes, capacity=capacity,
                                 n_iters=n_iters)
        deg = csr.out_deg[jnp.maximum(tile_nodes, 0)]
        A, scale = _apply_sampling(A, tile_nodes, key, deg, method=method,
                                   p=p, c=c, r=r)
        return jnp.sum(dag_count(A, r) * scale)

    local = jnp.sum(jax.lax.map(one_tile, nodes))
    return jax.lax.psum(local, axis)


def _worker_split_sum(csr, nodes_shard, pivots_shard, key, *, capacity,
                      n_iters, r, method, p, c, tile_b, axis):
    """§6 split units: one (node, pivot) per unit; counts (k−2)-cliques in
    A_u masked by pivot row v. The adjacency is re-extracted per unit —
    the dense analogue of replicating G⁺(u) to reducer (u, v)."""
    nodes = nodes_shard.reshape(-1, tile_b)
    pivots = pivots_shard.reshape(-1, tile_b)

    def one_tile(args):
        tile_nodes, tile_pivots = args
        A, _ = extract_adjacency(csr, tile_nodes, capacity=capacity,
                                 n_iters=n_iters)
        deg = csr.out_deg[jnp.maximum(tile_nodes, 0)]
        A, scale = _apply_sampling(A, tile_nodes, key, deg, method=method,
                                   p=p, c=c, r=r)
        rows = jnp.take_along_axis(
            A, tile_pivots[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        if r - 1 == 1:  # k=3: 1-cliques below pivot v = |Γ⁺(v) ∩ G⁺(u)|
            return jnp.sum(jnp.sum(rows, axis=1) * scale)
        Bv = A * rows[:, :, None] * rows[:, None, :]
        return jnp.sum(dag_count(Bv, r - 1) * scale)

    local = jnp.sum(jax.lax.map(one_tile, (nodes, pivots)))
    return jax.lax.psum(local, axis)


@dataclasses.dataclass
class DistributedResult:
    k: int
    method: str
    estimate: float
    n_workers: int
    per_round_bytes: dict
    balance: dict

    @property
    def count(self) -> int:
        return int(round(self.estimate))


def count_cliques_distributed(
        g: Graph, k: int, method: str = "exact", p: float = 0.1,
        colors: int = 10, seed: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
        axis: str = "workers",
        split_threshold: Optional[int] = None,
        tile_elem_budget: int = 1 << 22) -> DistributedResult:
    """Multi-device k-clique counting/estimation.

    ``mesh`` defaults to a 1-D mesh over all local devices. With
    ``split_threshold`` set, nodes with |Γ⁺(u)| above it are rerouted
    through the §6 split round.
    """
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = jax.sharding.Mesh(devs, (axis,))
    W = mesh.shape[axis]
    og = build_oriented(g)
    plan = build_plan(og, k)
    splits = []
    if split_threshold is not None:
        plan, splits = split_heavy(plan, og, k, split_threshold)
    csr = to_device(og)
    key = jax.random.PRNGKey(seed)
    r = k - 1
    eff_method = "exact" if method == "ni++" else method

    total = 0.0
    worker_plans = partition_for_workers(plan, og, W)
    # per capacity class: stack worker shards → (W, width), shard_map it
    caps = sorted({b.capacity for wp in worker_plans for b in wp.buckets})
    for cap in caps:
        per_w = []
        for wp in worker_plans:
            arrs = [b.nodes for b in wp.buckets if b.capacity == cap]
            per_w.append(np.concatenate(arrs) if arrs
                         else np.zeros(0, np.int32))
        width = max(len(a) for a in per_w)
        tile_b = max(8, min(width, tile_elem_budget // (cap * cap)))
        tile_b += (-tile_b) % 8
        width += (-width) % tile_b
        stacked = np.full((W, width), -1, np.int32)
        for i, a in enumerate(per_w):
            stacked[i, :len(a)] = a
        fn = jax.jit(jax.shard_map(
            functools.partial(_worker_bucket_sum, capacity=cap,
                              n_iters=og.lookup_iters, r=r,
                              method=eff_method, p=float(p), c=int(colors),
                              tile_b=tile_b, axis=axis),
            mesh=mesh,
            in_specs=(P(), P(axis, None), P()),
            out_specs=P()))
        total += float(fn(csr, jnp.asarray(stacked), key))

    for sp in splits:
        units = np.stack([sp.nodes, sp.pivots], axis=1)
        pad = (-len(units)) % (8 * W)
        units = np.concatenate(
            [units, np.tile([[-1, 0]], (pad, 1)).astype(np.int32)])
        per = len(units) // W
        tile_b = max(8, min(per, tile_elem_budget // (sp.capacity ** 2)))
        tile_b += (-tile_b) % 8
        per += (-per) % tile_b
        stacked_n = np.full((W, per), -1, np.int32)
        stacked_p = np.zeros((W, per), np.int32)
        # round-robin so consecutive pivots of one node spread out (LPT-ish)
        for i in range(len(units)):
            w, j = i % W, i // W
            stacked_n[w, j], stacked_p[w, j] = units[i]
        fn = jax.jit(jax.shard_map(
            functools.partial(_worker_split_sum, capacity=sp.capacity,
                              n_iters=og.lookup_iters, r=r,
                              method=eff_method, p=float(p), c=int(colors),
                              tile_b=tile_b, axis=axis),
            mesh=mesh,
            in_specs=(P(), P(axis, None), P(axis, None), P()),
            out_specs=P()))
        total += float(fn(csr, jnp.asarray(stacked_n),
                          jnp.asarray(stacked_p), key))

    csr_bytes = 4.0 * (og.n + 1 + 2 * og.m + og.n)
    from .plan import balance_report
    return DistributedResult(
        k=k, method=method, estimate=total, n_workers=W,
        per_round_bytes={
            "csr_replication_allgather": csr_bytes * (W - 1),
            "count_allreduce": 4.0 * W,
            "paper_round2_shuffle_equiv":
                mrc_mod.compute_stats(og, plan).round2_pairs * 8.0,
        },
        balance=balance_report(plan, og, W))
