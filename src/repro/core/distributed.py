"""Deprecated distributed entry point (thin wrapper over the engine).

Mapping of the paper's machinery onto the pod (now implemented by
``repro.engine.backends.ShardMapBackend``):

  - reducers            → per-device batched tiles (static shapes)
  - shuffle             → none needed: the oriented CSR is replicated
                          (one all-gather; local space O(m), exactly the
                          paper's reduce-3 local-space bound)
  - partial counts      → `psum` over the workers axis
  - speculative exec.   → LPT cost balancing in the planner +
                          §6 split round for oversized subgraphs
  - sampling            → RNG keyed by node id only, so the estimate is
                          *bit-identical under any re-partitioning* —
                          elasticity does not perturb results.

The engine is elastic by construction: the worker count is read off the
mesh at call time, and any plan re-partitions to any worker count.

.. deprecated:: prefer ``CliqueEngine(g, backend="shard_map")`` — it
   keeps the CSR on device and the compiled `jit(shard_map(...))`
   executables cached across queries; this wrapper rebuilds a throwaway
   session per call (exactly the seed behavior, minus the duplicated
   sampling/count code).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from ..graphs.formats import Graph


@dataclasses.dataclass
class DistributedResult:
    """Legacy result shape (new code reads
    :class:`repro.engine.CountReport`)."""
    k: int
    method: str
    estimate: float
    n_workers: int
    per_round_bytes: dict
    balance: dict

    @property
    def count(self) -> int:
        return int(round(self.estimate))


def count_cliques_distributed(
        g: Graph, k: int, method: str = "exact", p: float = 0.1,
        colors: int = 10, seed: int = 0,
        mesh: Optional[jax.sharding.Mesh] = None,
        axis: str = "workers",
        split_threshold: Optional[int] = None,
        tile_elem_budget: int = 1 << 22) -> DistributedResult:
    """Multi-device k-clique counting/estimation.

    ``mesh`` defaults to a 1-D mesh over all local devices. With
    ``split_threshold`` set, nodes with |Γ⁺(u)| above it are rerouted
    through the §6 split round.
    """
    from ..engine import CliqueEngine, CountRequest
    eng = CliqueEngine(g, backend="shard_map", mesh=mesh, axis=axis,
                       dist_tile_budget=tile_elem_budget)
    rep = eng.submit(CountRequest(k=k, method=method, p=p, colors=colors,
                                  seed=seed,
                                  split_threshold=split_threshold))
    return DistributedResult(
        k=k, method=method, estimate=rep.estimate,
        n_workers=rep.n_workers, per_round_bytes=rep.per_round_bytes,
        balance=rep.balance)
