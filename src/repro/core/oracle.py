"""Brute-force and closed-form oracles for validation.

The recursive enumerator mirrors the paper's responsibility assignment:
every k-clique is attributed to its ≺-minimum node, so ``per_node`` here
must match the exact engine's per-node outputs bit-for-bit.
"""
from __future__ import annotations

import math

import numpy as np

from ..graphs.formats import Graph
from .order import ranks


def _oriented_rank_sets(g: Graph):
    """Shared oracle setup: (nplus, node_of_rank) where nplus[u] is
    Γ⁺(u) as a python set of *ranks* — one definition of the ≺
    orientation for both the counting and the listing oracle, so they
    can never silently disagree on tie-breaking or edge direction."""
    r = ranks(g.degrees)
    nplus: list[set[int]] = [set() for _ in range(g.n)]
    for u, v in g.edges:
        a, b = (u, v) if r[u] < r[v] else (v, u)
        nplus[int(a)].add(int(r[int(b)]))
    node_of_rank = np.empty(g.n, dtype=np.int64)
    node_of_rank[r] = np.arange(g.n)
    return nplus, node_of_rank


def clique_count_bruteforce(g: Graph, k: int,
                            return_per_node: bool = False):
    """Exact k-clique count by ordered recursion (host, tiny graphs only)."""
    assert k >= 2
    nplus, node_of_rank = _oriented_rank_sets(g)

    def count_in(cand: set[int], depth: int) -> int:
        if depth == 0:
            return 1
        if depth == 1:
            return len(cand)
        total = 0
        for rv in cand:
            v = int(node_of_rank[rv])
            total += count_in(cand & nplus[v], depth - 1)
        return total

    per_node = np.zeros(g.n, dtype=np.int64)
    total = 0
    for u in range(g.n):
        c = count_in(nplus[u], k - 1)
        per_node[u] = c
        total += c
    if return_per_node:
        return total, per_node
    return total


def clique_list_bruteforce(g: Graph, k: int) -> np.ndarray:
    """Every k-clique of ``g`` as an (N, k) int64 array (host, tiny
    graphs only) — the listing oracle behind ``tests/test_listing.py``.

    Rows are [u, v₁, …, v_{k−1}]: the ≺-minimum (responsible) node
    first, then the remaining members in ≺ order — the same
    responsibility assignment and emission order convention as the
    engine's streaming enumeration, so sorted-row set comparison is all
    a test needs.
    """
    assert k >= 2
    nplus, node_of_rank = _oriented_rank_sets(g)
    out: list[list[int]] = []

    def emit_in(cand: set[int], depth: int, prefix: list[int]) -> None:
        if depth == 0:
            out.append(prefix)
            return
        for rv in sorted(cand):
            v = int(node_of_rank[rv])
            if depth == 1:
                out.append(prefix + [v])
            else:
                emit_in(cand & nplus[v], depth - 1, prefix + [v])

    for u in range(g.n):
        emit_in(nplus[u], k - 1, [u])
    return (np.asarray(out, dtype=np.int64) if out
            else np.empty((0, k), np.int64))


def complete_graph_cliques(n: int, k: int) -> int:
    return math.comb(n, k)


def er_expected_cliques(n: int, p: float, k: int) -> float:
    """E[#k-cliques] in G(n,p): C(n,k)·p^{C(k,2)}."""
    return math.comb(n, k) * p ** math.comb(k, 2)


def triangle_count_matrix(g: Graph) -> int:
    """Independent dense-matrix triangle oracle: tr(A³)/6."""
    A = np.zeros((g.n, g.n), dtype=np.float64)
    A[g.edges[:, 0], g.edges[:, 1]] = 1.0
    A[g.edges[:, 1], g.edges[:, 0]] = 1.0
    return int(round(np.trace(A @ A @ A) / 6.0))
