"""The paper's primary contribution: exact (SI_k) and sampled (SI_k^p,
SIC_k) k-clique counting, decomposed into the three MapReduce rounds and
re-expressed as TPU-native batched dense-linear-algebra stages.

Public API:
  count_cliques(graph, k, method=...)            — single host
  distributed.count_cliques_distributed(...)     — shard_map engine
"""
from .count import CountResult, count_cliques, dag_count, dag_count_flops
from .csr import OrientedGraph, build_oriented
from .oracle import (clique_count_bruteforce, clique_list_bruteforce,
                     complete_graph_cliques, er_expected_cliques,
                     triangle_count_matrix)
from .order import check_lemma1, ranks
from .plan import Plan, balance_report, build_plan, partition_for_workers

__all__ = [
    "CountResult", "count_cliques", "dag_count", "dag_count_flops",
    "OrientedGraph", "build_oriented",
    "clique_count_bruteforce", "clique_list_bruteforce",
    "complete_graph_cliques",
    "er_expected_cliques", "triangle_count_matrix",
    "check_lemma1", "ranks",
    "Plan", "balance_report", "build_plan", "partition_for_workers",
]
