"""MRC-model accounting (Karloff–Suri–Vassilvitskii) for the engine.

The paper analyzes SI_k against the MRC yardsticks: total space
O(m^{3/2}), total work O(m^{k/2}), local space O(m), local time
O(m^{(k−1)/2}); the sampled variants fit MRC proper once p ≤ 1/m^α.
This module computes the *actual* per-round volumes of a concrete run so
benchmarks can check the bounds empirically (benchmarks/table_mrc.py) and
the distributed engine can budget communication.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .csr import OrientedGraph
from .plan import Plan


@dataclasses.dataclass(frozen=True)
class MRCStats:
    m: int
    n: int
    rounds: int
    # round volumes, in key-value pairs (the MR currency)
    round1_pairs: int            # map-1 emissions (oriented edges)
    round2_pairs: float          # map-2 emissions: Σ C(|Γ⁺(u)|, 2) (+ $ markers)
    round3_pairs: float          # map-3 emissions: Σ |E(G⁺(u))| upper bound
    max_local_space: int         # max reducer input size
    total_work: float            # Σ |Γ⁺(u)|^{k−1}  (reduce-3 dominates)
    # paper bounds to compare against
    bound_total_space: float     # O(m^{3/2})
    bound_total_work: float      # O(m^{k/2})
    bound_local_space: float     # O(m)
    bound_local_time: float      # O(m^{(k−1)/2})
    sample_factor: float         # expected shrink of round-2/3 volume
    max_unit_size: int = 0       # largest |Γ⁺(u)| (largest capacity class)

    def check_bounds(self, const: float = 4.0) -> dict[str, bool]:
        """Empirical validation of Theorem 1's asymptotics (constant-slack).

        ``lemma1`` is exact, not constant-slack: the degree-order
        orientation guarantees every reduce-3 input |Γ⁺(u)| ≤ 2√m (paper
        Lemma 1 — a node's out-neighbors all have degree ≥ |Γ⁺(u)|, so
        m ≥ |Γ⁺(u)|²/2), hence the planner's largest capacity class is
        bounded the same way.
        """
        return {
            "total_space": self.round2_pairs * self.sample_factor
            <= const * self.bound_total_space,
            "local_space": self.max_local_space <= const * self.bound_local_space,
            "total_work": self.total_work <= const * self.bound_total_work,
            "lemma1": self.max_unit_size <= 2.0 * math.sqrt(max(self.m, 1)),
        }


def compute_stats(og: OrientedGraph, plan: Plan, method: str = "exact",
                  p: float = 1.0, colors: int = 10,
                  k: Optional[int] = None) -> MRCStats:
    """``k`` defaults to ``plan.k``; since plans went k-agnostic (the
    engine builds every plan at the k=3 reference), callers pass the
    query's k explicitly so the work bounds stay per-query."""
    d = og.out_deg.astype(np.float64)
    m = float(max(og.m, 1))
    k = plan.k if k is None else k
    pairs2 = float((d * (d - 1) / 2).sum())
    if method == "edge":
        sample = p
    elif method in ("color", "color_smooth"):
        sample = 1.0 / max(colors, 1)
    else:
        sample = 1.0
    rounds = 2 if method == "ni++" else 3
    return MRCStats(
        m=og.m, n=og.n, rounds=rounds,
        round1_pairs=og.m,
        round2_pairs=pairs2 + og.m,
        round3_pairs=pairs2 * sample,
        max_local_space=int(max(og.m, og.n)),
        total_work=float((d ** (k - 1)).sum()),
        bound_total_space=m ** 1.5,
        bound_total_work=m ** (k / 2.0),
        bound_local_space=m,
        bound_local_time=m ** ((k - 1) / 2.0),
        sample_factor=sample,
        max_unit_size=int(d.max()) if og.n else 0)


def theorem2_min_p(m: int, qk: float, k: int, eps: float = 0.1,
                   h: float = 1.0) -> float:
    """Smallest edge-sampling p meeting Theorem 2's concentration
    condition p^{(k-1)(k-2)/2} > h·m^{(k-3)/2}·ln m / (ε²·q_k)."""
    if qk <= 0:
        return 1.0
    rhs = h * m ** ((k - 3) / 2.0) * math.log(max(m, 2)) / (eps * eps * qk)
    expo = (k - 1) * (k - 2) / 2.0
    return min(1.0, rhs ** (1.0 / expo))


def theorem3_max_colors(m: int, qk: float, k: int, eps: float = 0.1,
                        h: float = 1.0) -> int:
    """Largest color count c meeting Theorem 3's condition
    1/c^{k-2} > h·m^{k-2}? — rearranged: c < (ε²·q_k / (h·m^{(k-3)/2}·ln m))^{1/(k-2)}.

    (We use the same interference-graph exponent as Theorem 2's proof
    sketch for SIC_k: cliques interfere iff they share a non-minimum
    node.)"""
    if qk <= 0:
        return 1
    rhs = eps * eps * qk / (h * m ** ((k - 3) / 2.0) * math.log(max(m, 2)))
    return max(1, int(rhs ** (1.0 / max(k - 2, 1))))
