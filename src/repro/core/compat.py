"""Version-compat shims for the jax API surface the engine touches.

The repo targets the jax.shard_map / jax.sharding.AxisType API; older
jax releases (≤ 0.4.x) ship the same machinery under
``jax.experimental.shard_map`` and without ``AxisType``. Import from
here instead of from jax directly so every call site works on both.
"""
from __future__ import annotations

import jax

try:  # jax ≥ 0.5: top-level export
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax ≤ 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

try:  # jax ≥ 0.5
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:
    AxisType = None  # sentinel: this jax has no explicit/auto axis types


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """shard_map with the static replication/VMA check disabled — for
    bodies whose output replication the older checker cannot infer
    (e.g. optimizer steps mixing psum'd grads with carried state)."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # newer jax renamed the flag
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with ``axis_types`` only where supported."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)
