"""Round 3 — (k−1)-clique counting in dense oriented adjacencies, plus the
sampling estimators of Section 4.

Counting identities (A is (B, D, D), strictly upper-triangular 0/1):

  r=2:  q₂ = Σ A                      (edges)
  r=3:  q₃ = Σ (AᵀA) ∘ A              (increasing triangles — one matmul)
  r≥4:  pivot recursion: q_r(A) = Σ_v q_{r−1}(A ∘ (A[v] ⊗ A[v]))

Each r-clique of the underlying graph appears exactly once as an
increasing tuple, so no division by symmetry is needed. The same math is
implemented as a Pallas TPU kernel in ``repro.kernels.cliques``; this
module is the jnp reference path and the single-host estimator driver.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .csr import OrientedGraph, build_oriented
from .extract import DeviceCSR, extract_adjacency, to_device
from .plan import Plan, build_plan
from . import mrc as mrc_mod


# --------------------------------------------------------------------------
# counting identities
# --------------------------------------------------------------------------

def dag_count(A: jax.Array, r: int) -> jax.Array:
    """Number of r-cliques in each DAG adjacency of the batch.

    A: (B, D, D) float32, strictly upper-triangular. Returns (B,) float32.
    """
    assert r >= 2, "r=1 is a row popcount; handled by the split path"
    if r == 2:
        return jnp.sum(A, axis=(1, 2))
    if r == 3:
        return jnp.einsum("bji,bjk,bik->b", A, A, A, optimize=True)
    D = A.shape[-1]

    def body(v, acc):
        row = jax.lax.dynamic_index_in_dim(A, v, axis=1, keepdims=False)
        Bv = A * row[:, :, None] * row[:, None, :]
        return acc + dag_count(Bv, r - 1)

    # init carry derived from A so it inherits A's varying-manual-axes
    # type under shard_map (a plain jnp.zeros would be "unvarying")
    init = jnp.sum(A[:, 0, 0:1], axis=1) * 0.0
    return jax.lax.fori_loop(0, D, body, init)


def dag_count_flops(D: int, B: int, r: int) -> float:
    """Analytic FLOPs of ``dag_count`` (roofline bookkeeping)."""
    if r == 2:
        return float(B) * D * D
    if r == 3:
        return 2.0 * B * D ** 3 + 2.0 * B * D * D
    return D * (2.0 * B * D * D + dag_count_flops(D, B, r - 1))


# --------------------------------------------------------------------------
# sampling masks (Section 4)
# --------------------------------------------------------------------------

def _per_node_keys(key: jax.Array, nodes: jax.Array) -> jax.Array:
    """Counter-based per-node keys: the same edge appearing in two
    subgraphs G⁺(u), G⁺(u′) is (re)sampled independently — the property
    the paper's Theorem 2 concentration proof relies on."""
    return jax.vmap(lambda u: jax.random.fold_in(key, u))(
        jnp.maximum(nodes, 0).astype(jnp.uint32))


def edge_sample_mask(key: jax.Array, nodes: jax.Array, D: int,
                     p: float) -> jax.Array:
    """Bernoulli(p) mask over each node's candidate pairs (map 2 with
    probability p)."""
    ks = _per_node_keys(key, nodes)
    return jax.vmap(
        lambda k: jax.random.bernoulli(k, p, (D, D)))(ks).astype(jnp.float32)


def color_mask(key: jax.Array, nodes: jax.Array, D: int,
               n_colors: jax.Array) -> jax.Array:
    """Monochromatic-pair mask: color Γ⁺(u) with c colors (per-u
    independent coloring — unlike [27]'s single global coloring), keep
    pairs with equal colors. ``n_colors`` is (B,) int32 to support the
    smoothed variant (fewer colors for small neighborhoods)."""
    ks = _per_node_keys(key, nodes)
    unif = jax.vmap(lambda k: jax.random.uniform(k, (D,)))(ks)
    colors = jnp.floor(unif * n_colors[:, None].astype(jnp.float32))
    return (colors[:, :, None] == colors[:, None, :]).astype(jnp.float32)


def smoothed_colors(out_deg: jax.Array, c: int, k: int) -> jax.Array:
    """Smoothed color count (Section 5.1): "changes smoothly (up to the
    given threshold c) according to the degree of the node, being smaller
    for nodes with fewer neighbors".

    We keep the expected number of *surviving pairs* at least on the
    order of the pairs a (k−1)-clique needs: c_u = clip(d⁺(u)/(k−1), 1, c)
    so low-degree nodes are sampled less aggressively. Unbiasedness is
    preserved because the reducer rescales per-node by c_u^{k−2}.
    """
    cu = jnp.floor(out_deg.astype(jnp.float32) / float(max(k - 1, 1)))
    return jnp.clip(cu, 1.0, float(c)).astype(jnp.int32)


# --------------------------------------------------------------------------
# the estimator driver (single host; the distributed engine wraps this)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CountResult:
    k: int
    method: str
    estimate: float
    per_node: Optional[np.ndarray]      # exact only: q_{u,k−1} per node
    mrc: "mrc_mod.MRCStats"
    plan_summary: dict
    timings: dict
    params: dict

    @property
    def count(self) -> int:
        return int(round(self.estimate))


@functools.partial(jax.jit,
                   static_argnames=("capacity", "n_iters", "r", "method",
                                    "p", "c", "engine"))
def _count_tile(csr: DeviceCSR, nodes: jax.Array, key: jax.Array, *,
                capacity: int, n_iters: int, r: int, method: str,
                p: float, c: int, engine: str) -> jax.Array:
    """Extract + (optionally sample) + count one tile. Returns (B,) f32
    per-node *rescaled* estimates."""
    A, _ = extract_adjacency(csr, nodes, capacity=capacity, n_iters=n_iters)
    scale = jnp.ones((nodes.shape[0],), jnp.float32)
    if method == "edge":
        mask = edge_sample_mask(key, nodes, capacity, p)
        A = A * mask
        scale = scale * np.float32(1.0 / p ** (r * (r - 1) / 2.0))
    elif method in ("color", "color_smooth"):
        deg = csr.out_deg[jnp.maximum(nodes, 0)]
        if method == "color_smooth":
            ncol = smoothed_colors(deg, c, r + 1)
        else:
            ncol = jnp.full(nodes.shape, c, jnp.int32)
        A = A * color_mask(key, nodes, capacity, ncol)
        scale = scale * ncol.astype(jnp.float32) ** np.float32(r - 1)
    if engine == "pallas":
        from ..kernels.cliques import ops as cliques_ops
        counts = cliques_ops.dag_count_pallas(A, r)
    else:
        counts = dag_count(A, r)
    return counts * scale


def _tile_batches(nodes: np.ndarray, capacity: int,
                  elem_budget: int = 1 << 23):
    """Split a bucket's node list into tiles with B·D² ≤ budget."""
    B = max(8, min(len(nodes), elem_budget // (capacity * capacity)))
    B += (-B) % 8
    for i in range(0, len(nodes), B):
        tile = nodes[i:i + B]
        if len(tile) < B:
            tile = np.concatenate([tile, np.full(B - len(tile), -1,
                                                 np.int32)])
        yield tile


def count_cliques(g: Graph, k: int, method: str = "exact",
                  p: float = 0.1, colors: int = 10,
                  seed: int = 0, engine: str = "jnp",
                  return_per_node: bool = False,
                  og: Optional[OrientedGraph] = None,
                  plan: Optional[Plan] = None) -> CountResult:
    """Count (exactly) or estimate the number of k-cliques of ``g``.

    methods:
      "exact"        — SI_k (Algorithm 1)
      "edge"         — SI_k with Bernoulli(p) pair sampling (Section 4)
      "color"        — SIC_k with c = ``colors`` (Section 4)
      "color_smooth" — SIC_k with degree-smoothed color counts (Section 5)
      "ni++"         — Node Iterator++ [34]; k must be 3 (2-round baseline)
    engine: "jnp" reference path or "pallas" (interpret on CPU, MXU on TPU).
    """
    assert k >= 3
    if method == "ni++":
        assert k == 3, "NI++ is a triangle-counting baseline"
    t0 = time.perf_counter()
    og = og or build_oriented(g)
    plan = plan or build_plan(og, k)
    t_plan = time.perf_counter() - t0

    csr = to_device(og)
    key = jax.random.PRNGKey(seed)
    r = k - 1
    total = 0.0
    per_node = np.zeros(g.n, np.float64) if return_per_node else None
    t_count = 0.0
    eff_method = "exact" if method == "ni++" else method
    for b in plan.buckets:
        for tile in _tile_batches(b.nodes, b.capacity):
            t1 = time.perf_counter()
            vals = _count_tile(csr, jnp.asarray(tile), key,
                               capacity=b.capacity,
                               n_iters=og.lookup_iters, r=r,
                               method=eff_method, p=float(p),
                               c=int(colors), engine=engine)
            vals = np.asarray(jax.block_until_ready(vals), np.float64)
            t_count += time.perf_counter() - t1
            total += float(vals.sum())
            if per_node is not None:
                sel = tile >= 0
                np.add.at(per_node, tile[sel], vals[sel])
    stats = mrc_mod.compute_stats(og, plan, method=method, p=p,
                                  colors=colors)
    return CountResult(
        k=k, method=method, estimate=total, per_node=per_node, mrc=stats,
        plan_summary=plan.cost_summary(),
        timings={"plan_s": t_plan, "count_s": t_count,
                 "total_s": time.perf_counter() - t0},
        params={"p": p, "colors": colors, "seed": seed, "engine": engine})
