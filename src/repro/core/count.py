"""Round 3 — (k−1)-clique counting in dense oriented adjacencies, plus the
sampling estimators of Section 4.

Counting identities (A is (B, D, D), strictly upper-triangular 0/1):

  r=2:  q₂ = Σ A                      (edges)
  r=3:  q₃ = Σ (AᵀA) ∘ A              (increasing triangles — one matmul)
  r≥4:  pivot recursion: q_r(A) = Σ_v q_{r−1}(A ∘ (A[v] ⊗ A[v]))

Each r-clique of the underlying graph appears exactly once as an
increasing tuple, so no division by symmetry is needed. The same math is
implemented as a Pallas TPU kernel in ``repro.kernels.cliques``; this
module is the jnp reference path and hosts the *shared tile path* every
backend of :class:`repro.engine.CliqueEngine` routes through.

Sampling parameters ``p`` and ``c`` are traced (not compile-time
static), so one compiled tile executable per ``(capacity, r, method,
engine)`` serves every sampling rate in a session.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.formats import Graph
from .csr import OrientedGraph
from .extract import (DeviceCSR, edge_lookup, extract_adjacency,
                      extract_adjacency_bits, gather_neighbors,
                      pack_adjacency, packed_words)
from .plan import Plan
from . import mrc as mrc_mod


# --------------------------------------------------------------------------
# counting identities
# --------------------------------------------------------------------------

def dag_count(A: jax.Array, r: int) -> jax.Array:
    """Number of r-cliques in each DAG adjacency of the batch.

    A: (B, D, D) float32, strictly upper-triangular. Returns (B,) float32.
    """
    assert r >= 2, "r=1 is a row popcount; handled by the split path"
    if r == 2:
        return jnp.sum(A, axis=(1, 2))
    if r == 3:
        return jnp.einsum("bji,bjk,bik->b", A, A, A, optimize=True)
    D = A.shape[-1]

    def body(v, acc):
        row = jax.lax.dynamic_index_in_dim(A, v, axis=1, keepdims=False)
        Bv = A * row[:, :, None] * row[:, None, :]
        return acc + dag_count(Bv, r - 1)

    # init carry derived from A so it inherits A's varying-manual-axes
    # type under shard_map (a plain jnp.zeros would be "unvarying")
    init = jnp.sum(A[:, 0, 0:1], axis=1) * 0.0
    return jax.lax.fori_loop(0, D, body, init)


def dag_profile(A: jax.Array, rmax: int) -> jax.Array:
    """Clique-size profile of each DAG adjacency: one traversal, every k.

    A: (B, D, D) float32, strictly upper-triangular. Returns
    (B, rmax−1) f32 with column j = number of (j+2)-cliques, j+2 ≤ rmax
    — the Pivoter idea carried through our pivot recursion: instead of
    summing a scalar per increasing tuple at one fixed depth, each
    recursion level prepends its own edge count, so the single deepest
    traversal reads off q_s for *every* size s ≤ rmax. Column j of the
    tile profile therefore contributes to the global q_{j+3} (the unit
    vertex u completes each s-clique of G⁺(u) to an (s+1)-clique).

    Correctness: B_v = A ∘ (A[v] ⊗ A[v]) lives strictly above v, so each
    s-clique of A is seen exactly once — as an (s−1)-clique of B_v for
    v its minimum vertex — and no column overcounts.
    """
    assert rmax >= 2, "the profile bottoms out at the edge count"
    if rmax == 2:
        return jnp.sum(A, axis=(1, 2))[:, None]
    edges = jnp.sum(A, axis=(1, 2))
    if rmax == 3:
        tri = jnp.einsum("bji,bjk,bik->b", A, A, A, optimize=True)
        return jnp.stack([edges, tri], axis=1)
    D = A.shape[-1]

    def body(v, acc):
        row = jax.lax.dynamic_index_in_dim(A, v, axis=1, keepdims=False)
        Bv = A * row[:, :, None] * row[:, None, :]
        return acc + dag_profile(Bv, rmax - 1)

    # init carry derived from A so it inherits A's varying-manual-axes
    # type under shard_map (see dag_count)
    init = jnp.broadcast_to((jnp.sum(A[:, 0, 0:1], axis=1) * 0.0)[:, None],
                            (A.shape[0], rmax - 2))
    sub = jax.lax.fori_loop(0, D, body, init)
    return jnp.concatenate([edges[:, None], sub], axis=1)


def dag_count_flops(D: int, B: int, r: int) -> float:
    """Analytic FLOPs of ``dag_count`` (roofline bookkeeping)."""
    if r == 2:
        return float(B) * D * D
    if r == 3:
        return 2.0 * B * D ** 3 + 2.0 * B * D * D
    return D * (2.0 * B * D * D + dag_count_flops(D, B, r - 1))


def _dag_count_engine(A: jax.Array, r: int, engine: str) -> jax.Array:
    """Dispatch the counting identity to the jnp or Pallas implementation."""
    if engine == "pallas":
        from ..kernels.cliques import ops as cliques_ops
        return cliques_ops.dag_count_pallas(A, r)
    return dag_count(A, r)


# --------------------------------------------------------------------------
# counting identities, packed domain (uint32 bitset rows)
# --------------------------------------------------------------------------

def _unpack_bits(bits: jax.Array, D: int) -> jax.Array:
    """(..., W) uint32 → (..., D) f32 indicator (in-register unpack)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    b = (bits[..., None] >> shifts) & jnp.uint32(1)
    return b.reshape(bits.shape[:-1] + (-1,))[..., :D].astype(jnp.float32)


def dag_count_bits(bits: jax.Array, r: int) -> jax.Array:
    """Number of r-cliques per packed DAG adjacency of the batch.

    bits: (B, D, W) uint32 rows, W = ⌈D/32⌉, strictly upper-triangular.
    Returns (B,) f32. Same pivot recursion as :func:`dag_count`, carried
    out in the packed domain: the pivot mask is a row-broadcast AND plus
    a row-bit select, and the innermost levels are pure AND+popcount —
    32 adjacency entries per lane op, no multiplies.
    """
    assert r >= 2, "r=1 is a row popcount; handled by the split path"
    D = bits.shape[1]
    if r == 2:
        return jnp.sum(jax.lax.population_count(bits).astype(jnp.float32),
                       axis=(1, 2))
    # init carry derived from bits so it inherits the varying-manual-axes
    # type under shard_map (see dag_count)
    init = jnp.sum(bits[:, 0, 0:1], axis=1).astype(jnp.float32) * 0.0
    if r == 3:
        def edge_level(i, acc):
            row = jax.lax.dynamic_index_in_dim(bits, i, axis=1,
                                               keepdims=False)  # (B, W)
            inter = jnp.bitwise_and(bits, row[:, None, :])       # (B, D, W)
            common = jnp.sum(jax.lax.population_count(inter)
                             .astype(jnp.float32), axis=2)       # (B, D)
            return acc + jnp.sum(common * _unpack_bits(row, D), axis=1)
        return jax.lax.fori_loop(0, D, edge_level, init)

    def pivot(v, acc):
        row = jax.lax.dynamic_index_in_dim(bits, v, axis=1,
                                           keepdims=False)       # (B, W)
        colmask = jnp.bitwise_and(bits, row[:, None, :])         # (B, D, W)
        sel = _unpack_bits(row, D) > 0.0                         # (B, D)
        Bv = jnp.where(sel[:, :, None], colmask, jnp.uint32(0))
        return acc + dag_count_bits(Bv, r - 1)

    return jax.lax.fori_loop(0, D, pivot, init)


def dag_profile_bits(bits: jax.Array, rmax: int) -> jax.Array:
    """Packed twin of :func:`dag_profile` for (B, D, W) uint32 bitset
    adjacencies: one traversal at depth ``rmax`` emits every column
    q_2..q_rmax, with the pivot masking identical to
    :func:`dag_count_bits` (row-broadcast AND + row-bit select)."""
    assert rmax >= 2, "the profile bottoms out at the edge count"
    D = bits.shape[1]
    edges = jnp.sum(jax.lax.population_count(bits).astype(jnp.float32),
                    axis=(1, 2))
    if rmax == 2:
        return edges[:, None]
    # init carry derived from bits so it inherits the varying-manual-axes
    # type under shard_map (see dag_count)
    zero = jnp.sum(bits[:, 0, 0:1], axis=1).astype(jnp.float32) * 0.0
    if rmax == 3:
        def edge_level(i, acc):
            row = jax.lax.dynamic_index_in_dim(bits, i, axis=1,
                                               keepdims=False)  # (B, W)
            inter = jnp.bitwise_and(bits, row[:, None, :])       # (B, D, W)
            common = jnp.sum(jax.lax.population_count(inter)
                             .astype(jnp.float32), axis=2)       # (B, D)
            return acc + jnp.sum(common * _unpack_bits(row, D), axis=1)
        tri = jax.lax.fori_loop(0, D, edge_level, zero)
        return jnp.stack([edges, tri], axis=1)

    def pivot(v, acc):
        row = jax.lax.dynamic_index_in_dim(bits, v, axis=1,
                                           keepdims=False)       # (B, W)
        colmask = jnp.bitwise_and(bits, row[:, None, :])         # (B, D, W)
        sel = _unpack_bits(row, D) > 0.0                         # (B, D)
        Bv = jnp.where(sel[:, :, None], colmask, jnp.uint32(0))
        return acc + dag_profile_bits(Bv, rmax - 1)

    init = jnp.broadcast_to(zero[:, None], (bits.shape[0], rmax - 2))
    sub = jax.lax.fori_loop(0, D, pivot, init)
    return jnp.concatenate([edges[:, None], sub], axis=1)


def dag_count_bits_ops(D: int, B: int, r: int) -> float:
    """Analytic VPU word-ops of ``dag_count_bits`` (roofline bookkeeping):
    every AND / popcount / select touches W = ⌈D/32⌉ uint32 lanes per
    row, so one packed level costs ~3·B·D·W word-ops per pivot."""
    W = float(packed_words(D))
    if r == 2:
        return 2.0 * B * D * W
    if r == 3:
        return D * (3.0 * B * D * W + 2.0 * B * D)
    return D * (3.0 * B * D * W + B * D + dag_count_bits_ops(D, B, r - 1))


def _dag_count_bits_engine(bits: jax.Array, r: int,
                           engine: str) -> jax.Array:
    """Dispatch the packed identity to the jnp or Pallas implementation."""
    if engine == "pallas":
        from ..kernels.bitset import ops as bitset_ops
        return bitset_ops.dag_count_bits_pallas(bits, r)
    return dag_count_bits(bits, r)


def _dag_profile_engine(A: jax.Array, rmax: int, engine: str) -> jax.Array:
    """Dense profile dispatch. The Pallas dense kernel is the scalar
    MXU-matmul count identity; the profile's vector carry rides the XLA
    recursion on every backend (the same seam as
    :func:`repro.kernels.bitset.ops.dag_list_bits_pallas`)."""
    del engine
    return dag_profile(A, rmax)


def _dag_profile_bits_engine(bits: jax.Array, rmax: int,
                             engine: str) -> jax.Array:
    """Packed profile dispatch to the jnp or Pallas implementation."""
    if engine == "pallas":
        from ..kernels.bitset import ops as bitset_ops
        return bitset_ops.dag_profile_bits_pallas(bits, rmax)
    return dag_profile_bits(bits, rmax)


# --------------------------------------------------------------------------
# emit variants: streaming k-clique enumeration (repro.listing)
# --------------------------------------------------------------------------
#
# The paper's exact algorithm "counts (and lists)" k-cliques: the pivot
# recursion that sums 1 per increasing tuple can just as well *emit* the
# tuple. The emit variants below walk the identical recursion but carry a
# fixed-capacity (chunk, r+1) int32 row buffer plus a running stream
# counter, and materialize only the cliques whose global stream position
# falls in the window [start, start + chunk). Enumeration order is
# deterministic (batch-major, then pivot-major, then row-major over the
# innermost pair mask), so a caller drains an overflowing tile by
# re-running the same compiled executable with start advanced by chunk —
# host and device memory stay O(chunk) no matter how many cliques the
# tile holds. ``start`` is traced; one executable per
# (capacity, r, chunk, representation) serves every chunk of every tile.


def _scatter_rows(flat_fn: Callable[[], jax.Array], cnt: jax.Array,
                  shape: tuple, prefix: tuple, start, chunk: int, carry):
    """Shared emit step: write the set elements of one innermost pair
    mask into the row buffer.

    ``flat_fn()`` materializes the (B·D·D,) bool mask of valid (i, j)
    pairs given ``prefix`` (traced tile-local pivot indices shared
    across the batch); ``cnt`` is its precomputed popcount, so a mask
    whose stream span is disjoint from [start, start+chunk) never runs
    ``flat_fn`` or the scatters (the packed path exploits this to stay
    in the uint32 domain on drained-past windows). carry is
    (counter, rows): the stream position before this mask and the
    (chunk, r+1) int32 buffer. Rows are [b, *prefix, i, j]; positions
    outside the window land on the out-of-range slot ``chunk`` and are
    dropped by the scatter.
    """
    counter, rows = carry
    B, D = shape

    def do_emit(rows):
        flat = flat_fn()
        pos = counter + jnp.cumsum(flat.astype(jnp.int32)) - 1
        write = flat & (pos >= start) & (pos < start + chunk)
        slot = jnp.where(write, pos - start, chunk)   # chunk → dropped
        idx = jnp.arange(B * D * D, dtype=jnp.int32)
        cols = (idx // (D * D),) + tuple(
            jnp.full(idx.shape, v, jnp.int32) for v in prefix) + \
            ((idx // D) % D, idx % D)
        # one row-wise scatter: the loop-carried buffer is rewritten
        # once per emitting step, not once per column
        return rows.at[slot].set(jnp.stack(cols, axis=1), mode="drop")

    overlap = (counter < start + chunk) & (counter + cnt > start)
    rows = jax.lax.cond(overlap, do_emit, lambda r: r, rows)
    return counter + cnt, rows


def _list_rec(A: jax.Array, r: int, prefix: tuple, start, chunk: int,
              carry):
    """Dense emit recursion — the pivot recursion of :func:`dag_count`
    with the innermost two levels emitted instead of summed."""
    B, D = A.shape[0], A.shape[1]
    if r == 2:
        flat = A.reshape(-1) > 0.0
        return _scatter_rows(lambda: flat,
                             jnp.sum(flat.astype(jnp.int32)), (B, D),
                             prefix, start, chunk, carry)

    def body(v, carry):
        row = jax.lax.dynamic_index_in_dim(A, v, axis=1, keepdims=False)
        Bv = A * row[:, :, None] * row[:, None, :]
        return _list_rec(Bv, r - 1, prefix + (v,), start, chunk, carry)

    return jax.lax.fori_loop(0, D, body, carry)


def _list_rec_bits(bits: jax.Array, r: int, prefix: tuple, start,
                   chunk: int, carry):
    """Packed emit recursion — pivot masking stays in the uint32 domain
    (row-broadcast AND + row-bit select, exactly :func:`dag_count_bits`);
    only the innermost pair mask is unpacked, and only when its count
    overlaps the chunk window (window-disjoint masks cost one popcount)."""
    B, D = bits.shape[0], bits.shape[1]
    if r == 2:
        cnt = jnp.sum(jax.lax.population_count(bits).astype(jnp.int32))
        return _scatter_rows(
            lambda: _unpack_bits(bits, D).reshape(-1) > 0.0, cnt,
            (B, D), prefix, start, chunk, carry)

    def body(v, carry):
        row = jax.lax.dynamic_index_in_dim(bits, v, axis=1, keepdims=False)
        colmask = jnp.bitwise_and(bits, row[:, None, :])
        sel = _unpack_bits(row, D) > 0.0
        Bv = jnp.where(sel[:, :, None], colmask, jnp.uint32(0))
        return _list_rec_bits(Bv, r - 1, prefix + (v,), start, chunk, carry)

    return jax.lax.fori_loop(0, D, body, carry)


def dag_list_cliques(A: jax.Array, r: int, *, chunk: int,
                     start) -> tuple[jax.Array, jax.Array]:
    """Enumerate the r-cliques of each dense DAG adjacency in the batch.

    A: (B, D, D) f32 strictly upper-triangular. Returns
    (rows (chunk, r+1) int32, total int32): ``rows[s]`` is the clique at
    stream position ``start + s`` as tile-local indices [b, i₁ < … < i_r]
    (unwritten slots stay −1); ``total`` is the full per-tile clique
    count — the emit twin of :func:`dag_count`, so ``total`` always
    equals ``dag_count(A, r)`` and the caller drains an overflow by
    re-running with ``start += chunk`` while ``start < total``.
    """
    assert r >= 2, "listing bottoms out at the pair mask (k ≥ 3)"
    rows = jnp.full((chunk, r + 1), -1, jnp.int32)
    counter, rows = _list_rec(A, r, (), jnp.int32(start), chunk,
                              (jnp.int32(0), rows))
    return rows, counter


def dag_list_bits(bits: jax.Array, r: int, *, chunk: int,
                  start) -> tuple[jax.Array, jax.Array]:
    """Packed twin of :func:`dag_list_cliques` for (B, D, W) uint32
    bitset adjacencies — same stream order, same chunk contract."""
    assert r >= 2, "listing bottoms out at the pair mask (k ≥ 3)"
    rows = jnp.full((chunk, r + 1), -1, jnp.int32)
    counter, rows = _list_rec_bits(bits, r, (), jnp.int32(start), chunk,
                                   (jnp.int32(0), rows))
    return rows, counter


def list_tile_rows(csr: DeviceCSR, nodes: jax.Array, start, *,
                   capacity: int, n_iters: int, r: int, chunk: int,
                   tile_repr: str = "dense",
                   engine: str = "jnp") -> tuple[jax.Array, jax.Array]:
    """Extract + enumerate one tile's chunk window, translated to global
    vertex ids.

    The emit twin of :func:`tile_values`/:func:`bits_tile_values`:
    extracts each G⁺(u) (dense or packed per ``tile_repr``), enumerates
    the (k−1)-cliques inside it, and gathers the tile-local row indices
    back through the extraction's neighbor map — so each returned row is
    the full k-clique [u, v₁, …, v_{k−1}] in *global* node ids, with u
    the ≺-minimum (responsible) vertex and v_i its rank-sorted
    out-neighbors. Returns (rows (chunk, r+1) int32, total int32);
    unfilled slots are −1. ``engine="pallas"`` routes the packed path
    through :func:`repro.kernels.bitset.ops.dag_list_bits_pallas` (the
    emission itself stays XLA scatter work on every backend — see that
    wrapper's docstring for why).
    """
    if tile_repr == "bits":
        bits, nb = extract_adjacency_bits(csr, nodes, capacity=capacity,
                                          n_iters=n_iters)
        if engine == "pallas":
            from ..kernels.bitset import ops as bitset_ops
            local, total = bitset_ops.dag_list_bits_pallas(
                bits, r, chunk=chunk, start=start)
        else:
            local, total = dag_list_bits(bits, r, chunk=chunk, start=start)
    else:
        A, nb = extract_adjacency(csr, nodes, capacity=capacity,
                                  n_iters=n_iters)
        local, total = dag_list_cliques(A, r, chunk=chunk, start=start)
    b = local[:, 0]
    ok = b >= 0
    safe_b = jnp.maximum(b, 0)
    cols = [jnp.where(ok, nodes[safe_b], -1)]
    for c in range(1, r + 1):
        i = jnp.maximum(local[:, c], 0)
        cols.append(jnp.where(ok, nb[safe_b, i], -1))
    return jnp.stack(cols, axis=1), total


# --------------------------------------------------------------------------
# sampling masks (Section 4)
# --------------------------------------------------------------------------

def _per_node_keys(key: jax.Array, nodes: jax.Array) -> jax.Array:
    """Counter-based per-node keys: the same edge appearing in two
    subgraphs G⁺(u), G⁺(u′) is (re)sampled independently — the property
    the paper's Theorem 2 concentration proof relies on."""
    return jax.vmap(lambda u: jax.random.fold_in(key, u))(
        jnp.maximum(nodes, 0).astype(jnp.uint32))


def edge_sample_mask(key: jax.Array, nodes: jax.Array, D: int,
                     p) -> jax.Array:
    """Bernoulli(p) mask over each node's candidate pairs (map 2 with
    probability p)."""
    ks = _per_node_keys(key, nodes)
    return jax.vmap(
        lambda k: jax.random.bernoulli(k, p, (D, D)))(ks).astype(jnp.float32)


def color_mask(key: jax.Array, nodes: jax.Array, D: int,
               n_colors: jax.Array) -> jax.Array:
    """Monochromatic-pair mask: color Γ⁺(u) with c colors (per-u
    independent coloring — unlike [27]'s single global coloring), keep
    pairs with equal colors. ``n_colors`` is (B,) int32 to support the
    smoothed variant (fewer colors for small neighborhoods)."""
    ks = _per_node_keys(key, nodes)
    unif = jax.vmap(lambda k: jax.random.uniform(k, (D,)))(ks)
    colors = jnp.floor(unif * n_colors[:, None].astype(jnp.float32))
    return (colors[:, :, None] == colors[:, None, :]).astype(jnp.float32)


def smoothed_colors(out_deg: jax.Array, c, k: int) -> jax.Array:
    """Smoothed color count (Section 5.1): "changes smoothly (up to the
    given threshold c) according to the degree of the node, being smaller
    for nodes with fewer neighbors".

    We keep the expected number of *surviving pairs* at least on the
    order of the pairs a (k−1)-clique needs: c_u = clip(d⁺(u)/(k−1), 1, c)
    so low-degree nodes are sampled less aggressively. Unbiasedness is
    preserved because the reducer rescales per-node by c_u^{k−2}.
    """
    cu = jnp.floor(out_deg.astype(jnp.float32) / float(max(k - 1, 1)))
    cmax = jnp.asarray(c, jnp.float32)  # c may be traced (session-cached)
    return jnp.clip(cu, 1.0, cmax).astype(jnp.int32)


def apply_sampling(A: jax.Array, nodes: jax.Array, out_deg: jax.Array,
                   key: jax.Array, *, method: str, r: int, p, c
                   ) -> tuple[jax.Array, jax.Array]:
    """Shared Section-4 sampling step for every tile path: returns
    (A_masked, per-node rescale). ``p``/``c`` are traced values."""
    D = A.shape[-1]
    scale = jnp.ones((nodes.shape[0],), jnp.float32)
    if method == "edge":
        A = A * edge_sample_mask(key, nodes, D, p)
        pf = jnp.asarray(p, jnp.float32)
        scale = scale / pf ** np.float32(r * (r - 1) / 2.0)
    elif method in ("color", "color_smooth"):
        if method == "color_smooth":
            ncol = smoothed_colors(out_deg, c, r + 1)
        else:
            ncol = jnp.full(nodes.shape, c, jnp.int32)
        A = A * color_mask(key, nodes, D, ncol)
        scale = scale * ncol.astype(jnp.float32) ** np.float32(r - 1)
    return A, scale


def apply_sampling_bits(bits: jax.Array, nodes: jax.Array,
                        out_deg: jax.Array, key: jax.Array, *, method: str,
                        r: int, p, c) -> tuple[jax.Array, jax.Array]:
    """Section-4 sampling for the packed tile path. The Bernoulli /
    monochromatic masks are generated densely (O(D²) bools — the cheap
    part) but packed before they touch the adjacency, so the dominant
    O(D^{r−1}) counting cost stays in the 32×-smaller packed domain."""
    D = bits.shape[1]
    scale = jnp.ones((nodes.shape[0],), jnp.float32)
    if method == "edge":
        mask = pack_adjacency(edge_sample_mask(key, nodes, D, p))
        bits = jnp.bitwise_and(bits, mask)
        pf = jnp.asarray(p, jnp.float32)
        scale = scale / pf ** np.float32(r * (r - 1) / 2.0)
    elif method in ("color", "color_smooth"):
        if method == "color_smooth":
            ncol = smoothed_colors(out_deg, c, r + 1)
        else:
            ncol = jnp.full(nodes.shape, c, jnp.int32)
        mask = pack_adjacency(color_mask(key, nodes, D, ncol))
        bits = jnp.bitwise_and(bits, mask)
        scale = scale * ncol.astype(jnp.float32) ** np.float32(r - 1)
    return bits, scale


# --------------------------------------------------------------------------
# the shared tile path (every engine backend routes through these)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CountResult:
    """Legacy single-host result (kept for the deprecated
    :func:`count_cliques` wrapper; new code reads
    :class:`repro.engine.CountReport`)."""
    k: int
    method: str
    estimate: float
    per_node: Optional[np.ndarray]      # exact only: q_{u,k−1} per node
    mrc: "mrc_mod.MRCStats"
    plan_summary: dict
    timings: dict
    params: dict

    @property
    def count(self) -> int:
        return int(round(self.estimate))


def tile_values(csr: DeviceCSR, nodes: jax.Array, key: jax.Array, *,
                capacity: int, n_iters: int, r: int, method: str,
                p, c, engine: str = "jnp") -> jax.Array:
    """Extract + (optionally sample) + count one tile. Returns (B,) f32
    per-node *rescaled* estimates. Unjitted: the local backend jits it
    as ``_count_tile``; the shard_map workers fold it under lax.map."""
    if method == "wedge":   # static → resolved at trace time
        return wedge_tile_values(csr, nodes, key, capacity=capacity,
                                 n_iters=n_iters, r=r, samples=c)
    A, _ = extract_adjacency(csr, nodes, capacity=capacity, n_iters=n_iters)
    deg = csr.out_deg[jnp.maximum(nodes, 0)]
    A, scale = apply_sampling(A, nodes, deg, key, method=method, r=r,
                              p=p, c=c)
    return _dag_count_engine(A, r, engine) * scale


def split_tile_values(csr: DeviceCSR, nodes: jax.Array, pivots: jax.Array,
                      key: jax.Array, *, capacity: int, n_iters: int,
                      r: int, method: str, p, c,
                      engine: str = "jnp") -> jax.Array:
    """§6 split units, one (node, pivot) per lane: counts (k−2)-cliques
    in A_u masked by pivot row v — the outermost pivot level lifted out
    of the kernel. Returns (B,) f32 rescaled partial estimates."""
    A, _ = extract_adjacency(csr, nodes, capacity=capacity, n_iters=n_iters)
    deg = csr.out_deg[jnp.maximum(nodes, 0)]
    A, scale = apply_sampling(A, nodes, deg, key, method=method, r=r,
                              p=p, c=c)
    rows = jnp.take_along_axis(
        A, pivots[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    if r - 1 == 1:  # k=3: 1-cliques below pivot v = |Γ⁺(v) ∩ G⁺(u)|
        return jnp.sum(rows, axis=1) * scale
    Bv = A * rows[:, :, None] * rows[:, None, :]
    return _dag_count_engine(Bv, r - 1, engine) * scale


def bits_tile_values(csr: DeviceCSR, nodes: jax.Array, key: jax.Array, *,
                     capacity: int, n_iters: int, r: int, method: str,
                     p, c, engine: str = "jnp") -> jax.Array:
    """Packed twin of :func:`tile_values`: extract G⁺(u) straight into
    uint32 bitset rows, mask in the packed domain, count with
    AND+popcount. Bit-exact vs the dense path (both count integers in
    f32); the tile it materializes is B·D²/8 bytes instead of 4·B·D²."""
    if method == "wedge":   # representation-free: no adjacency to pack
        return wedge_tile_values(csr, nodes, key, capacity=capacity,
                                 n_iters=n_iters, r=r, samples=c)
    bits, _ = extract_adjacency_bits(csr, nodes, capacity=capacity,
                                     n_iters=n_iters)
    deg = csr.out_deg[jnp.maximum(nodes, 0)]
    bits, scale = apply_sampling_bits(bits, nodes, deg, key, method=method,
                                      r=r, p=p, c=c)
    return _dag_count_bits_engine(bits, r, engine) * scale


def bits_split_tile_values(csr: DeviceCSR, nodes: jax.Array,
                           pivots: jax.Array, key: jax.Array, *,
                           capacity: int, n_iters: int, r: int, method: str,
                           p, c, engine: str = "jnp") -> jax.Array:
    """Packed twin of :func:`split_tile_values`: the §6 split round's
    outer pivot level becomes one row gather + a row-broadcast AND."""
    bits, _ = extract_adjacency_bits(csr, nodes, capacity=capacity,
                                     n_iters=n_iters)
    deg = csr.out_deg[jnp.maximum(nodes, 0)]
    bits, scale = apply_sampling_bits(bits, nodes, deg, key, method=method,
                                      r=r, p=p, c=c)
    rows = jnp.take_along_axis(
        bits, pivots[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    if r - 1 == 1:  # k=3: 1-cliques below pivot v = |Γ⁺(v) ∩ G⁺(u)|
        return jnp.sum(jax.lax.population_count(rows)
                       .astype(jnp.float32), axis=1) * scale
    D = capacity
    colmask = jnp.bitwise_and(bits, rows[:, None, :])
    sel = _unpack_bits(rows, D) > 0.0
    Bv = jnp.where(sel[:, :, None], colmask, jnp.uint32(0))
    return _dag_count_bits_engine(Bv, r - 1, engine) * scale


def profile_tile_values(csr: DeviceCSR, nodes: jax.Array, *, capacity: int,
                        n_iters: int, r: int,
                        engine: str = "jnp") -> jax.Array:
    """Extract + profile one tile (exact only — the all-k path). Returns
    (B, r−1) f32: column j is each unit's count of (j+2)-cliques inside
    G⁺(u), i.e. its contribution to the global q_{j+3}."""
    A, _ = extract_adjacency(csr, nodes, capacity=capacity, n_iters=n_iters)
    return _dag_profile_engine(A, r, engine)


def bits_profile_tile_values(csr: DeviceCSR, nodes: jax.Array, *,
                             capacity: int, n_iters: int, r: int,
                             engine: str = "jnp") -> jax.Array:
    """Packed twin of :func:`profile_tile_values`."""
    bits, _ = extract_adjacency_bits(csr, nodes, capacity=capacity,
                                     n_iters=n_iters)
    return _dag_profile_bits_engine(bits, r, engine)


def subset_tile_values(csr: DeviceCSR, nodes: jax.Array, key: jax.Array, *,
                       capacity: int, kept: int, n_iters: int, r: int,
                       engine: str = "jnp",
                       tile_repr: str = "bits") -> jax.Array:
    """Fixed-size neighborhood subsampling: the §5.1 smoothing idea taken
    to its compute-saving conclusion. Instead of masking pairs inside the
    full ``capacity``-wide adjacency (which leaves the dense tile cost
    untouched), keep a uniform random ``kept``-subset of each Γ⁺(u) and
    count r-cliques in the *compacted* (B, kept, kept) adjacency — the
    tile cost drops from O(D^{r−1}) to O(S^{r−1}) per unit.

    Unbiasedness: a fixed r-subset of Γ⁺(u) survives with probability
    (s)_r/(d)_r (s = min(d, kept), falling factorials), so the per-node
    estimate rescales by w_u = (d)_r/(s)_r. Nodes with d ≤ kept keep
    their whole neighborhood: w_u = 1 and the count is exact — only the
    heavy units carry sampling variance. Equivalently this is color
    sampling with a degree-smoothed color count c_u ≈ d_u/kept and
    exactly one retained color class.

    Returns (B,) f32 rescaled per-node estimates, like ``tile_values``.
    """
    nb, in_row = gather_neighbors(csr, nodes, capacity=capacity)
    B, S = nodes.shape[0], kept
    ks = _per_node_keys(key, nodes)
    scores = jax.vmap(lambda k: jax.random.uniform(k, (capacity,)))(ks)
    # invalid slots sort last, so the S smallest scores are a uniform
    # S-subset of the real neighbors (all of them when d ≤ S)
    scores = jnp.where(in_row, scores, jnp.inf)
    idx = jnp.sort(jnp.argsort(scores, axis=1)[:, :S], axis=1)
    kept_nb = jnp.take_along_axis(nb, idx, axis=1)
    kept_nb = jnp.where(jnp.take_along_axis(in_row, idx, axis=1),
                        kept_nb, -1)
    # positions stay ascending, rows stay rank-sorted → strict upper
    # triangularity is preserved and each clique counts once
    x = jnp.broadcast_to(kept_nb[:, :, None], (B, S, S))
    y = jnp.broadcast_to(kept_nb[:, None, :], (B, S, S))
    tri = jnp.triu(jnp.ones((S, S), bool), 1)[None]
    found = edge_lookup(csr, jnp.where(tri, x, -1), y, n_iters) & tri
    if tile_repr == "bits":
        # default: the compacted adjacency is counted fully packed,
        # like every other tile path
        counts = _dag_count_bits_engine(pack_adjacency(found), r, engine)
    else:   # a request-forced engine="dense" applies here too
        counts = _dag_count_engine(found.astype(jnp.float32), r, engine)
    d = csr.out_deg[jnp.maximum(nodes, 0)].astype(jnp.float32)
    s = jnp.minimum(d, np.float32(S))
    i = jnp.arange(r, dtype=jnp.float32)[None, :]
    # (d)_r/(s)_r; the max(·, 1) guards only fire where d < r ⇒ counts=0
    w = jnp.prod(jnp.maximum(d[:, None] - i, 1.0)
                 / jnp.maximum(s[:, None] - i, 1.0), axis=1)
    return jnp.where(nodes >= 0, counts * w, 0.0)


def wedge_tile_values(csr: DeviceCSR, nodes: jax.Array, key: jax.Array, *,
                      capacity: int, n_iters: int, r: int,
                      samples) -> jax.Array:
    """Wedge sampling (Kolda et al.) generalized to r ≥ 2: per unit u,
    draw ``samples`` uniform r-subsets of Γ⁺(u) and close each against
    the packed adjacency — X_u = C(d_u, r) · closed/samples. A uniform
    r-subset is a clique with probability q_{u,r}/C(d_u, r), so X_u is
    unbiased; r = 2 is literally the paper's wedge-closure check (u is
    the wedge center, the pair its endpoints).

    Unlike every other sampled path this never materializes the (D, D)
    adjacency — cost per unit is O(samples · (capacity + r²·n_iters)),
    independent of d², which is exactly why it wins on degree-skewed
    graphs where the dense tile of a few huge units dominates.

    ``samples`` is traced (it rides the session's ``c`` operand), and
    the draw loop is a ``fori_loop`` with a traced bound — so one
    compiled executable per (capacity, r) serves the whole samples×2
    escalation ladder, like p/c for the mask estimators.

    Returns (B,) f32 rescaled per-node estimates, like ``tile_values``.
    """
    nb, in_row = gather_neighbors(csr, nodes, capacity=capacity)
    B = nodes.shape[0]
    ks = _per_node_keys(key, nodes)
    tri = jnp.triu(jnp.ones((r, r), bool), 1)[None]

    def draw(t, hits):
        kt = jax.vmap(lambda k: jax.random.fold_in(k, t))(ks)
        scores = jax.vmap(
            lambda k: jax.random.uniform(k, (capacity,)))(kt)
        scores = jnp.where(in_row, scores, jnp.inf)
        # bottom-r scores = a uniform r-subset of the real neighbors;
        # re-sorting the positions keeps rows rank-ordered so the
        # pairwise check below stays strictly upper-triangular
        _, idx = jax.lax.top_k(-scores, r)
        idx = jnp.sort(idx, axis=1)
        sub = jnp.take_along_axis(nb, idx, axis=1)
        sub = jnp.where(jnp.take_along_axis(in_row, idx, axis=1),
                        sub, -1)
        x = jnp.broadcast_to(sub[:, :, None], (B, r, r))
        y = jnp.broadcast_to(sub[:, None, :], (B, r, r))
        ok = edge_lookup(csr, jnp.where(tri, x, -1), y, n_iters) | ~tri
        closed = jnp.all(ok, axis=(1, 2)) & jnp.all(sub >= 0, axis=1)
        return hits + closed.astype(jnp.float32)

    S = jnp.asarray(samples, jnp.int32)
    hits = jax.lax.fori_loop(0, S, draw, jnp.zeros((B,), jnp.float32))
    d = csr.out_deg[jnp.maximum(nodes, 0)].astype(jnp.float32)
    i = jnp.arange(r, dtype=jnp.float32)[None, :]
    # C(d, r) = (d)_r / r!  (zero where d < r — those units hold nothing)
    w = jnp.prod(jnp.maximum(d[:, None] - i, 0.0), axis=1) \
        / np.float32(np.prod(np.arange(1, r + 1)))
    est = w * hits / jnp.maximum(S.astype(jnp.float32), 1.0)
    return jnp.where(nodes >= 0, est, 0.0)


_TILE_STATICS = ("capacity", "n_iters", "r", "method", "engine")
_count_tile = functools.partial(jax.jit, static_argnames=_TILE_STATICS)(
    tile_values)
_split_tile = functools.partial(jax.jit, static_argnames=_TILE_STATICS)(
    split_tile_values)
_bits_tile = functools.partial(jax.jit, static_argnames=_TILE_STATICS)(
    bits_tile_values)
_bits_split_tile = functools.partial(
    jax.jit, static_argnames=_TILE_STATICS)(bits_split_tile_values)
_subset_tile = functools.partial(
    jax.jit, static_argnames=("capacity", "kept", "n_iters", "r", "engine",
                              "tile_repr"))(subset_tile_values)
_PROFILE_STATICS = ("capacity", "n_iters", "r", "engine")
_profile_tile = functools.partial(
    jax.jit, static_argnames=_PROFILE_STATICS)(profile_tile_values)
_bits_profile_tile = functools.partial(
    jax.jit, static_argnames=_PROFILE_STATICS)(bits_profile_tile_values)
_list_tile = functools.partial(
    jax.jit, static_argnames=("capacity", "n_iters", "r", "chunk",
                              "tile_repr", "engine"))(list_tile_rows)


# --------------------------------------------------------------------------
# tile representation choice + byte-accounted batching
# --------------------------------------------------------------------------

TILE_REPRS = ("dense", "bits")


def tile_unit_bytes(capacity: int, tile_repr: str = "dense") -> int:
    """HBM bytes one work unit's adjacency occupies in a tile: 4·D² for
    the dense f32 representation, 4·D·⌈D/32⌉ (= D²/8) packed."""
    assert tile_repr in TILE_REPRS, tile_repr
    if tile_repr == "bits":
        return 4 * capacity * packed_words(capacity)
    return 4 * capacity * capacity


def pick_tile_repr(*, r: int, capacity: int, method: str = "exact",
                   choice: str = "auto",
                   elem_budget: int = 1 << 23) -> str:
    """Bytes-based cost model for the packed-vs-dense tile choice.

    ``choice`` is the request's ``engine`` knob: "dense"/"bitset" force a
    representation; "auto" picks per (r, capacity) bucket. Packed wins
    where the MXU has nothing to multiply — k=3 (r=2: the count is a row
    popcount) and NI++'s triangle path — and wherever a minimal aligned
    batch of 8 dense f32 units would blow the tile byte budget (the
    huge-capacity buckets), where the 32× smaller packed tile keeps the
    dispatch batched instead of degrading to single-unit tiles. The
    dense matmul identity keeps r ≥ 3 buckets that fit: a 0/1 matmul on
    the MXU still beats the VPU's D/32 popcount lanes there.
    """
    if choice == "dense":
        return "dense"
    if choice == "bitset":
        return "bits"
    if method == "ni++" or r <= 2:
        return "bits"
    if 8 * tile_unit_bytes(capacity, "dense") > 4 * elem_budget:
        return "bits"
    return "dense"


def tile_batch_repr(tile_repr: str, method: str) -> str:
    """Representation to *byte-account* a tile batch with. Sampled
    methods materialize a transient dense mask before packing
    (:func:`apply_sampling_bits`), so their packed tiles must batch at
    dense sizes — only the exact path earns the 32× wider batch."""
    if tile_repr == "bits" and method != "exact":
        return "dense"
    return tile_repr


def subset_unit_bytes(capacity: int, kept: int) -> int:
    """Byte-accounting for one ``subset_tile_values`` unit: the
    compacted (S, S) adjacency plus the capacity-wide gather/score
    transients — not the full D² the unit never materializes."""
    return 4 * kept * kept + 16 * capacity


def _pick_tile_b(n_avail: int, capacity: int, elem_budget: int,
                 tile_repr: str = "dense",
                 unit_bytes: Optional[int] = None) -> int:
    """Largest batch whose tile fits the byte budget (4·elem_budget —
    the budget is denominated in f32 elements), aligned down to 8 when
    possible. Never exceeds the budget just to hit the alignment floor:
    a D=4096 dense tile runs 1 unit at a time, not 8 (the seed's
    ``max(8, …)`` silently shipped 512 MiB tiles there)."""
    budget_bytes = 4 * elem_budget
    if unit_bytes is None:
        unit_bytes = tile_unit_bytes(capacity, tile_repr)
    B = max(1, min(n_avail, budget_bytes // unit_bytes))
    if B >= 8:
        B -= B % 8
    return B


def _tile_batches(nodes: np.ndarray, capacity: int,
                  elem_budget: int = 1 << 23, tile_repr: str = "dense",
                  unit_bytes: Optional[int] = None):
    """Split a bucket's node list into tiles within the byte budget."""
    B = _pick_tile_b(len(nodes), capacity, elem_budget, tile_repr,
                     unit_bytes)
    for i in range(0, len(nodes), B):
        tile = nodes[i:i + B]
        if len(tile) < B:
            tile = np.concatenate([tile, np.full(B - len(tile), -1,
                                                 np.int32)])
        yield tile


def _split_batches(nodes: np.ndarray, pivots: np.ndarray, capacity: int,
                   elem_budget: int = 1 << 23, tile_repr: str = "dense"):
    """Tile a split plan's (node, pivot) unit lists the same way."""
    B = _pick_tile_b(len(nodes), capacity, elem_budget, tile_repr)
    for i in range(0, len(nodes), B):
        tn, tp = nodes[i:i + B], pivots[i:i + B]
        if len(tn) < B:
            pad = B - len(tn)
            tn = np.concatenate([tn, np.full(pad, -1, np.int32)])
            tp = np.concatenate([tp, np.zeros(pad, np.int32)])
        yield tn, tp


# --------------------------------------------------------------------------
# deprecated single-host entry point (thin wrapper over the engine)
# --------------------------------------------------------------------------

def count_cliques(g: Graph, k: int, method: str = "exact",
                  p: float = 0.1, colors: int = 10,
                  seed: int = 0, engine: str = "jnp",
                  return_per_node: bool = False,
                  og: Optional[OrientedGraph] = None,
                  plan: Optional[Plan] = None) -> CountResult:
    """Count (exactly) or estimate the number of k-cliques of ``g``.

    .. deprecated:: use :class:`repro.engine.CliqueEngine` — it builds
       the oriented CSR once per *graph* instead of once per call and
       caches plans/executables across queries. This wrapper spins up a
       throwaway engine per call and adapts its report.

    methods:
      "exact"        — SI_k (Algorithm 1)
      "edge"         — SI_k with Bernoulli(p) pair sampling (Section 4)
      "color"        — SIC_k with c = ``colors`` (Section 4)
      "color_smooth" — SIC_k with degree-smoothed color counts (Section 5)
      "ni++"         — Node Iterator++ [34]; k must be 3 (2-round baseline)
    engine: "jnp" reference path, "pallas" (interpret on CPU, MXU on TPU),
    or "bitset" (packed uint32 tiles + popcount counting).
    """
    from ..engine import CliqueEngine, CountRequest
    t0 = time.perf_counter()
    eng = CliqueEngine(g, backend="pallas" if engine == "pallas"
                       else "local", og=og)
    if plan is not None:
        eng.warm_plan(plan)
    rep = eng.submit(CountRequest(k=k, method=method, p=p, colors=colors,
                                  seed=seed,
                                  engine=("bitset" if engine == "bitset"
                                          else "auto"),
                                  return_per_node=return_per_node))
    timings = dict(rep.timings)
    timings["total_s"] = time.perf_counter() - t0
    return CountResult(
        k=k, method=method, estimate=rep.estimate, per_node=rep.per_node,
        mrc=rep.mrc, plan_summary=rep.plan_summary, timings=timings,
        params={"p": p, "colors": colors, "seed": seed, "engine": engine})
