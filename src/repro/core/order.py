"""Round 1 — the degree-based total order ≺ and edge orientation.

The paper orders nodes by (degree, label): x ≺ y iff d(x) < d(y), ties
broken by label. Orienting every edge from its ≺-smaller endpoint to its
≺-larger endpoint yields a DAG whose max out-degree is at most 2√m
(Lemma 1) — the structural fact all bounds hang on.

On TPU there is no shuffle: the oriented adjacency is built with a sort
(argsort *is* the hardware's shuffle), and ranks are dense positions in
the ≺ order so all later comparisons are single integer compares.
"""
from __future__ import annotations

import numpy as np

from ..graphs.formats import Graph


def ranks(degrees: np.ndarray) -> np.ndarray:
    """Dense rank of each node in the ≺ order.

    rank[u] < rank[v]  <=>  u ≺ v  <=>  (d(u), u) < (d(v), v).
    """
    n = degrees.shape[0]
    order = np.lexsort((np.arange(n, dtype=np.int64),
                        np.asarray(degrees, dtype=np.int64)))
    r = np.empty(n, dtype=np.int64)
    r[order] = np.arange(n, dtype=np.int64)
    return r


def orient_edges(g: Graph, node_ranks: np.ndarray):
    """Return (src, dst) arrays with rank[src] < rank[dst] for each edge.

    This realizes the paper's Map 1 ("if u ≺ v then emit ⟨u; v⟩") as a
    vectorized select instead of a shuffle.
    """
    u, v = g.edges[:, 0], g.edges[:, 1]
    swap = node_ranks[u] > node_ranks[v]
    src = np.where(swap, v, u)
    dst = np.where(swap, u, v)
    return src.astype(np.int64), dst.astype(np.int64)


def check_lemma1(g: Graph, out_deg: np.ndarray) -> bool:
    """|Γ⁺(u)| ≤ 2√m for every node (paper Lemma 1)."""
    if g.m == 0:
        return True
    return bool(out_deg.max() <= 2.0 * np.sqrt(g.m))
