"""The paper's §6 space-for-local-time trade ("split round").

For a node u whose G⁺(u) is too large, the paper replicates G⁺(u) once
per high-neighbor v and lets the reducer keyed (u, v) count
(k−2)-cliques. In the dense-pivot formulation this is *exactly* the
outermost pivot level of the counting recursion, lifted out of the
kernel and distributed: a work unit becomes (u, pivot v), its adjacency
is A_u masked by row v, and its local cost drops from D^{k−1} to
D^{k−2} — the factor-√m trade of the paper, with global work unchanged.

The split can be applied recursively (up to k−4 times, per the paper);
the engine applies one level, which already caps the heaviest unit at
the same cost class as the bulk of the distribution (Fig. 6's long tail
is cut off).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import OrientedGraph
from .plan import Bucket, Plan


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Work units (node, pivot) for oversized nodes."""
    capacity: int            # D of the *parent* subgraph A_u
    nodes: np.ndarray        # (B,) int32, -1 padding
    pivots: np.ndarray       # (B,) int32 pivot row index within A_u
    n_real: int

    @property
    def batch(self) -> int:
        return int(self.nodes.shape[0])


def split_heavy(plan: Plan, og: OrientedGraph, k: int,
                threshold: int) -> tuple[Plan, list[SplitPlan]]:
    """Move every node with |Γ⁺(u)| > threshold out of the normal plan and
    into (u, pivot) split units — one unit per row of A_u."""
    keep_buckets: list[Bucket] = []
    split_units: dict[int, list[tuple[int, int]]] = {}
    for b in plan.buckets:
        real = b.nodes[:b.n_real]
        deg = og.out_deg[real]
        heavy = real[deg > threshold]
        light = real[deg <= threshold]
        if light.size:
            pad = (-light.size) % 8
            nodes = np.concatenate([light.astype(np.int32),
                                    np.full(pad, -1, np.int32)])
            keep_buckets.append(Bucket(capacity=b.capacity, nodes=nodes,
                                       n_real=int(light.size)))
        for u in heavy:
            d = int(og.out_deg[u])
            cap = b.capacity
            units = split_units.setdefault(cap, [])
            for v in range(d):  # one unit per high-neighbor, as in §6
                units.append((int(u), v))
    splits = []
    for cap, units in sorted(split_units.items()):
        arr = np.array(units, np.int64).reshape(-1, 2)
        pad = (-len(arr)) % 8
        nodes = np.concatenate([arr[:, 0].astype(np.int32),
                                np.full(pad, -1, np.int32)])
        pivots = np.concatenate([arr[:, 1].astype(np.int32),
                                 np.zeros(pad, np.int32)])
        splits.append(SplitPlan(capacity=cap, nodes=nodes, pivots=pivots,
                                n_real=len(arr)))
    new_plan = Plan(k=plan.k, buckets=tuple(keep_buckets),
                    n_units=plan.n_units, total_cost=plan.total_cost,
                    pad_cost=plan.pad_cost,
                    max_capacity=max((b.capacity for b in keep_buckets),
                                     default=0))
    return new_plan, splits


def split_cost_model(og: OrientedGraph, k: int, threshold: int) -> dict:
    """Napkin math for §Perf: max unit cost and replication factor with
    and without the split round."""
    d = og.out_deg.astype(np.float64)
    heavy = d[d > threshold]
    base_max = float((d ** (k - 1)).max(initial=0.0))
    split_max = float(max((heavy ** (k - 2)).max(initial=0.0),
                          (d[d <= threshold] ** (k - 1)).max(initial=0.0)))
    extra_space = float((heavy * heavy).sum())  # D copies of a D-row graph
    return {"base_max_unit_cost": base_max, "split_max_unit_cost": split_max,
            "speedup_bound": base_max / max(split_max, 1.0),
            "extra_space_entries": extra_space,
            "n_heavy": int(heavy.size)}
