"""The portfolio controller behind accuracy-targeted queries.

The paper's headline claim for the sampling algorithms is "very accurate
solutions with high probability" — but SI_k/SIC_k make the *user* pick
the operating point (``p`` / ``colors``) blind. The controller closes
the loop the way Kolda et al. do for wedge sampling: the caller states
an accuracy contract ("q_k within 5% relative error at 99% confidence")
and the controller finds the cheapest method *and* operating point that
meets it.

How it works
------------
1. **Density certificates** — one cheap per-node edge count over the
   cached plan (the r=2 tile, reusing the session's executables)
   classifies every unit (complete / zero / stochastic) before any
   sampling and prices each portfolio method upfront: a starting level
   (prescreen), a certified support width, an analytic variance proxy,
   and a projected work figure in one shared flop unit
   (:func:`repro.estimator.levers.exact_flops` is the common
   denominator).
2. **Portfolio race** — methods are ranked by projected work; the two
   cheapest candidates that fit the budget run a small measured pilot
   (wall-clocked replicates). A pilot that already certifies the
   contract wins outright; otherwise the winner is the candidate with
   the smallest projected *remaining* wall, carrying its pilot
   replicates forward so the race costs nothing extra.
3. **Confidence interval** — per-node sampling keys make per-node
   estimates independent across nodes *and* replicates, so
   ``Var(total) = Σ_u Var(X_u)`` pools thousands of degrees of freedom
   from a 2-replicate pilot. The half-width is empirical-Bernstein

       hw = sqrt(2·V̂·L/R) + 3·M·L/max(R−1, 1),  L = ln(3/(1−confidence))

   with M the *certified* support width, never the observed range.
   Levers whose per-node values are correlated (sparsification's global
   edge mask) declare ``ci_mode="total"`` and get the bound on replicate
   totals instead — honest at the price of degrees of freedom.
4. **Escalation** — while the CI misses the target, the controller adds
   replicates up to the lever's ceiling (wedge replicates are nearly
   free and earn a much higher one), else escalates the winner's level
   geometrically: ``p``×2, ``colors``÷2, kept-capacity×2, draws×2,
   keep-rate → 1.
5. **Exact fall-through** — before every spend the controller consults
   the shared work model; once projected sampled work passes the exact
   plan cost it runs the exact query and reports a zero-width interval.
   Tiny graphs and rare-count targets resolve exact — "auto" degrades
   to correctness, never to a wrong bar.

Every query reports ``ci_low``/``ci_high``/``achieved_rel_error``/
``escalations`` plus an ``estimator`` telemetry dict whose
``portfolio`` entry records the full decision — per-method certificates,
pilot walls, the winner, and the escalation path — so ``gw.stats()``
and the CLI can explain *why* a method was chosen. See
``docs/estimator.md``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import jax
import numpy as np

from .bounds import (DEFAULT_POLICY, EstimatorPolicy, empirical_bernstein,
                     replicates_to_target)
from .certificates import _certificates
from .levers import (SparsifyLever, WedgeLever, _MaskLever,
                     exact_flops)


def _interval(lever, X: list, conf: float, M: float):
    """EB interval respecting the lever's CI mode: per-node columns for
    independent-unit levers, replicate totals (R, 1) for correlated
    ones."""
    A = np.stack(X)
    if lever.ci_mode == "total":
        A = A.sum(axis=1, keepdims=True)
    return empirical_bernstein(A, conf, M)


def _prescreen(lever, cert, rel: float, L: float,
               policy: EstimatorPolicy):
    """Pick the coarsest level whose EB range floor could possibly
    certify the target, priced against the certificates' structural
    lower bound on q_k before any replicate runs. Levers whose width
    bound does not shrink with the level (wedge: support is C(d, r) at
    every draw count) keep their start — their floor moves with R, not
    the level, and escalating upfront would just burn the ladder."""
    start = lever.start_level()
    if cert.det_lower <= 0.0:
        return start
    floor_target = rel * max(cert.det_lower, 1.0)
    last, prev_w = start, None
    for level, _ in zip(lever.levels(start),
                        range(policy.max_escalations + 1)):
        if lever.is_exact(level):
            break
        w = lever.width_bound(level)
        floor = 3.0 * w * L / max(policy.pilot_replicates - 1, 1)
        if floor <= floor_target:
            return level
        if prev_w is not None and w >= prev_w:
            return start
        prev_w, last = w, level
    return last


def _portfolio(eng, backend, entry, req, r: int, cert,
               policy: EstimatorPolicy) -> list:
    """The levers competing for this request: the full portfolio for
    "auto", the single named lever otherwise (legacy edge/color adaptive
    behavior is exactly the one-lever race)."""
    choice = req.engine
    if req.method == "auto":
        # every registered sampled method competes; the race below
        # pilots only the cheapest candidates the budget admits
        return [_MaskLever(eng, backend, entry, req, cert, policy,
                           method="edge"),
                _MaskLever(eng, backend, entry, req, cert, policy,
                           method="color"),
                WedgeLever(eng, backend, entry, r, cert, policy, choice),
                SparsifyLever(eng, backend, entry, req, r, cert, policy)]
    if req.method == "wedge":
        return [WedgeLever(eng, backend, entry, r, cert, policy, choice)]
    if req.method == "sparsify":
        return [SparsifyLever(eng, backend, entry, req, r, cert, policy)]
    return [_MaskLever(eng, backend, entry, req, cert, policy)]


def run_adaptive(eng, backend, entry, req,
                 policy: Optional[EstimatorPolicy] = None
                 ) -> tuple[float, Optional[np.ndarray], dict]:
    """Drive one accuracy-targeted query on an engine session. Returns
    ``(estimate, per_node, info)``; ``info`` carries the CI fields and
    controller telemetry the engine folds into the CountReport."""
    policy = policy or DEFAULT_POLICY
    if not isinstance(req.k, int):
        # CountRequest.validate rejects k="all" adaptive requests before
        # the engine dispatches here; keep the guard anyway so a caller
        # reaching the controller directly gets an answerable error, not
        # a type crash on r = k − 1 below
        raise ValueError('adaptive queries target one q_k; k="all" is '
                         "exact-only")
    if backend.name not in ("local", "pallas"):
        raise ValueError("adaptive (accuracy-targeted) queries need the "
                         "per-node replicate structure; use the local or "
                         "pallas backend")
    rel = req.rel_error if req.rel_error is not None \
        else policy.default_rel_error
    conf = req.confidence
    r = req.k - 1
    L = math.log(3.0 / max(1.0 - conf, 1e-12))
    cert = _certificates(eng, backend, entry, r, req.engine)
    levers = _portfolio(eng, backend, entry, req, r, cert, policy)
    exact_work = exact_flops(eng, entry, r)
    budget = policy.work_slack * exact_work
    base_key = jax.random.PRNGKey(req.seed)
    spent, esc, reps_total = 0.0, 0, 0
    stats = getattr(eng, "adaptive_stats", None)
    if stats is not None:
        stats["queries"] += 1

    # -- upfront certificates: one per lever, shared flop units ---------
    certs = []
    for lv in levers:
        level = _prescreen(lv, cert, rel, L, policy)
        certs.append({
            "lever": lv.name, "level": level,
            "width_bound": lv.width_bound(level),
            "var_proxy": lv.var_proxy(level),
            "cost_per_replicate": lv.cost(level),
            "fixed_cost": lv.fixed_cost(level),
            "projected_replicates": replicates_to_target(
                lv.var_proxy(level), lv.width_bound(level), conf,
                rel * max(cert.det_lower, 1.0)),
            "exact_at_start": lv.is_exact(level),
        })
        certs[-1]["projected_work"] = (
            certs[-1]["fixed_cost"]
            + certs[-1]["projected_replicates"]
            * certs[-1]["cost_per_replicate"])
    order = sorted(range(len(levers)),
                   key=lambda i: (certs[i]["exact_at_start"],
                                  certs[i]["projected_work"]))
    path: list[dict] = []
    portfolio = {"certificates": certs, "pilot": [], "winner": None,
                 "ranking": [levers[i].name for i in order],
                 "path": path}

    def info(resolved: str, level, est: float, hw: float,
             lv=None) -> dict:
        achieved = hw / max(abs(est), 1.0)
        name = lv.name if lv is not None else levers[order[0]].name
        if stats is not None:
            stats["escalations"] += esc
            stats["replicates"] += reps_total
            stats["sampled" if resolved == "sampled"
                  else "fallthroughs"] += 1
            if resolved == "sampled":
                wins = stats.setdefault("winners", {})
                wins[name] = wins.get(name, 0) + 1
        return {
            "resolved": resolved, "lever": name, "level": level,
            "ci_low": est - hw, "ci_high": est + hw,
            "achieved_rel_error": achieved, "escalations": esc,
            "replicates": reps_total, "rel_error_target": rel,
            "confidence": conf, "spent_work": spent,
            "exact_work": exact_work, "portfolio": portfolio,
        }

    def fall_through() -> tuple[float, Optional[np.ndarray], dict]:
        child = dataclasses.replace(req, method="exact", rel_error=None)
        est, per_node = backend.run(eng, entry, child, base_key)
        return est, per_node, info("exact", None, est, 0.0)

    def run_replicate(X: list, lv, level) -> None:
        nonlocal spent, reps_total
        key = jax.random.fold_in(base_key, reps_total)
        X.append(lv.replicate(level, key))
        reps_total += 1
        spent += lv.cost(level)

    # -- pilot race: wall-clock the cheapest candidates -----------------
    max_race = 2 if req.method == "auto" else 1
    raced: list[tuple] = []       # (i, level, X, wall_per_rep, est, hw)
    winner: Optional[tuple] = None
    for i in order:
        if len(raced) >= max_race:
            break
        lv, c = levers[i], certs[i]
        if c["exact_at_start"]:
            continue              # its ladder starts exact: no pilot
        if spent + c["fixed_cost"] \
                + policy.pilot_replicates * c["cost_per_replicate"] \
                > budget:
            portfolio["pilot"].append({"lever": lv.name,
                                       "skipped": "budget"})
            continue
        spent += lv.fixed_cost(c["level"])
        X: list[np.ndarray] = []
        t0 = time.perf_counter()
        for _ in range(policy.pilot_replicates):
            run_replicate(X, lv, c["level"])
        wall = time.perf_counter() - t0
        M = lv.width_bound(c["level"])
        est, hw, V = _interval(lv, X, conf, M)
        need = replicates_to_target(V, M, conf, rel * max(abs(est), 1.0))
        portfolio["pilot"].append({
            "lever": lv.name, "level": c["level"], "wall": wall,
            "estimate": est, "half_width": hw,
            "projected_replicates": need,
        })
        rec = (i, c["level"], X, wall / max(policy.pilot_replicates, 1),
               est, hw)
        raced.append(rec)
        if hw <= rel * max(abs(est), 1.0):
            winner = rec          # pilot already certifies: race over
            break

    if winner is None and raced:
        def projected_wall(rec) -> float:
            i, level, X, wall_per_rep, est, _ = rec
            M = levers[i].width_bound(level)
            _, _, V = _interval(levers[i], X, conf, M)
            need = replicates_to_target(V, M, conf,
                                        rel * max(abs(est), 1.0))
            return wall_per_rep * max(need - len(X), 1)
        winner = min(raced, key=projected_wall)
    if winner is None:
        return fall_through()
    lv = levers[winner[0]]
    portfolio["winner"] = lv.name

    # -- drive the winner: add replicates, escalate, or fall through ----
    def drive(lv, start, X0):
        nonlocal esc, spent
        X = X0
        for level in lv.levels(start):
            if esc >= policy.max_escalations or lv.is_exact(level):
                return None
            if X is None:
                fixed = lv.fixed_cost(level)
                if spent + fixed \
                        + policy.pilot_replicates * lv.cost(level) \
                        > budget:
                    return None
                spent += fixed
                X = []
                for _ in range(policy.pilot_replicates):
                    run_replicate(X, lv, level)
            M = lv.width_bound(level)
            cap = lv.max_replicates(policy)
            while True:
                est, hw, V = _interval(lv, X, conf, M)
                if hw <= rel * max(abs(est), 1.0):
                    path.append({"lever": lv.name, "level": level,
                                 "replicates": len(X),
                                 "half_width": hw})
                    return level, X, est, hw
                need = replicates_to_target(V, M, conf,
                                            rel * max(abs(est), 1.0))
                if need > cap:
                    break          # cheaper to escalate the lever
                extra = need - len(X)
                if extra <= 0:
                    break
                if spent + extra * lv.cost(level) > budget:
                    return None
                for _ in range(extra):
                    run_replicate(X, lv, level)
            path.append({"lever": lv.name, "level": level,
                         "replicates": len(X), "half_width": hw})
            esc += 1
            X = None
        return None                # not reached (levels infinite)

    result = drive(lv, winner[1], winner[2])
    if result is None:
        return fall_through()
    level, X, est, hw = result
    per_node = (np.mean(np.stack(X), axis=0)
                if req.return_per_node else None)
    return est, per_node, info("sampled", level, est, hw, lv)
