"""Escalation levers: one per portfolio method.

Every lever exposes the same protocol the controller drives:

- ``levels(start)`` / ``start_level()`` / ``is_exact(level)`` — the
  escalation ladder;
- ``width_bound(level)`` — certified support width for the
  empirical-Bernstein range term (per-node, or per-total for
  ``ci_mode="total"`` levers);
- ``var_proxy(level)`` — an upfront analytic variance certificate used
  only to *rank* methods in the portfolio race, never for the CI;
- ``cost(level)`` / ``fixed_cost(level)`` / ``exact_work()`` — the work
  model, in one shared flop unit so projected work is comparable
  *across* levers (the thing the portfolio ranks on);
- ``replicate(level, key)`` — one independent replicate, returning the
  (n,) per-node estimate vector;
- ``max_replicates(policy)`` — per-method replicate ceiling before the
  controller escalates the level instead;
- ``ci_mode`` — ``"per_node"`` (independent per-node columns feed the
  EB bound directly) or ``"total"`` (per-node values are correlated —
  a global edge mask — so the CI is computed on replicate totals with
  the certified total width; honest, fewer degrees of freedom).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..core.count import _tile_batches, dag_count_flops
from .bounds import EstimatorPolicy, _falling_comb
from .certificates import _Certificates
from .methods import ColorCoding, EdgeSample


def _accumulate(per_node: np.ndarray, vals, tile) -> None:
    vals = np.asarray(jax.block_until_ready(vals), np.float64)
    sel = tile >= 0
    np.add.at(per_node, tile[sel], vals[sel])


def _bucket_flops(cap: int, batch: int, S: int, n_iters: int,
                  r: int) -> float:
    """Subset-tile flop model for one bucket at kept capacity S."""
    S = min(cap, S)
    return (8.0 * batch * cap                     # score + select
            + 4.0 * batch * S * S * n_iters       # pair lookups
            + dag_count_flops(S, batch, r))       # count


def exact_flops(eng, entry, r: int) -> float:
    """The session's exact tile work in the shared flop unit — the
    common denominator of every lever's budget and the portfolio's
    projected-work ranking."""
    n_iters = eng.og.lookup_iters
    return sum(_bucket_flops(b.capacity, b.batch, b.capacity, n_iters, r)
               for b in entry.plan.buckets)


def _plan_parts(eng, entry, cert: _Certificates, r: int) -> tuple:
    """Per-bucket split of the work: the certified-deterministic
    per-node contribution (computed once, numpy) and the stochastic
    node list a replicate actually has to sample — pure functions of
    (plan, certificates, r), cached on the entry across queries."""
    parts = entry._aux.get(("subset_parts", r))
    if parts is None:
        det_parts: dict[int, np.ndarray] = {}
        stoch_nodes: dict[int, np.ndarray] = {}
        det_all = np.zeros(eng.og.n, np.float64)
        det_all[cert.complete] = _falling_comb(cert.deg[cert.complete], r)
        for bi, b in enumerate(entry.plan.buckets):
            real = b.nodes[b.nodes >= 0]
            det = np.zeros(eng.og.n, np.float64)
            det[real] = det_all[real]
            det_parts[bi] = det
            stoch = real[cert.stochastic[real]].astype(np.int32)
            pad = (-len(stoch)) % 8
            stoch_nodes[bi] = np.concatenate(
                [stoch, np.full(pad, -1, np.int32)])
        parts = entry._aux[("subset_parts", r)] = (det_parts, stoch_nodes)
    return parts


class _MaskLever:
    """method="edge"/"color" with a rel_error target: escalate the
    method's own knob through the standard masked tile path. ``p`` and
    ``colors`` are traced, so every escalation reuses the session's
    compiled executables — escalation recompiles nothing. The dense tile
    cost does not shrink with the mask, so the work model prices
    replicates by the paper's MRC round-3 volume shrink (the quantity
    the sampling theorems actually buy) rather than by tile FLOPs."""

    ci_mode = "per_node"

    def __init__(self, eng, backend, entry, req, cert: _Certificates,
                 policy: EstimatorPolicy, method: str = None) -> None:
        self.eng, self.backend, self.entry = eng, backend, entry
        self.req, self.cert, self.policy = req, cert, policy
        # ``method`` names the mask when the lever competes inside the
        # "auto" portfolio (req.method is "auto" there)
        self.name = method or req.method
        self.r = req.k - 1
        self._exact = exact_flops(eng, entry, self.r)

    def levels(self, start) -> Iterator[float]:
        if self.name == "edge":
            p = start
            while True:
                yield min(1.0, p)
                p *= 2.0
        else:
            c = start
            while True:
                yield max(1, c)
                c //= 2

    def start_level(self):
        return (self.policy.init_p if self.name == "edge"
                else self.policy.init_colors)

    def is_exact(self, level) -> bool:
        return level >= 1.0 if self.name == "edge" else level <= 1

    def max_replicates(self, policy: EstimatorPolicy) -> int:
        return policy.max_replicates_per_level

    def _scale(self, level) -> float:
        """Largest per-node rescale factor the mask applies."""
        r = self.r
        if self.name == "edge":
            return float(level) ** -(r * (r - 1) / 2.0)
        return float(level) ** (r - 1)

    def _unit_widths(self, level) -> np.ndarray:
        """Every non-zero-certified unit is stochastic under a mask
        (even a clique unit), with masked count ≤ its Kruskal–Katona
        bound and rescale ≤ the mask's scale."""
        c = self.cert
        live = c.stochastic | c.complete
        if not live.any():
            return np.zeros(0, np.float64)
        kk = np.where(c.complete, _falling_comb(c.deg, self.r), c.kk)
        return kk[live] * self._scale(level)

    def width_bound(self, level) -> float:
        ws = self._unit_widths(level)
        return float(ws.max()) if len(ws) else 0.0

    def var_proxy(self, level) -> float:
        ws = self._unit_widths(level)
        return float(((ws / 2.0) ** 2).sum())

    def _factor(self, level) -> float:
        return float(level) if self.name == "edge" else 1.0 / float(level)

    def cost(self, level) -> float:
        return self._exact * self._factor(level)

    def fixed_cost(self, level) -> float:
        return 0.0

    def exact_work(self) -> float:
        return self._exact

    def replicate(self, level, key: jax.Array) -> np.ndarray:
        # rebuild via the typed spec: pins exactly the knob this mask
        # reads, and internal replicates never trip the legacy-string
        # deprecation shim
        spec = (EdgeSample(p=float(level)) if self.name == "edge" else
                ColorCoding(colors=int(level),
                            smooth=self.name == "color_smooth"))
        child = dataclasses.replace(self.req, rel_error=None,
                                    return_per_node=True, method=spec)
        _, per_node = self.backend.run(self.eng, self.entry, child, key)
        return per_node


class WedgeLever:
    """method="wedge": escalate the per-unit draw count S. The kernel
    (:func:`repro.core.count.wedge_tile_values`) never materializes the
    dense tile, so replicates cost O(S·capacity) per stochastic unit —
    independent of d², which is why this lever dominates on
    degree-skewed graphs. Certified-complete units are deterministic
    under wedge draws too (every r-subset of a clique closes), so a
    replicate samples only the stochastic tail.

    There is no exact endpoint on this ladder (X_u has support width
    C(d_u, r) at every S), so escalation ends via the replicate budget
    /fall-through, and the lever earns ``policy.wedge_max_replicates``:
    its EB range term shrinks only with R, and its replicates are nearly
    free."""

    name = "wedge"
    ci_mode = "per_node"

    def __init__(self, eng, backend, entry, r: int, cert: _Certificates,
                 policy: EstimatorPolicy, choice: str = "auto") -> None:
        self.eng, self.backend, self.entry, self.r = eng, backend, entry, r
        self.kind = backend.kind
        self.cert = cert
        self.policy = policy
        self.choice = choice
        self._det_parts, self._stoch_nodes = _plan_parts(eng, entry, cert,
                                                         r)
        self._exact = exact_flops(eng, entry, r)

    def levels(self, start: int) -> Iterator[int]:
        S = start
        while True:
            yield S
            S *= 2

    def start_level(self) -> int:
        return self.policy.init_samples

    def is_exact(self, S: int) -> bool:
        return False

    def max_replicates(self, policy: EstimatorPolicy) -> int:
        return max(policy.wedge_max_replicates,
                   policy.max_replicates_per_level)

    def _stoch_combs(self) -> np.ndarray:
        c = self.cert
        return _falling_comb(c.deg[c.stochastic], self.r)

    def width_bound(self, S: int) -> float:
        """X_u = C(d_u, r)·closed/S ∈ [0, C(d_u, r)] regardless of S —
        the draw count shrinks the variance, never the support."""
        cd = self._stoch_combs()
        return float(cd.max()) if len(cd) else 0.0

    def var_proxy(self, S: int) -> float:
        """Var(X_u) = C(d,r)²·π(1−π)/S with π = q_{u,r}/C(d,r) ≤
        kk_u/C(d,r), so Var ≤ C(d,r)·kk_u/S, summed over stochastic
        units."""
        c = self.cert
        cd = self._stoch_combs()
        if not len(cd):
            return 0.0
        return float((cd * c.kk[c.stochastic]).sum() / max(S, 1))

    def _bucket_flops(self, cap: int, batch: int, S: int) -> float:
        n_iters = self.eng.og.lookup_iters
        return float(S) * batch * (10.0 * cap
                                   + 4.0 * self.r * self.r * n_iters)

    def cost(self, S: int) -> float:
        return sum(self._bucket_flops(b.capacity,
                                      len(self._stoch_nodes[bi]), S)
                   for bi, b in enumerate(self.entry.plan.buckets))

    def fixed_cost(self, S: int) -> float:
        return 0.0

    def exact_work(self) -> float:
        return self._exact

    def replicate(self, S: int, key: jax.Array) -> np.ndarray:
        from ..engine.backends import tile_executable
        eng, r, kind = self.eng, self.r, self.kind
        per_node = np.zeros(eng.og.n, np.float64)
        for bi, b in enumerate(self.entry.plan.buckets):
            per_node += self._det_parts[bi]
            nodes = self._stoch_nodes[bi]
            if not len(nodes):
                continue
            # the representation choice is moot (no adjacency tile);
            # "bits" keeps the cache key aligned with the backends'
            fn = tile_executable(eng, kind, "bits", b.capacity, r,
                                 "wedge")
            # byte-account the gather/score transients, not a D² tile
            for tile in _tile_batches(nodes, b.capacity,
                                      self.backend.budget,
                                      unit_bytes=16 * b.capacity + 64):
                _accumulate(per_node,
                            fn(eng.csr, jnp.asarray(tile), key, p=1.0,
                               c=S), tile)
        return per_node


class SparsifyLever:
    """method="sparsify": escalate the edge keep-rate q toward 1. Each
    replicate counts exactly on a freshly sparsified child graph
    (through the engine's normal pipeline) and rescales by q^{−C(k,2)}.

    Honesty note: one replicate uses ONE global edge mask, so per-node
    counts are positively associated (an FKG inequality — surviving
    cliques share surviving edges) and the per-node EB variance would
    understate the truth. ``ci_mode="total"`` routes the CI through
    replicate totals with the certified total width
    q^{−C(k,2)}·det_upper instead — honest, but with only (R−1) degrees
    of freedom the lever usually prices itself out of the portfolio and
    exists mostly as the *direct* ``Sparsify(q=...)`` method, whose
    unbiasedness the calibration tier checks statistically."""

    name = "sparsify"
    ci_mode = "total"

    def __init__(self, eng, backend, entry, req, r: int,
                 cert: _Certificates, policy: EstimatorPolicy) -> None:
        self.eng, self.backend, self.entry = eng, backend, entry
        self.req, self.cert, self.policy = req, cert, policy
        self.r = r
        self.k = r + 1
        self._exact = exact_flops(eng, entry, r)

    def levels(self, start: float) -> Iterator[float]:
        q = start
        while True:
            yield min(q, 1.0)
            q = 1.0 - (1.0 - q) / 2.0

    def start_level(self) -> float:
        return self.policy.init_q

    def is_exact(self, q: float) -> bool:
        return q >= 0.999

    def max_replicates(self, policy: EstimatorPolicy) -> int:
        return policy.max_replicates_per_level

    def _scale(self, q: float) -> float:
        return float(q) ** -(self.k * (self.k - 1) / 2.0)

    def width_bound(self, q: float) -> float:
        """Certified width of the replicate TOTAL: the child count is at
        most the certified ceiling on q_k, rescaled."""
        return self._scale(q) * self.cert.det_upper

    def var_proxy(self, q: float) -> float:
        """Per surviving clique the rescaled indicator has variance
        ≈ scale − 1; ≤ det_upper cliques (covariance ignored — this is
        the DOULION ranking certificate, not the CI)."""
        return self.cert.det_upper * max(self._scale(q) - 1.0, 0.0)

    def cost(self, q: float) -> float:
        """Exact work on the child graph: edge survival thins every
        Γ⁺(u) by ~q (plan/CSR rebuild overhead not modeled)."""
        return self._exact * float(q)

    def fixed_cost(self, q: float) -> float:
        return 0.0

    def exact_work(self) -> float:
        return self._exact

    def replicate(self, q: float, key: jax.Array) -> np.ndarray:
        from ..engine.report import CountRequest
        data = np.asarray(jax.random.key_data(key)).ravel()
        seed = int(data[-1]) & 0x7FFFFFFF
        child = self.eng._sparsify_child(float(q), seed)
        rep = child.submit(CountRequest(
            k=self.k, method="exact", backend=self.backend.name,
            engine=self.req.engine, return_per_node=True))
        return np.asarray(rep.per_node, np.float64) * self._scale(q)
