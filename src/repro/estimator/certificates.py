"""Per-plan density certificates: one cheap r=2 tile pass classifies
every work unit (complete / zero / stochastic) before any sampling, and
prices each portfolio method's certificate. Cached on the PlanEntry."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.count import _tile_batches, pick_tile_repr
from .bounds import _falling_comb, kruskal_katona_bound


class _Certificates:
    """Per-unit (d_u, e_u) and what they certify for order r = k−1."""

    def __init__(self, deg: np.ndarray, edges: np.ndarray, in_plan:
                 np.ndarray, r: int) -> None:
        self.deg, self.edges, self.in_plan, self.r = deg, edges, in_plan, r
        need = r * (r - 1) / 2.0
        self.complete = in_plan & (edges >= deg * (deg - 1.0) / 2.0)
        self.zero = in_plan & (edges < need)
        self.stochastic = in_plan & ~self.complete & ~self.zero
        # deterministic structural lower bound on the true q_k: clique
        # units contribute exactly C(d, r), everything else ≥ 0
        self.det_lower = float(_falling_comb(deg[self.complete], r).sum())
        self.kk = np.zeros_like(deg)
        self.kk[self.stochastic] = kruskal_katona_bound(
            edges[self.stochastic], r)

    @property
    def det_upper(self) -> float:
        """Structural *upper* bound on q_k over the plan's units:
        complete units hold exactly C(d, r), stochastic units at most
        their Kruskal–Katona count — the certified support ceiling the
        sparsification lever rescales for its total-width term."""
        return self.det_lower + float(self.kk[self.stochastic].sum())


def _certificates(eng, backend, entry, r: int,
                  choice: str = "auto") -> _Certificates:
    """Compute (once per plan entry per backend kind) each unit's
    out-neighborhood edge count via the exact r=2 tile — one extraction
    pass, no counting recursion — and derive the certificates.

    ``choice`` is the request's forced tile representation; the cached
    certificate *values* are representation-independent (both paths are
    bit-exact), so the cache key deliberately omits it."""
    from ..engine.backends import tile_executable
    kind = backend.kind
    cache = entry._aux.setdefault("certificates", {})
    cert = cache.get((kind, r))
    if cert is not None:
        return cert
    n = eng.og.n
    edges = np.zeros(n, np.float64)
    in_plan = np.zeros(n, bool)
    for b in entry.plan.buckets:
        # r=2 is a pure popcount — the packed representation always wins
        # (unless the request forces dense)
        repr_ = pick_tile_repr(r=2, capacity=b.capacity, choice=choice,
                               elem_budget=backend.budget)
        fn = tile_executable(eng, kind, repr_, b.capacity, 2, "exact")
        for tile in _tile_batches(b.nodes, b.capacity, backend.budget,
                                  repr_):
            vals = np.asarray(jax.block_until_ready(
                fn(eng.csr, jnp.asarray(tile), jax.random.PRNGKey(0),
                   p=1.0, c=1)), np.float64)
            sel = tile >= 0
            np.add.at(edges, tile[sel], vals[sel])
            in_plan[tile[sel]] = True
    deg = eng.og.out_deg.astype(np.float64)
    cert = _Certificates(deg, edges, in_plan, r)
    cache[(kind, r)] = cert
    return cert
