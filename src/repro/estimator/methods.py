"""Typed method specs: the registry behind ``CountRequest(method=...)``.

The legacy interface was a string plus a soup of loose knobs —
``CountRequest(method="color", colors=10, p=0.1, rel_error=...)`` — in
which nothing says *which* knobs the method actually reads. A
:class:`MethodSpec` names them:

    CountRequest(k=5, method=EdgeSample(p=0.5))
    CountRequest(k=5, method=WedgeSample(samples=128))
    CountRequest(k=4, method=Sparsify(q=0.25))
    CountRequest(k=5, method=Auto(rel_error=0.05, confidence=0.99))

``CountRequest`` normalizes a spec into its legacy knob fields at
construction (see ``request_kwargs``), so everything downstream — the
engine dispatch, the traced ``p``/``c`` tile operands, ``query_key`` —
is unchanged, and a spec resolves to the *same* durable store key as
the legacy spelling it replaces. Legacy method strings keep working via
deprecation shims on ``CountRequest``.

Knob slot-reuse (deliberate, keyed into the store contract): wedge
sampling's ``samples`` rides the request's ``colors`` field and
sparsification's ``q`` rides ``p`` — both travel to every backend on
the already-traced ``c``/``p`` tile operands, so no backend (local,
pallas, shard_map, ooc) needed a plumbing change to learn the new
methods, and the 13-slot ``query_key`` layout (hashed by the PR 8
result store) is untouched.

This module is import-cycle free: it knows nothing about the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


class MethodSpec:
    """Base of every typed method spec.

    Subclasses set ``method`` (the canonical engine method string) and
    override :meth:`request_kwargs` to name the ``CountRequest`` fields
    they pin. Specs are frozen dataclasses: hashable, comparable,
    printable — fit for test parametrization and telemetry.
    """

    method = "exact"

    def request_kwargs(self) -> dict:
        """CountRequest field values this spec pins (knob slot-reuse
        included: e.g. ``WedgeSample.samples`` maps to ``colors``)."""
        return {}


@dataclasses.dataclass(frozen=True)
class Exact(MethodSpec):
    """Exact counting (the default; never deprecated as a string)."""

    method = "exact"


@dataclasses.dataclass(frozen=True)
class NIPlusPlus(MethodSpec):
    """The NI++ triangle baseline (k=3 only; exact tile path)."""

    method = "ni++"


@dataclasses.dataclass(frozen=True)
class EdgeSample(MethodSpec):
    """SE_k: Bernoulli(p) pair mask, rescale p^{-C(k-1,2)}."""

    p: float = 0.1

    method = "edge"

    def request_kwargs(self) -> dict:
        return {"p": self.p}


@dataclasses.dataclass(frozen=True)
class ColorCoding(MethodSpec):
    """SIC_k: monochromatic-pair mask with ``colors`` colors
    (``smooth=True`` is the §5.1 degree-smoothed variant)."""

    colors: int = 10
    smooth: bool = False

    @property
    def method(self) -> str:
        return "color_smooth" if self.smooth else "color"

    def request_kwargs(self) -> dict:
        return {"colors": self.colors}


@dataclasses.dataclass(frozen=True)
class WedgeSample(MethodSpec):
    """Wedge sampling (Kolda et al.), generalized to any k: per unit u,
    ``samples`` uniform (k−1)-subsets of Γ⁺(u) are closed against the
    adjacency; X_u = C(d_u, k−1)·closed/samples. Never materializes the
    dense tile, so it wins exactly where exact counting is hardest —
    degree-skewed graphs. ``samples`` rides the request's ``colors``
    slot (see the module docstring)."""

    samples: int = 64

    method = "wedge"

    def request_kwargs(self) -> dict:
        return {"colors": self.samples}


@dataclasses.dataclass(frozen=True)
class Sparsify(MethodSpec):
    """DOULION-style edge sparsification (Tsourakakis et al.): keep
    each edge with probability ``q``, count exactly on the sparsified
    graph through the normal engine pipeline (any backend, including
    bitset and ooc), rescale by q^{−C(k,2)}. ``q`` rides the request's
    ``p`` slot (see the module docstring)."""

    q: float = 0.5

    method = "sparsify"

    def request_kwargs(self) -> dict:
        return {"p": self.q}


@dataclasses.dataclass(frozen=True)
class Auto(MethodSpec):
    """Accuracy contract: the adaptive controller races the method
    portfolio and escalates the winner until the empirical-Bernstein CI
    half-width is within ``rel_error``·estimate at ``confidence`` (or
    falls through to exact when that is provably cheaper).
    ``rel_error=None`` uses the engine's :class:`EstimatorPolicy`
    default."""

    rel_error: Optional[float] = None
    confidence: float = 0.99

    method = "auto"

    def request_kwargs(self) -> dict:
        return {"rel_error": self.rel_error,
                "confidence": self.confidence}


# legacy method strings that still work on CountRequest but emit a
# DeprecationWarning ("exact" stays warning-free — it is the field
# default and would fire on every construction; "wedge"/"sparsify" are
# new and canonical in both spellings)
DEPRECATED_STRINGS = ("edge", "color", "color_smooth", "ni++", "auto")

SPECS = {
    "exact": Exact,
    "ni++": NIPlusPlus,
    "edge": EdgeSample,
    "color": ColorCoding,
    "color_smooth": ColorCoding,
    "wedge": WedgeSample,
    "sparsify": Sparsify,
    "auto": Auto,
}


def from_string(method: str, *, p: float = 0.1, colors: int = 10,
                rel_error: Optional[float] = None,
                confidence: float = 0.99) -> MethodSpec:
    """Build the canonical spec for a legacy (method, knobs) spelling —
    the migration shim the CLI and ``CountRequest.spec`` use. Raises
    ``ValueError`` on unknown names."""
    if method not in SPECS:
        raise ValueError(f"unknown method {method!r}; "
                         f"one of {tuple(SPECS)}")
    if method == "exact":
        return Exact()
    if method == "ni++":
        return NIPlusPlus()
    if method == "edge":
        return EdgeSample(p=p)
    if method in ("color", "color_smooth"):
        return ColorCoding(colors=colors, smooth=method == "color_smooth")
    if method == "wedge":
        return WedgeSample(samples=colors)
    if method == "sparsify":
        return Sparsify(q=p)
    return Auto(rel_error=rel_error, confidence=confidence)
