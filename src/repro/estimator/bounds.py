"""Concentration / extremal machinery shared by every estimator method:
the Kruskal–Katona support bounds and the empirical-Bernstein interval,
plus the controller policy knobs."""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class EstimatorPolicy:
    """Controller knobs (engine-wide; requests carry only the target)."""
    default_rel_error: float = 0.05   # when method="auto" sets no target
    pilot_replicates: int = 2         # replicates per new operating point
    max_replicates_per_level: int = 24  # beyond this, escalate instead
    init_kept: int = 8                # subset lever: starting capacity
    init_p: float = 1.0 / 16.0        # edge lever: starting rate
    init_colors: int = 16             # color lever: starting color count
    init_samples: int = 64            # wedge lever: starting draw count
    init_q: float = 0.5               # sparsify lever: starting keep rate
    # the wedge lever's replicates are nearly free (no dense tile), and
    # its EB range term only shrinks with R — so it earns a much higher
    # replicate ceiling before escalating its draw count
    wedge_max_replicates: int = 256
    max_escalations: int = 16         # hard cap → exact fall-through
    work_slack: float = 0.9           # sampled budget vs exact work


DEFAULT_POLICY = EstimatorPolicy()


def _falling_comb(n: np.ndarray, r: int) -> np.ndarray:
    """C(n, r) for float arrays via falling factorials, 0 where n < r."""
    out = np.ones_like(n, dtype=np.float64)
    for i in range(r):
        out *= np.maximum(n - i, 0.0)
    return out / math.factorial(r)


def kruskal_katona_bound(edges: np.ndarray, r: int) -> np.ndarray:
    """Max number of r-cliques in any graph with ``edges`` edges: the
    colex graphs are extremal, giving C(x, r) + C(j, r−1) for
    e = C(x, 2) + j, 0 ≤ j < x."""
    e = np.maximum(np.asarray(edges, np.float64), 0.0)
    x = np.floor((1.0 + np.sqrt(1.0 + 8.0 * e)) / 2.0)
    j = e - x * (x - 1.0) / 2.0
    return _falling_comb(x, r) + _falling_comb(j, r - 1)


def empirical_bernstein(X: np.ndarray, confidence: float, M: float
                        ) -> tuple[float, float, float]:
    """(estimate, half_width, V̂) for replicate matrix X of shape (R, n):
    R independent replicates of the n per-node estimates, with certified
    per-node support width ≤ M.

    The variance of the total is the sum of per-node variances (per-node
    keys decorrelate nodes), so V̂ pools (R−1) degrees of freedom from
    every node. The range term uses the *certified* width M, not the
    observed range — R lucky all-zero replicates of a rare-clique unit
    cannot fake a tight interval. M = 0 means every unit is certified
    deterministic and the interval honestly collapses to a point.

    Estimators whose per-node values are *correlated* (a global edge
    mask: sparsification) must not feed per-node columns here — they
    pass replicate totals as an (R, 1) matrix with the certified total
    width, trading degrees of freedom for honesty.
    """
    R = X.shape[0]
    est = float(X.sum(axis=1).mean())
    V = float(X.var(axis=0, ddof=1).sum()) if R > 1 else float("inf")
    L = math.log(3.0 / max(1.0 - confidence, 1e-12))
    if not np.isfinite(V):
        return est, float("inf"), V
    hw = math.sqrt(2.0 * V * L / R) + 3.0 * M * L / max(R - 1, 1)
    return est, hw, V


def replicates_to_target(V: float, M: float, confidence: float,
                         target_hw: float) -> int:
    """Smallest R with sqrt(2VL/R) + 3ML/(R−1) ≤ target (solve the
    quadratic in 1/sqrt(R), then pay the −1 back)."""
    if target_hw <= 0.0 or not np.isfinite(V):
        return 1 << 30
    L = math.log(3.0 / max(1.0 - confidence, 1e-12))
    a, b = math.sqrt(2.0 * V * L), 3.0 * M * L
    root = (a + math.sqrt(a * a + 4.0 * target_hw * b)) / (2.0 * target_hw)
    return max(1, int(math.ceil(root * root)) + 1)


# backward-compatible private alias (pre-package name)
_replicates_to_target = replicates_to_target
