"""Accuracy-targeted estimation: a typed method portfolio behind
``CountRequest(method=...)``.

The package splits along the controller's own seams:

- :mod:`repro.estimator.methods` — the typed :class:`MethodSpec`
  registry (``EdgeSample``, ``ColorCoding``, ``WedgeSample``,
  ``Sparsify``, ``Auto``, …) that ``CountRequest`` normalizes into its
  legacy knob fields, plus the deprecation shims for legacy strings;
- :mod:`repro.estimator.bounds` — Kruskal–Katona support bounds, the
  empirical-Bernstein interval, and the :class:`EstimatorPolicy` knobs;
- :mod:`repro.estimator.certificates` — the per-plan r=2 density pass
  that classifies every work unit before any sampling;
- :mod:`repro.estimator.levers` — one escalation lever per method
  (edge/color masks, wedge sampling, edge sparsification), all
  pricing work in one shared flop unit;
- :mod:`repro.estimator.controller` — the portfolio race + escalation
  loop behind ``method="auto"`` and every ``rel_error`` contract.

Everything the engine, tests, and notebooks imported from the old flat
``repro.estimator`` module is re-exported here unchanged.
"""
from .bounds import (DEFAULT_POLICY, EstimatorPolicy,  # noqa: F401
                     empirical_bernstein, kruskal_katona_bound,
                     replicates_to_target, _falling_comb,
                     _replicates_to_target)
from .certificates import _Certificates, _certificates  # noqa: F401
from .controller import run_adaptive  # noqa: F401
from .levers import (SparsifyLever, WedgeLever, _MaskLever,  # noqa: F401
                     _accumulate, exact_flops)
from .methods import (DEPRECATED_STRINGS, SPECS, Auto,  # noqa: F401
                      ColorCoding, EdgeSample, Exact, MethodSpec,
                      NIPlusPlus, Sparsify, WedgeSample, from_string)

__all__ = [
    "run_adaptive", "EstimatorPolicy", "DEFAULT_POLICY",
    "empirical_bernstein", "kruskal_katona_bound", "replicates_to_target",
    "exact_flops",
    "MethodSpec", "Exact", "NIPlusPlus", "EdgeSample", "ColorCoding",
    "WedgeSample", "Sparsify", "Auto", "SPECS", "DEPRECATED_STRINGS",
    "from_string",
]
