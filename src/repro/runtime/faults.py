"""Fault injection + retry orchestration.

Real pods lose nodes; the orchestration answer is (a) checkpoint/restart
for the training loop and (b) idempotent, retryable work units for the
clique engine's rounds. Both are driven through :class:`FaultDomain` so
tests can inject deterministic failures and assert bit-identical
recovery.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class SimulatedFault(RuntimeError):
    pass


@dataclasses.dataclass
class FaultDomain:
    """Deterministic failure injector: fails the Nth..(N+k)th calls."""
    fail_at: tuple[int, ...] = ()
    calls: int = 0
    max_retries: int = 3
    backoff_s: float = 0.0

    def run(self, fn: Callable, *args, **kwargs):
        attempts = 0
        while True:
            self.calls += 1
            if self.calls - 1 in self.fail_at:
                attempts += 1
                if attempts > self.max_retries:
                    raise SimulatedFault(
                        f"work unit failed {attempts} times")
                if self.backoff_s:
                    time.sleep(self.backoff_s)
                continue
            return fn(*args, **kwargs)


@dataclasses.dataclass
class RoundScheduler:
    """Executes a list of idempotent work units with retry + progress
    journal — the clique engine's "speculative execution" stand-in.

    Each unit is (name, thunk); results are kept so a re-run after a
    mid-round crash (journal says which units completed) only re-executes
    the missing ones. The engine's units are pure functions of
    (graph, plan, seed), so re-execution is deterministic.
    """
    faults: Optional[FaultDomain] = None
    journal: dict = dataclasses.field(default_factory=dict)

    def run_round(self, units: list[tuple[str, Callable]]) -> dict:
        for name, thunk in units:
            if name in self.journal:
                continue  # already done before the crash
            runner = self.faults.run if self.faults else (lambda f: f())
            self.journal[name] = runner(thunk)
        return dict(self.journal)
