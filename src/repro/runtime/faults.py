"""Fault injection + retry orchestration.

Real pods lose nodes; the orchestration answer is (a) checkpoint/restart
for the training loop and (b) idempotent, retryable work units for the
clique engine's rounds. Both are driven through :class:`FaultDomain` so
tests can inject deterministic failures and assert bit-identical
recovery. The out-of-core scheduler (:mod:`repro.scheduler`) builds its
per-task retry loop on the same domain: injection via
:meth:`FaultDomain.maybe_fail`, sleeps via the exponential-backoff
schedule below.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Callable, Optional


class SimulatedFault(RuntimeError):
    pass


def backoff_delay(attempt: int, *, base_s: float, factor: float = 2.0,
                  cap_s: float = 30.0, jitter: float = 0.0,
                  seed: int = 0) -> float:
    """Exponential backoff with deterministic jitter.

    ``attempt`` is 1-based (the sleep before the attempt-th retry). The
    geometric term ``base_s * factor**(attempt-1)`` is capped at
    ``cap_s`` *before* jitter, then a deterministic fraction of the
    capped delay — ``jitter * frac(seed, attempt)`` with ``frac`` a
    pure hash into [0, 1) — is added on top, so two domains with the
    same seed sleep the identical schedule (reproducible tests, no
    shared-RNG coupling between concurrent retry loops) while different
    seeds decorrelate (no thundering-herd resubmission).
    """
    assert attempt >= 1, "attempt is 1-based"
    d = min(base_s * factor ** (attempt - 1), cap_s)
    if jitter:
        # crc32 as a cheap stable hash: identical across processes and
        # platforms (unlike hash()), seeded, uniform enough for jitter
        h = zlib.crc32(f"{seed}:{attempt}".encode()) & 0xFFFFFFFF
        d += d * jitter * (h / 2**32)
    return d


@dataclasses.dataclass
class FaultDomain:
    """Deterministic failure injector + retry/backoff policy.

    Injection: :meth:`maybe_fail` raises :class:`SimulatedFault` when
    the global call index is listed in ``fail_at`` (thread-safe — the
    scheduler's workers share one domain). Retry: :meth:`run` wraps a
    thunk with the injection check and an exponential-backoff retry
    loop (``backoff_s`` is the base delay; ``backoff_factor`` the
    per-retry growth, capped at ``backoff_cap_s``, with deterministic
    ``jitter`` seeded by ``jitter_seed``). Every sleep actually taken
    is recorded in ``sleeps`` so tests pin the schedule.
    """
    fail_at: tuple[int, ...] = ()
    calls: int = 0
    max_retries: int = 3
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    backoff_cap_s: float = 30.0
    jitter: float = 0.0
    jitter_seed: int = 0
    sleeps: list = dataclasses.field(default_factory=list)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def maybe_fail(self) -> None:
        """Count one work-unit attempt; raise if it is an injected
        failure. Thread-safe; the counter is the injection index."""
        with self._lock:
            idx = self.calls
            self.calls += 1
        if idx in self.fail_at:
            raise SimulatedFault(f"injected fault at call {idx}")

    def backoff_schedule(self, attempt: int) -> float:
        """Delay before the ``attempt``-th retry (1-based)."""
        return backoff_delay(attempt, base_s=self.backoff_s,
                             factor=self.backoff_factor,
                             cap_s=self.backoff_cap_s,
                             jitter=self.jitter, seed=self.jitter_seed)

    def sleep_before_retry(self, attempt: int) -> float:
        """Sleep the schedule's delay for retry ``attempt`` and record
        it (the scheduler's own retry loop calls this directly)."""
        d = self.backoff_schedule(attempt)
        self.sleeps.append(d)
        if d:
            time.sleep(d)
        return d

    def run(self, fn: Callable, *args, **kwargs):
        attempts = 0
        while True:
            try:
                self.maybe_fail()
            except SimulatedFault:
                attempts += 1
                if attempts > self.max_retries:
                    raise SimulatedFault(
                        f"work unit failed {attempts} times")
                if self.backoff_s:
                    self.sleep_before_retry(attempts)
                continue
            return fn(*args, **kwargs)


@dataclasses.dataclass
class RoundScheduler:
    """Executes a list of idempotent work units with retry + progress
    journal — the clique engine's "speculative execution" stand-in.

    Each unit is (name, thunk); results are kept so a re-run after a
    mid-round crash (journal says which units completed) only re-executes
    the missing ones. The engine's units are pure functions of
    (graph, plan, seed), so re-execution is deterministic.

    The production version of this idea — disk-backed ledger, work
    stealing, straggler speculation — is :mod:`repro.scheduler`.
    """
    faults: Optional[FaultDomain] = None
    journal: dict = dataclasses.field(default_factory=dict)

    def run_round(self, units: list[tuple[str, Callable]]) -> dict:
        for name, thunk in units:
            if name in self.journal:
                continue  # already done before the crash
            runner = self.faults.run if self.faults else (lambda f: f())
            self.journal[name] = runner(thunk)
        return dict(self.journal)
