"""Deterministic chaos schedules for the multi-host scheduler.

A chaos spec is a comma-separated list of events, each anchored to the
coordinator's *commit count* — the only clock that is deterministic
across machines and load levels (wall-clock triggers would make the
tier-1 smoke flaky). Grammar:

- ``kill:<i>@<c>``    — SIGKILL executor ``i`` after ``c`` commits,
  deferred until the victim actually holds a lease so the smoke's
  "≥1 lease expiry, ≥1 reassignment" assertion is deterministic.
- ``hang:<i>@<c>/<s>`` — SIGSTOP executor ``i`` after ``c`` commits
  (again once it holds a lease), SIGCONT after ``s`` seconds. With
  ``s`` ≳ 2 leases the task is reassigned *and* the thawed original
  later reports a duplicate completion — the commit-dup path.
- ``part:<i>@<c>``    — partition: the coordinator drops executor
  ``i``'s connection after ``c`` commits. The process survives; its
  leases expire and its work moves.
- ``slow:<i>/<s>``    — every task on executor ``i`` takes ``s`` extra
  seconds, from the start of the run. This is the deterministic
  cross-host-speculation forcer: the slowed host's tasks blow the
  p95-rate envelope and their speculative copies land on fast hosts.

The monkey itself only decides *when*; *how* is injected by the
coordinator as callbacks (``kill``/``stop``/``cont``/``partition``), so
this module stays process-model-agnostic and unit-testable without
spawning executors.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional


@dataclasses.dataclass
class ChaosEvent:
    action: str             # "kill" | "hang" | "part" | "slow"
    executor: int
    after_commits: int = 0  # fire once this many tasks have committed
    seconds: float = 0.0    # hang: stop duration; slow: per-task delay


def parse_chaos(spec: str) -> list[ChaosEvent]:
    """Parse ``kill:1@2,hang:0@3/2.0,slow:2/1.5`` into events."""
    events: list[ChaosEvent] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            action, rest = part.split(":", 1)
            seconds = 0.0
            if "/" in rest:
                rest, sec = rest.rsplit("/", 1)
                seconds = float(sec)
            after = 0
            if "@" in rest:
                rest, at = rest.split("@", 1)
                after = int(at)
            executor = int(rest)
        except ValueError as e:
            raise ValueError(
                f"bad chaos event {part!r} (grammar: action:executor"
                f"[@after_commits][/seconds])") from e
        if action not in ("kill", "hang", "part", "slow"):
            raise ValueError(f"unknown chaos action {action!r} in "
                             f"{part!r}")
        if action == "hang" and seconds <= 0:
            raise ValueError(f"hang needs a /seconds duration: {part!r}")
        if action == "slow" and seconds <= 0:
            raise ValueError(f"slow needs a /seconds delay: {part!r}")
        events.append(ChaosEvent(action=action, executor=executor,
                                 after_commits=after, seconds=seconds))
    return events


class ChaosMonkey:
    """Fires a parsed schedule against a set of executors.

    ``on_commit`` is called by the coordinator after every committed
    task; due events whose victim does not yet hold a lease stay armed
    (kill/hang only — killing an idle executor would expire no lease
    and the smoke's assertions would race). ``applied`` records what
    actually fired, for telemetry.
    """

    def __init__(self, events: list[ChaosEvent], *,
                 kill: Optional[Callable[[int], None]] = None,
                 stop: Optional[Callable[[int], None]] = None,
                 cont: Optional[Callable[[int], None]] = None,
                 partition: Optional[Callable[[int], None]] = None
                 ) -> None:
        self._pending = [e for e in events if e.action != "slow"]
        self._slow = {e.executor: e.seconds for e in events
                      if e.action == "slow"}
        self._kill, self._stop, self._cont = kill, stop, cont
        self._partition = partition
        self._timers: list[threading.Timer] = []
        # the coordinator pokes on_commit from every connection-handler
        # thread AND its monitor loop — without this lock two threads
        # can both see a due event in _pending and fire it twice
        self._lock = threading.Lock()
        self.applied: list[str] = []

    def task_delay(self, executor: int) -> float:
        """Extra per-task seconds for ``executor`` (slow events)."""
        return self._slow.get(executor, 0.0)

    def pending(self) -> bool:
        return bool(self._pending)

    def on_commit(self, n_commits: int,
                  holds_lease: Callable[[int], bool]) -> None:
        """Fire every due event. Caller provides ``holds_lease`` so
        kill/hang wait for a moment when the victim owns work.
        Thread-safe: each event fires exactly once."""
        with self._lock:
            self._fire_due(n_commits, holds_lease)

    def _fire_due(self, n_commits: int,
                  holds_lease: Callable[[int], bool]) -> None:
        still = []
        for e in self._pending:
            due = n_commits >= e.after_commits
            if due and e.action in ("kill", "hang") \
                    and not holds_lease(e.executor):
                still.append(e)     # stay armed until the victim leases
                continue
            if not due:
                still.append(e)
                continue
            if e.action == "kill" and self._kill is not None:
                self._kill(e.executor)
            elif e.action == "hang" and self._stop is not None:
                self._stop(e.executor)
                if self._cont is not None:
                    t = threading.Timer(e.seconds, self._cont,
                                        args=(e.executor,))
                    t.daemon = True
                    t.start()
                    self._timers.append(t)
            elif e.action == "part" and self._partition is not None:
                self._partition(e.executor)
            self.applied.append(f"{e.action}:{e.executor}")
        self._pending = still

    def cancel(self) -> None:
        with self._lock:
            for t in self._timers:
                t.cancel()
