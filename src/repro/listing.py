"""Streaming k-clique enumeration through the tile pipeline.

The paper's exact algorithm is for "counting (and listing) k-cliques";
this module is the listing half. It drives the emit variants of the
counting recursions (:func:`repro.core.count.dag_list_cliques` /
``dag_list_bits``) through the same plan buckets, tile batches, and
representation cost model every counting backend uses, and streams the
result to the caller as :class:`CliqueBatch` chunks:

- **bounded memory** — each tile is enumerated into a fixed-capacity
  device buffer of ``req.chunk`` rows; a tile holding more cliques than
  one chunk is *drained*: the same compiled executable re-runs with the
  stream window advanced by ``chunk`` until the tile is exhausted. Host
  and device memory stay O(chunk + tile), never O(#cliques).
- **global ids** — tile-local indices are translated back through the
  extraction's neighbor map on device, so each row is a full k-clique
  ``[u, v₁, …, v_{k−1}]`` in graph node ids, ``u`` the ≺-minimum
  (responsible) vertex.
- **predicate / limit** — an optional vectorized host predicate filters
  each chunk before it is yielded (e.g. "cliques containing node 17" —
  see :func:`containing`), and ``limit`` stops the stream — and all
  remaining device work — as soon as that many cliques have been
  yielded (top-t queries).

Use it through the engine::

    from repro.engine import CliqueEngine, CountRequest
    eng = CliqueEngine(graph)
    for batch in eng.stream(CountRequest(k=4, mode="list", chunk=8192)):
        process(batch.cliques)                  # (≤ chunk, k) int32

or materialized (small results / service tickets)::

    rep = eng.submit(CountRequest(k=4, mode="list", limit=100))
    rep.cliques                                 # (≤ 100, 4) int32

See ``docs/listing.md`` for the full design.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .core.count import (_list_tile, _tile_batches, pick_tile_repr,
                         tile_unit_bytes)
from .core.plan import partition_for_workers
from .engine.backends import tile_executable


@dataclasses.dataclass
class CliqueBatch:
    """One streamed chunk of enumerated k-cliques.

    ``cliques`` is (n, k) int32 global node ids, n ≤ the request's
    ``chunk`` — the bounded-memory contract. ``chunk_index`` counts
    chunks within the owning tile (> 0 means the tile overflowed the
    buffer and is being drained)."""
    k: int
    cliques: np.ndarray
    tile_index: int
    chunk_index: int
    truncated: bool = False     # the stream's limit was hit in this batch


def containing(*nodes: int) -> Callable[[np.ndarray], np.ndarray]:
    """Predicate factory: keep cliques containing every given node —
    the "top-t cliques by node" query of social-network analytics,
    usually paired with ``limit``::

        CountRequest(k=4, mode="list", predicate=containing(17), limit=10)
    """
    want = np.asarray(nodes, np.int32)

    def pred(rows: np.ndarray) -> np.ndarray:
        return np.all((rows[:, :, None] == want[None, None, :]).any(axis=1),
                      axis=1)

    return pred


def _listing_batch_bytes(capacity: int, r: int) -> int:
    """Byte-accounting for one listing work unit. An *emitting* step of
    the recursion materializes dense-sized transients regardless of the
    tile representation: the bool pair mask, the int32 idx/pos/cumsum
    arrays (~4 dense planes), and the stacked (B·D², r+1) scatter
    payload — ~(r+5) dense f32 planes at peak, vs the single plane the
    counting path budgets. Fold that into the unit size so the batch
    sizing bounds *listing's* peak working set, not counting's; a
    packed tile accordingly never earns the 32×-wider batch here."""
    return (r + 5) * tile_unit_bytes(capacity, "dense")


def stream_cliques(eng, req, *, stats: Optional[dict] = None
                   ) -> Iterator[CliqueBatch]:
    """Stream every k-clique of the engine's graph as CliqueBatch chunks.

    ``eng`` is a :class:`repro.engine.CliqueEngine`; ``req`` a validated
    ``CountRequest(mode="list")``. Pass ``stats`` (a dict) to receive
    telemetry: tiles / chunks / drained tiles / enumerated / listed /
    truncated.

    The stream is deterministic for a fixed (graph, request): plan
    buckets in capacity order, tiles in plan order, chunks in stream
    order. Under the shard_map backend the buckets are walked in the
    same LPT per-worker partition the counting path shards by — the
    enumerated *set* is identical on every backend (witness emission
    cannot ride a ``psum``, so the dispatches themselves stay
    single-device; per-worker device-side draining is a ROADMAP item).
    """
    if eng.closed:
        raise RuntimeError(
            "CliqueEngine session is closed (evicted from its pool); "
            "build a new session for this graph")
    req.validate()
    if req.mode != "list":
        raise ValueError("stream_cliques needs a mode='list' request")
    backend = eng._backend(req.backend or eng.default_backend)
    # a request with backend=None must hit the same guard an explicit
    # backend="ooc" does (the ooc backend has no in-memory emit path —
    # without this it would die on a missing tile budget mid-stream)
    backend.validate(req)
    entry, _ = eng._plan_entry(req)
    r, chunk = req.k - 1, req.chunk
    s = stats if stats is not None else {}
    s.update(tiles=0, skipped_tiles=0, chunks=0, drained_tiles=0,
             enumerated=0, listed=0, truncated=False)
    remaining = req.limit
    zero_key = jax.random.PRNGKey(0)   # exact count path ignores the key

    # shard_map walks its per-worker LPT partition (same work, same
    # set); single-device backends walk the plan directly
    W = backend.n_workers
    plans = ([entry.plan] if W == 1
             else partition_for_workers(entry.plan, eng.og, W))
    tile_index = 0
    for plan in plans:
        for b in plan.buckets:
            repr_ = pick_tile_repr(r=r, capacity=b.capacity,
                                   method="exact", choice=req.engine,
                                   elem_budget=backend.budget)
            kind = "pallas" if backend.name == "pallas" else "jnp"
            fn = eng.executables.get(
                ("list", kind, repr_, b.capacity, r, chunk),
                lambda: functools.partial(
                    _list_tile, capacity=b.capacity,
                    n_iters=eng.og.lookup_iters, r=r, chunk=chunk,
                    tile_repr=repr_, engine=kind))
            # count-first sizing pass: the counting identity (matmul /
            # popcount — far cheaper than the emit recursion) decides
            # whether the tile holds any cliques at all, so clique-free
            # tiles (most of a sparse background at large k) never pay
            # for emission. It shares the counting path's session cache.
            count_fn = tile_executable(eng, kind, repr_, b.capacity, r,
                                       "exact")
            for tile in _tile_batches(
                    b.nodes, b.capacity, backend.budget, "dense",
                    unit_bytes=_listing_batch_bytes(b.capacity, r)):
                s["tiles"] += 1
                tile_dev = jnp.asarray(tile)
                sized = float(jnp.sum(count_fn(eng.csr, tile_dev,
                                               zero_key, p=1.0, c=1)))
                if not sized:
                    s["skipped_tiles"] += 1
                    tile_index += 1
                    continue
                if sized >= 2.0 ** 31:
                    # stream positions are int32 on device; refuse to
                    # wrap silently (f32 sizing is imprecise at this
                    # magnitude but its order of magnitude is exact)
                    raise OverflowError(
                        f"one tile holds ~{sized:.3g} cliques, beyond "
                        "the int32 stream counter; lower max_capacity "
                        "so the planner splits this bucket further")
                start, n_chunks, total = 0, 0, None
                while total is None or start < total:
                    rows, tile_total = fn(eng.csr, tile_dev,
                                          jnp.int32(start))
                    if total is None:
                        total = int(tile_total)
                        s["enumerated"] += total
                    got = np.asarray(rows[:max(0, min(total - start,
                                                      chunk))])
                    if req.predicate is not None and len(got):
                        got = got[np.asarray(req.predicate(got), bool)]
                    truncated = (remaining is not None
                                 and len(got) >= remaining)
                    if truncated:
                        got = got[:remaining]
                    if len(got):
                        s["chunks"] += 1
                        s["listed"] += len(got)
                        if remaining is not None:
                            remaining -= len(got)
                        yield CliqueBatch(k=req.k, cliques=got,
                                          tile_index=tile_index,
                                          chunk_index=n_chunks,
                                          truncated=truncated)
                    if truncated:
                        s["truncated"] = True
                        return
                    n_chunks += 1
                    start += chunk
                if n_chunks > 1:
                    s["drained_tiles"] += 1
                tile_index += 1


def collect_cliques(eng, req) -> tuple[np.ndarray, dict]:
    """Materialize a listing query: (cliques (N, k) int32, stats).

    This is what ``CliqueEngine.submit(CountRequest(mode="list"))`` and
    CliqueService listing tickets call; memory is O(N), so cap unbounded
    queries with ``limit`` (or use :func:`stream_cliques` directly)."""
    stats: dict = {}
    batches = [b.cliques for b in stream_cliques(eng, req, stats=stats)]
    cliques = (np.concatenate(batches) if batches
               else np.empty((0, req.k), np.int32))
    return cliques, stats
