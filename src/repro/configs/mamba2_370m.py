"""mamba2-370m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified].

48L d_model=1024 vocab=50280, ssm_state=128, d_inner=2048, head_dim=64
(32 SSD heads). No attention, no MLP: each block is a Mamba-2 mixer.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
        ssm_state=128, d_inner=2048, ssm_head_dim=64, tie_embeddings=True,
        source="arXiv:2405.21060; unverified")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=512,
        ssm_state=16, d_inner=128, ssm_head_dim=32, tie_embeddings=True,
        ssd_chunk=16, source="smoke")
