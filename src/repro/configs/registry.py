"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from . import (command_r_35b, deepseek_v2_lite_16b, hymba_1p5b,
               internvl2_76b, mamba2_370m, mixtral_8x7b, qwen1p5_4b,
               tinyllama_1p1b, whisper_small, yi_6b)
from .base import SHAPES, ModelConfig, ShapeConfig, cell_is_runnable

_MODULES = {
    "hymba-1.5b": hymba_1p5b,
    "command-r-35b": command_r_35b,
    "qwen1.5-4b": qwen1p5_4b,
    "yi-6b": yi_6b,
    "tinyllama-1.1b": tinyllama_1p1b,
    "whisper-small": whisper_small,
    "internvl2-76b": internvl2_76b,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b,
    "mixtral-8x7b": mixtral_8x7b,
    "mamba2-370m": mamba2_370m,
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its runnability verdict."""
    out = []
    for a in list_archs():
        cfg = get_config(a)
        for s, shape in SHAPES.items():
            ok, why = cell_is_runnable(cfg, shape)
            out.append((a, s, ok, why))
    return out
