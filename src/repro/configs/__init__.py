from .base import (SHAPES, ModelConfig, ParallelConfig, ShapeConfig,
                   cell_is_runnable, round_up)
from .registry import (all_cells, get_config, get_shape, get_smoke_config,
                       list_archs)

__all__ = [
    "SHAPES", "ModelConfig", "ParallelConfig", "ShapeConfig",
    "cell_is_runnable", "round_up", "all_cells", "get_config", "get_shape",
    "get_smoke_config", "list_archs",
]
