"""hymba-1.5b — hybrid parallel attention+SSM heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention heads and Mamba heads run in parallel within each layer and
their outputs are averaged (the paper's fused hybrid head). Most layers
use sliding-window attention — we model the uniform-SWA variant (window
1024) so the layer stack stays scan-able; meta-tokens are not modeled
(noted in DESIGN.md).
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
        sliding_window=1024, hybrid=True,
        ssm_state=16, d_inner=3200, ssm_heads=25, ssm_head_dim=128,
        source="arXiv:2411.13676; hf")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid", n_layers=2, d_model=64,
        n_heads=5, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
        sliding_window=16, hybrid=True,
        ssm_state=8, d_inner=128, ssm_heads=4, ssm_head_dim=32,
        source="smoke")
