"""qwen1.5-4b — dense MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

40L d_model=2560 20H (kv=20, i.e. full MHA) d_ff=6912 vocab=151936.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912,
        vocab_size=151936, attn_bias=True,
        source="hf:Qwen/Qwen1.5-0.5B; hf")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        attn_bias=True, source="smoke")
