"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab=32000, window 4096.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=32000, sliding_window=4096,
        n_experts=8, n_experts_per_tok=2, moe_d_ff=14336,
        source="arXiv:2401.04088; hf")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        sliding_window=16, n_experts=4, n_experts_per_tok=2, moe_d_ff=128,
        source="smoke")
