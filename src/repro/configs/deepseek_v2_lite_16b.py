"""deepseek-v2-lite-16b — MoE with MLA [arXiv:2405.04434; hf].

27L d_model=2048 16H (MLA kv_lora=512) per-expert d_ff=1408, vocab=102400,
64 routed experts top-6 + 2 shared. MLA: qk_nope=128 qk_rope=64 v=128;
the KV cache stores only the 512-d latent + 64-d rope key per token.
All layers are uniform MoE so the stack scans (the HF release's dense
first layer is noted as a deviation in DESIGN.md).
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27,
        d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408,
        vocab_size=102400,
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64, n_experts_per_tok=6, n_shared_experts=2,
        moe_d_ff=1408, source="arXiv:2405.04434; hf")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32, vocab_size=512,
        use_mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1,
        moe_d_ff=32, source="smoke")
