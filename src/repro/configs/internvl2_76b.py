"""internvl2-76b — VLM: InternViT frontend (stub) + 76B LM backbone
[arXiv:2404.16821; unverified].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The vision
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
patch embeddings (batch, 256, 3200); the model owns only the MLP
projector (3200 → d_model) and prepends the projected patch tokens to
the text sequence.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
        vocab_size=128256, n_vision_tokens=256, vision_embed_dim=3200,
        source="arXiv:2404.16821; unverified")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        n_vision_tokens=8, vision_embed_dim=48, source="smoke")
