"""Config system: model configs, input-shape configs, parallelism configs.

Every assigned architecture is a :class:`ModelConfig`; every benchmark
shape is a :class:`ShapeConfig`; the mesh/parallelism choices live in
:class:`ParallelConfig`. Configs are frozen dataclasses — hashable, so
they key jit caches safely.
"""
from __future__ import annotations

import dataclasses


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads
    # --- attention flavor ---
    attn_bias: bool = False         # qwen-style QKV bias
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full causal attention
    parallel_block: bool = False    # command-r: x + attn(n(x)) + mlp(n(x))
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    # --- MLA (deepseek) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 2.0
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0                # 0 → 2 * d_model
    conv_width: int = 4
    ssd_chunk: int = 128
    # --- hybrid (hymba): parallel attention + SSM heads per layer ---
    hybrid: bool = False
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    cross_attention: bool = False
    max_source_positions: int = 0   # whisper: 1500 frames
    # --- VLM (internvl): precomputed patch-embedding prefix ---
    n_vision_tokens: int = 0
    vision_embed_dim: int = 0       # frontend stub emits this dim
    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""                # provenance tag from the assignment

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 128 so TP sharding always divides."""
        return round_up(self.vocab_size, 128)

    @property
    def dinner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.dinner // self.ssm_head_dim)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM state and/or sliding window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in the roofline)."""
        D, F, V, L = self.d_model, self.d_ff, self.padded_vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.attention_free:
            if self.use_mla:
                qdim = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                per_layer += D * qdim                       # W_q
                per_layer += D * (self.kv_lora_rank + self.qk_rope_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)     # W_uk, W_uv
                per_layer += self.n_heads * self.v_head_dim * D
            else:
                qk = self.hd * (self.n_heads + 2 * self.n_kv_heads)
                per_layer += D * qk + self.n_heads * self.hd * D
        if self.family in ("ssm",) or self.hybrid:
            di, N, H = self.dinner, self.ssm_state, self.n_ssm_heads
            per_layer += D * (2 * di + 2 * N + H)           # in_proj
            per_layer += self.conv_width * (di + 2 * N)     # conv
            per_layer += di * D + 2 * H                     # out_proj, A, D
        if self.n_experts:
            per_layer += D * self.n_experts                 # router
            per_layer += 3 * D * self.moe_d_ff * (
                self.n_experts + self.n_shared_experts)
        else:
            per_layer += 3 * D * F                          # gated MLP
        total = emb + L * per_layer + D
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * D * D + 3 * D * F)
            dec_cross = L * 4 * D * D
            total += enc + dec_cross + self.max_source_positions * D
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-to experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        all_experts = self.n_layers * 3 * self.d_model * self.moe_d_ff \
            * self.n_experts
        active = self.n_layers * 3 * self.d_model * self.moe_d_ff \
            * self.n_experts_per_tok
        return int(full - all_experts + active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the mesh axes are used.

    dp_axes: batch/FSDP axes; tp_axis: tensor-parallel axis. Sequence
    parallelism shards the layer-scan carry over tp; the KV cache is
    sequence-sharded over tp for decode (works for every kv-head count).
    """
    dp_axes: tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    fsdp_axis: str = "data"         # parameter/optimizer-state sharding
    act_mode: str = "fsdp_seq"      # fsdp_seq | tp_sp | megatron
    remat: str = "full"             # full | dots | none
    moe_capacity_factor: float = 2.0
    grad_accum: int = 1


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The brief's skip rule: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "SKIP: full quadratic attention at 512k context"
    return True, ""
