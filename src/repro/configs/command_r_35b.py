"""command-r-35b — dense GQA, parallel block, no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000. Cohere's block
is parallel-residual (x + attn(n(x)) + mlp(n(x))) with LayerNorm.
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22528,
        vocab_size=256000, parallel_block=True, norm="layernorm",
        rope_theta=8_000_000.0, tie_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        parallel_block=True, norm="layernorm", tie_embeddings=True,
        source="smoke")
