"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356;
unverified].

12L (enc) + 12L (dec) d_model=768 12H d_ff=3072 vocab=51865. The conv
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
frame embeddings (batch, 1500, d_model); the encoder is the transformer
stack on top. RoPE replaces Whisper's learned absolute positions so the
stack stays uniform (noted in DESIGN.md).
"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=51865, attn_bias=True, norm="layernorm",
        encoder_layers=12, cross_attention=True, max_source_positions=1500,
        source="arXiv:2212.04356; unverified")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        attn_bias=True, norm="layernorm",
        encoder_layers=2, cross_attention=True, max_source_positions=24,
        source="smoke")
