"""Cell construction: (architecture × input shape × mesh) → a jittable
entry point with fully-specified in_shardings and abstract inputs.

``input_specs`` follows the brief: ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation. The
same builder feeds the dry-run, the roofline extractor, and (at smoke
scale, with real arrays) the integration tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..configs import get_config, get_shape
from ..configs.base import (ModelConfig, ParallelConfig, ShapeConfig,
                            cell_is_runnable)
from ..distributed.sharding import (batch_sharding, cache_shardings,
                                    param_shardings, replicated)
from ..models import abstract_params, decode_step, init_cache, prefill
from ..models.layers import ShardCtx
from ..training.optimizer import OptConfig, OptState
from ..training.train_step import make_train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    shape_cfg: ShapeConfig
    fn: Callable                 # jit-able entry point
    args: tuple                  # abstract args (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    kind: str                    # train | prefill | decode
    runnable: bool
    skip_reason: str = ""
    out_shardings: Any = None    # None → let XLA choose
    # known loop trip counts for HLO analysis (outermost first)
    trip_hints: dict = dataclasses.field(default_factory=dict)


def _abstract_batch(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.max_source_positions, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.vision_embed_dim), jnp.float32)
    return batch


def _abstract_opt(params_abs) -> OptState:
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z,
                    nu=jax.tree.map(lambda x: x, z),
                    master=jax.tree.map(lambda x: x, z))


def input_specs(arch: str, shape_name: str) -> dict:
    """Public helper per the brief: abstract specs for every input of the
    cell's entry point (no mesh needed)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    batch = _abstract_batch(cfg, shape)
    if shape.kind == "train":
        return {"batch": batch}
    if shape.kind == "prefill":
        return {"batch": {k: v for k, v in batch.items()
                          if k != "targets"}}
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return {"token": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32), "cache": cache}


def default_parallel(kind: str) -> ParallelConfig:
    """Measured per-kind defaults (EXPERIMENTS.md §Perf):
    train_4k has few tokens/chip → pure ZeRO-3 DP over every mesh axis
    beats seq-sharding (no activation collectives); prefill/decode have
    batch < chips → fsdp_seq shards memory over the model axis."""
    if kind == "train":
        return ParallelConfig(dp_axes=("pod", "data", "model"),
                              act_mode="zero3")
    return ParallelConfig()


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               par: Optional[ParallelConfig] = None,
               cfg: Optional[ModelConfig] = None) -> Cell:
    cfg = cfg or get_config(arch)
    shape = get_shape(shape_name)
    par = par or default_parallel(shape.kind)
    ok, why = cell_is_runnable(cfg, shape)
    ctx = ShardCtx(mesh=mesh, dp=par.dp_axes, tp=par.tp_axis,
                   mode=par.act_mode)
    params_abs = abstract_params(cfg)
    pshard = param_shardings(params_abs, cfg, mesh, par)
    bshard_fn = batch_sharding(mesh, par, shape.global_batch)
    batch_abs = _abstract_batch(cfg, shape)
    bshard = jax.tree.map(bshard_fn, batch_abs)
    trip_hints = {"n_layers": cfg.n_layers,
                  "enc_layers": cfg.encoder_layers}

    if shape.kind == "train":
        oc = OptConfig()
        step = make_train_step(cfg, oc, ctx=ctx, remat=par.remat,
                               grad_accum=par.grad_accum)
        opt_abs = _abstract_opt(params_abs)
        oshard = OptState(step=replicated(mesh),
                          mu=jax.tree.map(lambda s: s, pshard),
                          nu=jax.tree.map(lambda s: s, pshard),
                          master=jax.tree.map(lambda s: s, pshard))
        return Cell(arch=arch, shape=shape_name, cfg=cfg, shape_cfg=shape,
                    fn=step, args=(params_abs, opt_abs, batch_abs),
                    in_shardings=(pshard, oshard, bshard),
                    kind="train", runnable=ok, skip_reason=why,
                    trip_hints=trip_hints)

    if shape.kind == "prefill":
        def fn(params, batch):
            return prefill(cfg, params, batch, ctx=ctx,
                           cache_len=shape.seq_len)
        batch_p = {k: v for k, v in batch_abs.items() if k != "targets"}
        bshard_p = {k: v for k, v in bshard.items() if k != "targets"}
        # output cache must be sharded like the decode cache, or XLA
        # replicates it (qwen prefill: 25 GiB/dev → fits after this)
        cache_out_abs, _ = jax.eval_shape(fn, params_abs, batch_p)
        cshard_out = cache_shardings(cache_out_abs, cfg, mesh, par,
                                     shape.global_batch)
        return Cell(arch=arch, shape=shape_name, cfg=cfg, shape_cfg=shape,
                    fn=fn, args=(params_abs, batch_p),
                    in_shardings=(pshard, bshard_p),
                    out_shardings=(cshard_out, None),
                    kind="prefill", runnable=ok, skip_reason=why,
                    trip_hints=trip_hints)

    # decode: one new token against a cache of seq_len
    cache_abs = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cshard = cache_shardings(cache_abs, cfg, mesh, par, shape.global_batch)
    tok_shard = bshard_fn(
        jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32))

    def fn(params, cache, token, pos):
        return decode_step(cfg, params, cache, token, pos, ctx=ctx)

    return Cell(arch=arch, shape=shape_name, cfg=cfg, shape_cfg=shape,
                fn=fn,
                args=(params_abs, cache_abs,
                      jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32)),
                in_shardings=(pshard, cshard, tok_shard, replicated(mesh)),
                out_shardings=(None, jax.tree.map(lambda s: s, cshard)),
                kind="decode", runnable=ok, skip_reason=why,
                trip_hints=trip_hints)


def lower_cell(cell: Cell):
    """jit with production donation: train aliases params+opt through the
    step; decode aliases the cache in place. Halves the apparent live
    memory and matches how the real launchers run."""
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[cell.kind]
    kw = {}
    if cell.out_shardings is not None:
        kw["out_shardings"] = cell.out_shardings
    return jax.jit(cell.fn, in_shardings=cell.in_shardings,
                   donate_argnums=donate, **kw).lower(*cell.args)
