"""Clique-counting launcher (the paper's workload as a CLI), now a thin
shell over the session engine: one CSR build + upload serves every
query, and ``--k`` accepts a comma list for a batched session sweep.

  PYTHONPATH=src python -m repro.launch.count --graph rmat:12:8 --k 4 \
      --method color --colors 10 [--backend shard_map] [--devices 8] \
      [--split-threshold 512]
  PYTHONPATH=src python -m repro.launch.count --graph rmat:10:8 \
      --k 3,4,5 --method exact,color   # session sweep, cached plans
  PYTHONPATH=src python -m repro.launch.count \
      --graph corpus:planted_1200_12_16_40 --k 5 --rel-error 0.05 \
      --assert-golden                  # accuracy-targeted (repro.estimator)
  PYTHONPATH=src python -m repro.launch.count --graph rmat:10:8 --k 4 \
      --list --limit 20               # enumerate cliques (repro.listing)
  PYTHONPATH=src python -m repro.launch.count \
      --graph corpus:planted_1200_12_16_40 --k 4 --backend ooc \
      --workers 4 --spill-dir /tmp/spill --inject-fault 1 \
      --inject-straggler 4 --assert-golden   # out-of-core + chaos smoke
  PYTHONPATH=src python -m repro.launch.count --graph ... --backend ooc \
      --resume                        # continue a killed run's ledger
  PYTHONPATH=src python -m repro.launch.count \
      --graph corpus:planted_1200_12_16_40 --k 4 --backend ooc \
      --executors 3 --chaos kill:1@1,slow:2/2.0 --lease 1.5 \
      --assert-golden        # multi-host: real executor subprocesses,
                             # one SIGKILLed + one slowed mid-run

``--serve`` drives the multi-graph :class:`CliqueService` instead:
``--graph`` takes a comma list of specs, ``--repeat R`` submits the
whole workload R times (duplicate "users" — exercises coalescing), and
``--max-sessions`` bounds the LRU engine pool (fewer sessions than
graphs exercises eviction):

  PYTHONPATH=src python -m repro.launch.count --serve \
      --graph rmat:7:4,er:60:150 --k 3,4 --repeat 2 --max-sessions 1

``--serve-gateway`` layers the production front end on top: admission
control, per-request deadlines (``--deadline``), and — with
``--store-dir`` — a persistent result store. The workload runs twice:
the second pass must be answered entirely from the store. Re-running
the same command against the same ``--store-dir`` exercises the
restart path (every answer served without touching an engine):

  PYTHONPATH=src python -m repro.launch.count --serve-gateway \
      --graph rmat:7:4,er:60:150 --k 3,4 --store-dir /tmp/clique-store
"""
import argparse
import os
import sys


def _make_graph(spec: str, seed: int):
    from ..graphs import (barabasi_albert, complete_graph,
                          conformance_corpus, erdos_renyi_m, load_npz,
                          load_snap_txt, rmat)
    kind, *rest = spec.split(":")
    if kind == "corpus":
        by_name = {g.name: g for g in conformance_corpus()}
        if rest[0] not in by_name:
            raise ValueError(f"unknown corpus graph {rest[0]!r}; "
                             f"one of {sorted(by_name)}")
        return by_name[rest[0]]
    if kind == "rmat":
        scale, ef = int(rest[0]), int(rest[1]) if len(rest) > 1 else 8
        return rmat(scale, ef, seed=seed)
    if kind == "ba":
        n, at = int(rest[0]), int(rest[1])
        return barabasi_albert(n, at, seed=seed)
    if kind == "er":
        n, m = int(rest[0]), int(rest[1])
        return erdos_renyi_m(n, m, seed=seed)
    if kind == "complete":
        return complete_graph(int(rest[0]))
    if kind == "npz":
        return load_npz(rest[0])
    if kind == "snap":
        return load_snap_txt(rest[0])
    raise ValueError(f"unknown graph spec {spec}")


def _serve(args, backend: str, reqs) -> int:
    """--serve: run the (graphs × reqs) × repeat workload through one
    CliqueService and report per-query rows plus pool/coalescing
    telemetry. ``backend`` and ``reqs`` arrive resolved/validated by
    main() (--devices / --distributed imply shard_map, --engine pallas
    implies pallas). The invariants the flags imply are asserted, so
    this doubles as the tier-1 service smoke."""
    import dataclasses
    import json
    import time

    from ..serving.cliques import CliqueService

    specs = args.graph.split(",")
    graphs = [_make_graph(s, args.seed) for s in specs]
    if args.per_node:
        print("warning: --per-node is ignored in --serve mode",
              file=sys.stderr)
    sweep = [dataclasses.replace(r, return_per_node=False) for r in reqs]

    svc = CliqueService(max_sessions=args.max_sessions,
                        default_backend=backend)
    jobs = [(g, r) for _ in range(max(args.repeat, 1))
            for g in graphs for r in sweep]
    refs = [svc.register(g) for g in graphs]
    for g, ref in zip(graphs, refs):
        print(f"graph {g.name}: n={g.n} m={g.m} ({ref[:8]}…)")
    t0 = time.perf_counter()
    tickets = svc.submit_many(jobs)
    svc.drain()
    wall = time.perf_counter() - t0
    for (g, req), t in zip(jobs[:len(graphs) * len(sweep)], tickets):
        rep = t.result()
        print(json.dumps({
            "graph": g.name, "k": rep.k, "method": rep.method,
            "backend": rep.backend, "estimate": rep.estimate,
            "count": rep.count, "cache": rep.cache,
        }, default=str))
    stats = svc.stats()
    print(json.dumps({"service": stats}, indent=1, default=str))
    print(f"wall: {wall:.2f}s for {len(jobs)} queries "
          f"({len(jobs) / max(wall, 1e-9):.1f} q/s, "
          f"coalesce_rate={stats['coalesce_rate']:.2f})")
    assert stats["failed"] == 0, "service reported failed queries"
    if args.repeat > 1:
        assert stats["coalesced"] > 0, \
            "duplicate workload produced no coalescing"
    if len(set(refs)) > args.max_sessions:   # duplicate specs share a session
        assert stats["pool"]["evictions"] > 0, \
            "graphs exceed the pool but nothing was evicted"
    return 0


def _serve_gateway(args, backend: str, reqs) -> int:
    """--serve-gateway: the full production path — gateway → store →
    service → engine. Runs the workload twice: pass 1 executes (or, on
    a restarted store, serves every answer from disk), pass 2 must be
    100% store hits. The invariants are asserted, so this doubles as
    the tier-1 gateway smoke."""
    import dataclasses
    import json
    import time

    from ..serving.gateway import ServingGateway

    specs = args.graph.split(",")
    graphs = [_make_graph(s, args.seed) for s in specs]
    if args.per_node:
        print("warning: --per-node is ignored in --serve-gateway mode",
              file=sys.stderr)
    sweep = [dataclasses.replace(r, return_per_node=False) for r in reqs]

    gw = ServingGateway(store_dir=args.store_dir,
                        max_sessions=args.max_sessions,
                        default_backend=backend,
                        default_deadline_s=args.deadline)
    restarted = False
    if args.store_dir is not None:
        s0 = gw.stats()
        restarted = s0["store"]["entries"] > 0
        if restarted:
            print(f"restart: {s0['store']['entries']} stored answers, "
                  f"{s0['warmed_graphs']} persisted graphs, "
                  f"{s0['warmed_sessions']} sessions pre-warmed")
    jobs = [(g, r) for _ in range(max(args.repeat, 1))
            for g in graphs for r in sweep]
    for g in graphs:
        print(f"graph {g.name}: n={g.n} m={g.m}")

    def run_pass(name: str):
        t0 = time.perf_counter()
        tickets = [gw.submit(g, r) for g, r in jobs]
        reports = [t.result(timeout=600) for t in tickets]
        wall = time.perf_counter() - t0
        hits = sum(t.from_store for t in tickets)
        print(f"{name}: {len(jobs)} queries in {wall:.2f}s "
              f"({hits} store hits)")
        return tickets, reports, wall

    t1, r1, wall1 = run_pass("pass 1")
    for (g, _), rep in zip(jobs[:len(graphs) * len(sweep)], r1):
        print(json.dumps({
            "graph": g.name, "k": rep.k, "method": rep.method,
            "backend": rep.backend, "estimate": rep.estimate,
            "count": rep.count, "cache": rep.cache,
        }, default=str))
    if restarted:
        # every answer must come off disk without touching an engine
        assert all(t.from_store for t in t1), \
            "restarted gateway missed its own store"
        assert gw.stats()["service"]["executed"] == 0, \
            "restarted gateway re-executed a stored answer"
        print("restart warm-start ok: every answer served from the "
              "store, zero engine executions")
    t2, r2, wall2 = run_pass("pass 2")
    if args.store_dir is not None:
        assert all(t.from_store for t in t2), \
            "second pass was not fully served from the store"
        for a, b in zip(r1, r2):
            assert a.estimate == b.estimate, (a.k, a.estimate, b.estimate)
        print(f"store ok: pass 2 bit-exact from disk "
              f"({wall1 / max(wall2, 1e-9):.0f}x faster)")
    stats = gw.stats()
    print(json.dumps({"gateway": stats}, indent=1, default=str))
    assert stats["service"]["failed"] == 0, "gateway reported failures"
    assert stats["deadline_expired"] == 0, \
        "workload blew its --deadline"
    gw.shutdown()
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", required=True,
                    help="rmat:S[:EF] | ba:N:K | er:N:M | complete:N | "
                         "npz:path | snap:path")
    ap.add_argument("--k", default="3",
                    help="clique size, a comma list (session sweep), or "
                         "'all' for the one-pass clique-number profile "
                         "q_3..q_kmax")
    ap.add_argument("--max-k", type=int, default=None,
                    help="with --k all: cap the profile (and the device "
                         "recursion depth) at q_max_k")
    ap.add_argument("--method", default="exact",
                    help="exact | edge | color | color_smooth | ni++ | "
                         "wedge | sparsify | auto, or comma list (crossed "
                         "with every k); auto races the method portfolio "
                         "to meet --rel-error/--confidence")
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--colors", type=int, default=10)
    ap.add_argument("--samples", type=int, default=None,
                    help="--method wedge: uniform (k-1)-subset draws per "
                         "work unit (default 64)")
    ap.add_argument("--q", type=float, default=None,
                    help="--method sparsify: edge keep-rate in (0, 1] "
                         "(default 0.5)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rel-error", type=float, default=None,
                    help="accuracy target: estimate within this relative "
                         "error at --confidence (implies method auto "
                         "unless an adaptive method is given)")
    ap.add_argument("--confidence", type=float, default=0.99,
                    help="confidence level for --rel-error (default .99)")
    ap.add_argument("--assert-golden", action="store_true",
                    help="corpus: graphs only — assert each reported CI "
                         "(or exact count) contains the checked-in "
                         "golden count (the tier-1 estimator smoke)")
    ap.add_argument("--backend", default=None,
                    choices=["local", "pallas", "shard_map", "ooc"],
                    help="engine backend (default local; --distributed/"
                         "--devices imply shard_map; ooc = out-of-core "
                         "partitioned execution, see docs/scheduler.md)")
    ap.add_argument("--engine", default="jnp",
                    choices=["jnp", "pallas", "bitset", "dense"],
                    help="--engine pallas ≡ --backend pallas (deprecated "
                         "alias); --engine bitset/dense force the packed "
                         "uint32 / dense f32 tile representation (default: "
                         "per-bucket auto-pick)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--split-threshold", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--per-node", action="store_true",
                    help="report top per-node clique attribution")
    ap.add_argument("--list", action="store_true", dest="list_cliques",
                    help="enumerate the cliques themselves (mode='list', "
                         "exact method only): streams CliqueBatch chunks, "
                         "prints the first --list-show rows per k, and "
                         "cross-checks the streamed total against an "
                         "exact count on the same session unless --limit "
                         "cuts the stream short")
    ap.add_argument("--limit", type=int, default=None,
                    help="--list: stop after this many cliques (early-"
                         "stops the remaining device work)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="--list: listing buffer rows per chunk (bounds "
                         "stream memory; default %d)" % (1 << 16))
    ap.add_argument("--list-show", type=int, default=3,
                    help="--list: cliques to print per query (default 3)")
    ap.add_argument("--workers", type=int, default=4,
                    help="--backend ooc: scheduler worker-pool size")
    ap.add_argument("--spill-dir", default=None,
                    help="--backend ooc: shard-slice spill directory "
                         "(default $TMPDIR/repro-ooc; reused across runs "
                         "keyed by graph fingerprint + plan signature)")
    ap.add_argument("--resume", action="store_true",
                    help="--backend ooc: replay the task ledger of a "
                         "prior (killed) run — completed tasks are not "
                         "recounted")
    ap.add_argument("--inject-fault", type=int, default=0,
                    help="--backend ooc: fail the first N task "
                         "executions (retried with backoff; the smoke "
                         "asserts the answer is unchanged)")
    ap.add_argument("--inject-straggler", type=float, default=0.0,
                    help="--backend ooc: delay one task's first "
                         "execution by this many seconds — forces the "
                         "straggler detector to speculate a duplicate")
    ap.add_argument("--ooc-task-delay", type=float, default=0.0,
                    help="--backend ooc: uniform per-execution delay in "
                         "seconds (stretches the run so a kill-and-"
                         "resume demo has a mid-run to kill into)")
    ap.add_argument("--executors", type=int, default=0,
                    help="--backend ooc: run the query on this many real "
                         "executor subprocesses behind a coordinator "
                         "(leases + heartbeats + ledger commit protocol) "
                         "instead of the in-process pool")
    ap.add_argument("--chaos", default=None,
                    help="--executors: deterministic fault schedule, "
                         "e.g. kill:1@1,slow:2/2.0 — SIGKILL executor 1 "
                         "after 1 commit, slow executor 2's tasks by 2s "
                         "(see repro/runtime/chaos.py for the grammar)")
    ap.add_argument("--lease", type=float, default=None,
                    help="--executors: task lease seconds (heartbeats "
                         "renew it; expiry reassigns the task)")
    ap.add_argument("--assert-no-rerun", action="store_true",
                    help="--backend ooc --resume: assert the ledger "
                         "replay re-executed zero committed tasks")
    ap.add_argument("--serve", action="store_true",
                    help="drive a CliqueService over a comma list of "
                         "--graph specs (multi-graph pool + coalescing)")
    ap.add_argument("--repeat", type=int, default=1,
                    help="--serve: submit the workload this many times "
                         "(duplicate users; exercises coalescing)")
    ap.add_argument("--max-sessions", type=int, default=4,
                    help="--serve/--serve-gateway: LRU engine-pool "
                         "capacity")
    ap.add_argument("--serve-gateway", action="store_true",
                    help="drive the production ServingGateway (admission "
                         "control, deadlines, persistent result store); "
                         "runs the workload twice and asserts the second "
                         "pass is served from the store")
    ap.add_argument("--store-dir", default=None,
                    help="--serve-gateway: persistent result-store "
                         "directory; reuse across invocations to "
                         "exercise the restart warm-start path")
    ap.add_argument("--deadline", type=float, default=None,
                    help="--serve-gateway: per-request deadline in "
                         "seconds (expired tickets fail with "
                         "DeadlineExceeded)")
    args = ap.parse_args()

    if args.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.devices}"
        os.execv(sys.executable, [sys.executable, "-m",
                                  "repro.launch.count"] + sys.argv[1:])

    import json
    import time

    from ..engine import CliqueEngine, CountRequest

    backend = args.backend
    if backend is None:
        if args.distributed or args.devices:
            backend = "shard_map"
        elif args.engine == "pallas":
            backend = "pallas"
        else:
            backend = "local"

    from ..engine import ADAPTIVE_METHODS

    if str(args.k).strip() == "all":
        ks: list = ["all"]
    else:
        ks = [int(x) for x in str(args.k).split(",")]
        if args.max_k is not None:
            ap.error('--max-k only applies to --k all')
    methods = args.method.split(",")
    if args.rel_error is not None and methods == ["exact"]:
        methods = ["auto"]   # bare --rel-error means "auto, to this bar"
    if args.per_node and backend == "shard_map":
        print("warning: --per-node is a local/pallas feature; ignored "
              "on the shard_map backend", file=sys.stderr)
    if args.list_cliques:
        if methods != ["exact"]:
            ap.error("--list is exact-only: sampled estimators have no "
                     "witnesses to emit for the cliques they skip")
        if args.rel_error is not None:
            ap.error("--list and --rel-error are mutually exclusive")
        if args.limit is not None and args.assert_golden:
            ap.error("--assert-golden pins the *full* count; a --limit-"
                     "truncated listing can never match it")
    elif args.limit is not None or args.chunk is not None:
        ap.error("--limit/--chunk are --list knobs")

    tile_engine = (args.engine if args.engine in ("bitset", "dense")
                   else "auto")
    listing_kw = {}
    if args.list_cliques:
        listing_kw = dict(mode="list", limit=args.limit,
                          chunk=(args.chunk if args.chunk is not None
                                 else 1 << 16))

    from ..estimator import from_string

    def _spec(m: str):
        """Typed MethodSpec for one --method entry: the CLI speaks the
        new registry (no deprecated strings), with --samples/--q routed
        to the methods that read them."""
        return from_string(
            m,
            p=(args.q if m == "sparsify" and args.q is not None
               else args.p),
            colors=(args.samples if m == "wedge"
                    and args.samples is not None else args.colors),
            rel_error=args.rel_error, confidence=args.confidence)

    try:  # resolve + validate the whole sweep before any work runs
        reqs = [CountRequest(
            **listing_kw,
            k=k, max_k=args.max_k if k == "all" else None,
            method=_spec(m), p=args.p, colors=args.colors,
            seed=args.seed, engine=tile_engine,
            # the accuracy target rides only the methods that can adapt,
            # so e.g. --method auto,exact --rel-error 0.05 compares the
            # controller against the exact baseline in one sweep
            rel_error=args.rel_error if m in ADAPTIVE_METHODS else None,
            confidence=args.confidence,
            split_threshold=args.split_threshold or None,
            return_per_node=args.per_node and backend != "shard_map")
            for k in ks for m in methods]
        for r in reqs:
            r.validate()
    except ValueError as e:
        ap.error(str(e))

    if args.serve and args.serve_gateway:
        ap.error("--serve and --serve-gateway are mutually exclusive")
    if not args.serve_gateway and (args.store_dir is not None
                                   or args.deadline is not None):
        ap.error("--store-dir/--deadline are --serve-gateway knobs")
    if args.serve_gateway:
        return _serve_gateway(args, backend, reqs)
    if args.serve:
        return _serve(args, backend, reqs)

    g = _make_graph(args.graph, args.seed)
    print(f"graph {g.name}: n={g.n} m={g.m} ({g.storage_mb():.1f} MB)")
    golden = None
    if args.assert_golden:
        fixture = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
            "tests", "fixtures", "golden_counts.json")
        with open(fixture) as f:
            golden = json.load(f)
        assert g.name in golden, \
            f"--assert-golden needs a corpus: graph, got {g.name!r}"
    if args.executors and backend != "ooc":
        ap.error("--executors needs --backend ooc")
    if args.chaos and not args.executors:
        ap.error("--chaos needs --executors (it schedules faults "
                 "against real executor processes; use --inject-fault/"
                 "--inject-straggler for the in-process pool)")
    if args.executors and (args.inject_fault or args.inject_straggler):
        ap.error("--inject-fault/--inject-straggler are in-process "
                 "hooks; with --executors use --chaos")
    chaos_slow = args.chaos is not None and "slow:" in args.chaos
    ooc_cfg = None
    if backend == "ooc" or any(r.backend == "ooc" for r in reqs):
        import threading

        from ..runtime.faults import FaultDomain
        from ..scheduler import SchedulerConfig
        delay_hook = None
        if not args.executors and (args.inject_straggler > 0
                                   or args.ooc_task_delay > 0):
            armed = {"straggler": args.inject_straggler > 0}
            hook_lock = threading.Lock()

            def delay_hook(tid, ei):
                d = args.ooc_task_delay
                if ei == 0:
                    with hook_lock:
                        if armed["straggler"]:
                            armed["straggler"] = False
                            d += args.inject_straggler
                return d
        ooc_cfg = SchedulerConfig(
            n_workers=args.workers, spill_dir=args.spill_dir,
            resume=args.resume,
            faults=(FaultDomain(fail_at=tuple(range(args.inject_fault)),
                                backoff_s=0.01)
                    if args.inject_fault else None),
            delay_hook=delay_hook,
            executors=max(args.executors, 0),
            chaos=args.chaos,
            task_delay_s=(args.ooc_task_delay if args.executors else 0.0),
            # tight detector knobs when a straggler is forced (in-process
            # --inject-straggler or a chaos slow: event), so the smoke
            # doesn't wait out production-sized envelopes
            **({"lease_s": args.lease} if args.lease else {}),
            **({"speculation_min_s": 0.05, "speculation_factor": 2.0,
                "poll_s": 0.005}
               if args.inject_straggler > 0 or chaos_slow else {}))
    t0 = time.perf_counter()
    eng = CliqueEngine(g, backend=backend, ooc=ooc_cfg)
    sched_totals: dict = {}
    for rep in eng.submit_many(reqs):
        row = {
            "k": rep.k, "method": rep.method, "backend": rep.backend,
            "estimate": rep.estimate, "count": rep.count,
            "workers": rep.n_workers,
            "mrc_rounds": rep.mrc.rounds,
            "imbalance": rep.balance["imbalance"],
            "plan": rep.plan_summary,
            "cache": rep.cache,
            "count_s": round(rep.timings["count_s"], 4),
        }
        if rep.profile is not None:
            row["profile"] = {f"q_{j + 3}": int(v)
                              for j, v in enumerate(rep.profile)}
            row["kmax"] = int(rep.profile.size) + 2 if rep.profile.size \
                else 0
            row["allk"] = rep.cache.get("allk")
        if rep.ci_low is not None:
            row["ci"] = [rep.ci_low, rep.ci_high]
            row["achieved_rel_error"] = rep.achieved_rel_error
            row["escalations"] = rep.escalations
            row["resolved"] = rep.params["resolved"]
            port = (rep.estimator or {}).get("portfolio")
            if port is not None:
                # why this method won: certificate ranking + pilot walls
                row["portfolio"] = {
                    "winner": port["winner"],
                    "ranking": port["ranking"],
                    "lever": rep.estimator["lever"],
                    "level": rep.estimator["level"],
                    "pilot": port["pilot"],
                }
        tel_sp = rep.cache.get("sparsify")
        if tel_sp is not None:
            row["sparsify"] = tel_sp
        if rep.cliques is not None:
            row["listing"] = rep.listing
            row["cliques_head"] = \
                rep.cliques[:max(args.list_show, 0)].tolist()
            if args.limit is None:
                # the streamed enumeration must agree with the counting
                # identity on the same session — a free exactness smoke
                check = eng.submit(CountRequest(k=rep.k,
                                                engine=tile_engine))
                assert rep.count == check.count, \
                    (rep.k, rep.count, check.count)
                row["count_check"] = "ok"
        if rep.per_node is not None:
            top = rep.per_node.argsort()[-3:][::-1]
            row["top_nodes"] = top.tolist()
        print(json.dumps(row, indent=1, default=str))
        tel = rep.cache.get("scheduler")
        if tel is not None:
            shown = {k: tel[k] for k in
                     ("tasks", "run", "resumed", "stolen", "speculated",
                      "speculation_wins", "retried", "n_workers",
                      "spill", "spill_bytes", "max_slice_bytes",
                      "csr_bytes", "wall_s")}
            if tel.get("executors"):
                shown.update({k: tel[k] for k in
                              ("executors", "lease_expiries",
                               "reassigned", "heartbeats_missed",
                               "commit_dups", "per_host")
                              if k in tel})
                if "chaos" in tel:
                    shown["chaos"] = tel["chaos"]
            print(json.dumps({"scheduler": shown}, indent=1,
                             default=str))
            sched_totals = {k: sched_totals.get(k, 0) + tel.get(k, 0)
                            for k in ("retried", "speculated", "run",
                                      "resumed", "tasks",
                                      "speculation_wins",
                                      "lease_expiries", "reassigned",
                                      "commit_dups")}
        if golden is not None and rep.k == "all":
            want = golden[g.name].get("profile")
            assert want is not None, \
                (f"--assert-golden: no profile pinned for {g.name}; "
                 "re-run scripts/regen_golden.py")
            got = [] if rep.profile is None else \
                [int(v) for v in rep.profile]
            for j, truth in enumerate(want):
                if args.max_k is not None and j + 3 > args.max_k:
                    break
                have = got[j] if j < len(got) else 0
                assert have == truth, (f"q_{j + 3}", have, truth)
            print(f"golden ok: profile matches the pinned "
                  f"q_3..q_{len(want) + 2}")
        elif golden is not None:
            pinned = golden[g.name]["counts"]
            assert str(rep.k) in pinned, \
                (f"--assert-golden: k={rep.k} is not pinned for "
                 f"{g.name} (fixture has k in {sorted(pinned)})")
            truth = pinned[str(rep.k)]
            if rep.ci_low is not None:
                assert rep.ci_low <= truth <= rep.ci_high, \
                    (rep.k, truth, rep.ci_low, rep.ci_high)
            else:
                assert rep.count == truth, (rep.k, rep.count, truth)
            print(f"golden ok: q_{rep.k}={truth} within reported bounds")
    if sched_totals:
        # the injected-chaos smoke: the faults/straggler actually fired
        # AND every count above already matched --assert-golden
        if args.inject_fault:
            assert sched_totals["retried"] >= 1, \
                "--inject-fault produced no retries"
        if args.inject_straggler > 0:
            assert sched_totals["speculated"] >= 1, \
                "--inject-straggler was never speculated"
        if args.chaos is not None:
            if any(a + ":" in args.chaos
                   for a in ("kill", "hang", "part")):
                assert sched_totals["lease_expiries"] >= 1, \
                    "--chaos lost no lease"
                assert sched_totals["reassigned"] >= 1, \
                    "--chaos reassigned no task"
            if chaos_slow:
                assert sched_totals["speculation_wins"] >= 1, \
                    "--chaos slow: produced no cross-host " \
                    "speculation win"
        if args.assert_no_rerun:
            assert args.resume, "--assert-no-rerun needs --resume"
            assert sched_totals["run"] == 0, \
                (f"resume re-executed {sched_totals['run']} committed "
                 f"task(s)")
            assert sched_totals["resumed"] == sched_totals["tasks"], \
                "resume did not replay the full ledger"
            print("resume ok: 0 tasks re-executed "
                  f"({sched_totals['resumed']} replayed)")
        print(f"scheduler totals: {json.dumps(sched_totals)}")
    print(json.dumps({"session": eng.session_stats()}, indent=1,
                     default=str))
    print(f"wall: {time.perf_counter() - t0:.2f}s "
          f"(q_k of {g.name}, k={ks})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
