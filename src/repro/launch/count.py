"""Clique-counting launcher (the paper's workload as a CLI).

  PYTHONPATH=src python -m repro.launch.count --graph rmat:12:8 --k 4 \
      --method color --colors 10 [--devices 8] [--split-threshold 512]
"""
import argparse
import os
import sys


def _make_graph(spec: str, seed: int):
    from ..graphs import (barabasi_albert, complete_graph, erdos_renyi_m,
                          load_npz, load_snap_txt, rmat)
    kind, *rest = spec.split(":")
    if kind == "rmat":
        scale, ef = int(rest[0]), int(rest[1]) if len(rest) > 1 else 8
        return rmat(scale, ef, seed=seed)
    if kind == "ba":
        n, at = int(rest[0]), int(rest[1])
        return barabasi_albert(n, at, seed=seed)
    if kind == "er":
        n, m = int(rest[0]), int(rest[1])
        return erdos_renyi_m(n, m, seed=seed)
    if kind == "complete":
        return complete_graph(int(rest[0]))
    if kind == "npz":
        return load_npz(rest[0])
    if kind == "snap":
        return load_snap_txt(rest[0])
    raise ValueError(f"unknown graph spec {spec}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", required=True,
                    help="rmat:S[:EF] | ba:N:K | er:N:M | complete:N | "
                         "npz:path | snap:path")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--method", default="exact",
                    choices=["exact", "edge", "color", "color_smooth",
                             "ni++"])
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--colors", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--split-threshold", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()

    if args.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.devices}"
        os.execv(sys.executable, [sys.executable, "-m",
                                  "repro.launch.count"] + sys.argv[1:])

    import json
    import time

    g = _make_graph(args.graph, args.seed)
    print(f"graph {g.name}: n={g.n} m={g.m} ({g.storage_mb():.1f} MB)")
    t0 = time.perf_counter()
    if args.distributed or args.devices:
        from ..core.distributed import count_cliques_distributed
        res = count_cliques_distributed(
            g, args.k, method=args.method, p=args.p, colors=args.colors,
            seed=args.seed,
            split_threshold=args.split_threshold or None)
        print(json.dumps({
            "estimate": res.estimate, "count": res.count,
            "workers": res.n_workers, "balance": res.balance,
            "bytes": res.per_round_bytes}, indent=1))
    else:
        from ..core import count_cliques
        res = count_cliques(g, args.k, method=args.method, p=args.p,
                            colors=args.colors, seed=args.seed,
                            engine=args.engine)
        print(json.dumps({
            "estimate": res.estimate, "count": res.count,
            "mrc_rounds": res.mrc.rounds,
            "plan": res.plan_summary}, indent=1, default=str))
    print(f"wall: {time.perf_counter() - t0:.2f}s "
          f"(q_{args.k} of {g.name})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
