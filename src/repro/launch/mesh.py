"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init, and
smoke tests must keep seeing the single real device.
"""
from __future__ import annotations


from ..core.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, elastic rescale, workers axis)."""
    return _compat_make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) for the roofline terms.
HW = {
    "peak_bf16_flops": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}
