import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the
# device count at first initialization, and the production dry-run needs
# 512 placeholder host devices to build the (2,16,16) multi-pod mesh.

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
  jax.jit(entry, in_shardings=…).lower(**input_specs).compile()
then record memory_analysis(), cost_analysis(), and the trip-count-aware
HLO roofline terms to one JSON per cell under --out. Failures (sharding
mismatch, OOM at compile, unsupported collective) are bugs — the driver
exits nonzero if any runnable cell fails.

Resumable: cells with an existing JSON are skipped unless --force.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all \
      --shape all --out experiments/dryrun
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False,
             par=None, tag_suffix: str = "") -> dict:

    from ..configs import get_config, get_shape
    from ..configs.base import ParallelConfig, cell_is_runnable
    from .cells import build_cell, lower_cell
    from .hlo_analysis import analyze_hlo
    from .mesh import make_production_mesh
    from .roofline import compute_roofline

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}{tag_suffix}".replace("/", "_")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_is_runnable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "runnable": ok, "skip_reason": why}
    if not ok:
        _write(path, rec)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # par=None → build_cell applies the measured per-kind default
        # (zero3 for train, fsdp_seq for prefill/decode)
        cell = build_cell(arch, shape_name, mesh, par)
        lowered = lower_cell(cell)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            # XLA's own peak estimate, per device, donation-aware —
            # the number that must stay under the 16 GB v5e HBM
            "peak_per_device_gib": ma.peak_memory_in_bytes / 2**30,
            "fits_16g": bool(ma.peak_memory_in_bytes < 16 * 2**30),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["cost_analysis"] = {
            "flops_body_once_per_dev": float(ca.get("flops", -1.0)),
            "bytes_body_once_per_dev":
                float(ca.get("bytes accessed", -1.0))}
        hlo = analyze_hlo(compiled.as_text())
        rec["hlo"] = hlo.to_dict()
        rl = compute_roofline(arch, shape_name, mesh_name, cfg, shape,
                              len(mesh.devices.flat), hlo)
        rec["roofline"] = rl.to_dict()
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — recorded, driver fails at end
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(path, rec)
    return rec


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(path + ".tmp", path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--act-mode", default=None,
                    choices=[None, "fsdp_seq", "tp_sp", "megatron"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "full", "dots", "none"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from ..configs import SHAPES, list_archs
    from ..configs.base import ParallelConfig
    par = None
    if args.act_mode or args.remat:
        kw = {}
        if args.act_mode:
            kw["act_mode"] = args.act_mode
        if args.remat:
            kw["remat"] = args.remat
        par = ParallelConfig(**kw)
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               force=args.force, par=par,
                               tag_suffix=args.tag)
                status = rec.get("status", "skip")
                mem = rec.get("memory", {}).get("peak_per_device_gib", 0)
                print(f"[{status:5s}] {arch:22s} {shape:12s} "
                      f"{'multi' if mp else 'single':6s} "
                      f"peak/dev={mem:.2f}GiB "
                      f"compile={rec.get('compile_s', 0):.1f}s "
                      f"{rec.get('skip_reason', '')}"
                      f"{rec.get('error', '')[:120]}",
                      flush=True)
                if status == "error":
                    failures.append((arch, shape, mp))
    if failures:
        print(f"FAILED cells: {failures}")
        return 1
    print("dry-run complete: all runnable cells lowered + compiled.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
