"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --workdir /tmp/run1 [--resume] [--devices 8]

On this container ``--smoke`` (reduced config) is the runnable path; the
full configs are exercised through the dry-run. ``--devices N`` forks the
process env to N fake host devices (must be first, before jax init —
handled below by re-exec).
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--devices", type=int, default=0,
                    help="re-exec with N fake host devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.devices}"
        os.execv(sys.executable, [sys.executable, "-m",
                                  "repro.launch.train"] + sys.argv[1:])

    import jax

    from ..configs import get_config, get_smoke_config
    from ..configs.base import ShapeConfig
    from ..data.pipeline import make_pipeline
    from ..models import init_params
    from ..training.loop import Trainer
    from ..training.optimizer import OptConfig, init_opt_state
    from ..training.train_step import make_train_step

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                   total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, oc, remat=args.remat,
                                   grad_accum=args.grad_accum))
    pipe = make_pipeline(cfg, shape, seed=args.seed)
    tr = Trainer(cfg, step, pipe, args.workdir,
                 ckpt_every=args.ckpt_every)
    start = 0
    if args.resume:
        params, opt, start = tr.resume(params, opt)
        print(f"resumed from step {start}")
    params, opt, end = tr.fit(params, opt, args.steps, start_step=start)
    print(f"trained to step {end}; metrics at {tr.metrics_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
