"""Mini HLO analyzer: trip-count-aware FLOP and collective accounting.

`compiled.cost_analysis()` on this backend is per-device and counts each
while (scan) body ONCE — measured in tools/derisk, not assumed. This
module re-derives the roofline numerators from `compiled.as_text()`:

  * computations are parsed into symbol tables (op name → result shape);
  * `while` ops expose exact `known_trip_count` in backend_config, and
    `body=`/`calls=`/`to_apply=` edges give the call graph, so every
    computation gets a multiplicity = ∏ enclosing trip counts;
  * `dot` ops contribute 2 · numel(result) · K FLOPs (K = contracted
    extent from the lhs shape + `lhs_contracting_dims`), × multiplicity;
  * collective ops contribute per-device *wire bytes* using ring costs:
      all-gather / reduce-scatter : R·(g−1)/g
      all-reduce                  : 2·R·(g−1)/g
      all-to-all                  : R·(g−1)/g
      collective-permute          : R
    where R is the full (result) byte size and g the replica-group size
    parsed from `replica_groups=[n_groups, g]`.

Everything is per-device (the HLO is the post-SPMD partitioned module).

CPU-backend correction: XLA-CPU legalizes bf16 dots by converting both
operands to f32 *before* SPMD collectives are placed, so gathers of bf16
weights/activations appear as f32 in the compiled module — 2× the bytes
a TPU build would move (the MXU consumes bf16 natively; GSPMD gathers in
the narrow type). `analyze_hlo` therefore halves the wire bytes of any
f32 collective whose producer is a convert(-fusion), and reports the
total correction in ``bf16_corrected_bytes`` so the adjustment is
auditable. (Verified by tracing: `all-gather(f32) ← convert_fusion ←
bf16 parameter` chains in command-r-35b/train_4k.)
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shape(text: str):
    """First shape token like f32[16,128] → (dtype, dims). Tuples: returns
    list of such."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.group(1), m.group(2)
    dims = [int(d) for d in dims.split(",") if d] if dims else []
    return dt, dims


def _numel(dims) -> int:
    return int(math.prod(dims)) if dims else 1


def _bytes(dt, dims) -> int:
    return _numel(dims) * _DTYPE_BYTES.get(dt, 4)


@dataclasses.dataclass
class HLOStats:
    dot_flops: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    bf16_corrected_bytes: float = 0.0   # see analyze_hlo docstring
    unrolled_trip_warnings: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def to_dict(self) -> dict:
        return {"dot_flops": self.dot_flops,
                "collective_bytes": dict(self.collective_bytes),
                "collective_count": dict(self.collective_count),
                "bf16_corrected_bytes": self.bf16_corrected_bytes,
                "total_collective_bytes": self.total_collective_bytes}


def _split_computations(txt: str) -> dict[str, list[str]]:
    """Computation headers look like
    ``%name (args...) -> type {`` or ``ENTRY %name (...) -> ... {`` and
    may contain nested parens in tuple types, so match on the trailing
    ``) -> ... {`` instead of balancing parens."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        s = line.rstrip()
        if s.endswith("{") and ") -> " in s and "=" not in s.split("(")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", s.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps


def analyze_hlo(txt: str) -> HLOStats:
    comps = _split_computations(txt)
    # call graph + trip counts
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    entry = None
    for m in re.finditer(r"ENTRY\s+%?([\w\.\-]+)", txt):
        entry = m.group(1)
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                body = re.search(r"body=%?([\w\.\-]+)", ln)
                trip = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                t = float(trip.group(1)) if trip else 1.0
                if body:
                    edges[cname].append((body.group(1), t))
                cond = re.search(r"condition=%?([\w\.\-]+)", ln)
                if cond:
                    edges[cname].append((cond.group(1), t))
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", ln):
                edges[cname].append((m.group(1), 1.0))
            for m in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations)="
                    r"\{?%?([\w\.\-,% ]+)\}?", ln):
                for c in re.split(r"[,\s%]+", m.group(1)):
                    if c:
                        edges[cname].append((c, 1.0))
    mult: dict[str, float] = defaultdict(float)
    if entry is None and comps:
        entry = list(comps)[-1]
    mult[entry] = 1.0
    # propagate multiplicities (call graph is a DAG)
    changed = True
    iters = 0
    while changed and iters < 200:
        changed = False
        iters += 1
        for src, outs in edges.items():
            if mult[src] <= 0:
                continue
            for dst, t in outs:
                want = mult[src] * t
                if dst in comps and mult[dst] < want:
                    mult[dst] = want
                    changed = True

    stats = HLOStats()
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0) or 1.0
        symbols: dict[str, tuple] = {}
        defs: dict[str, str] = {}
        for ln in lines:
            d = _DEF_RE.match(ln)
            if not d:
                continue
            name, rhs = d.group(1), d.group(2)
            defs[name] = rhs
            shp = _parse_shape(rhs)
            if shp:
                symbols[name] = shp
        for ln in lines:
            d = _DEF_RE.match(ln)
            if not d:
                continue
            rhs = d.group(2)
            op = re.search(r"\}?\s*([\w\-]+)\(", rhs)
            opname = op.group(1) if op else ""
            if opname == "dot":
                shp = _parse_shape(rhs)
                if not shp:
                    continue
                _, rdims = shp
                args = re.search(r"dot\(([^)]*)\)", rhs)
                lhs_name = args.group(1).split(",")[0].strip().lstrip("%") \
                    if args else ""
                lhs = symbols.get(lhs_name)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                k = 1
                if lhs and cdims and cdims.group(1):
                    for ci in cdims.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs[1]):
                            k *= lhs[1][ci]
                stats.dot_flops += m * 2.0 * _numel(rdims) * k
            elif any(rhs_k + "(" in rhs.split("metadata")[0]
                     for rhs_k in _COLL_KINDS):
                kind = next(kk for kk in _COLL_KINDS
                            if kk + "(" in rhs.split("metadata")[0])
                shp = _parse_shape(rhs)
                if not shp:
                    continue
                if rhs.startswith("("):  # tuple result (grouped all-reduce)
                    total = 0
                    for mm in _SHAPE_RE.finditer(
                            rhs.split(kind + "(")[0]):
                        total += _bytes(mm.group(1),
                                        [int(x) for x in
                                         mm.group(2).split(",") if x])
                    size = total
                else:
                    size = _bytes(*shp)
                # CPU-backend bf16 legalization correction (see docstring)
                if "f32[" in rhs.split(kind + "(")[0]:
                    args = re.search(kind + r"\(([^)]*)\)", rhs)
                    ops_names = [n.strip().lstrip("%") for n in
                                 args.group(1).split(",")] if args else []
                    if any("convert" in defs.get(n, "")
                           or "convert" in n for n in ops_names):
                        stats.bf16_corrected_bytes += m * size / 2
                        size = size / 2
                g = 1
                rg = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
                if rg:
                    g = int(rg.group(2))
                else:
                    rg2 = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
                    if rg2:
                        g = len(rg2.group(1).split(","))
                if g <= 1:
                    continue
                frac = (g - 1) / g
                if kind == "all-reduce":
                    wire = 2.0 * size * frac
                elif kind == "collective-permute":
                    wire = float(size)
                else:
                    wire = size * frac
                stats.collective_bytes[kind] += m * wire
                stats.collective_count[kind] += int(m)
    return stats
