"""Roofline terms per (arch × shape × mesh) from compiled artifacts.

  compute    = FLOPs_per_device / peak_bf16
  memory     = HBM_bytes_per_device / hbm_bw
  collective = wire_bytes_per_device / ici_bw

FLOPs and collective bytes come from the trip-count-aware HLO analyzer
(`hlo_analysis.py`); HBM traffic is analytic (formulas below — the
compiled module's `bytes accessed` shares cost_analysis' body-once
problem and is reported only as a diagnostic). MODEL_FLOPS = 6·N·T
(train) / 2·N·T (forward) with N = active params, plus attention-score
terms; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundant
compute.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeConfig
from .hlo_analysis import HLOStats
from .mesh import HW


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS for the whole step (all devices)."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    L, H, dh = cfg.n_layers, max(cfg.n_heads, 1), cfg.hd
    if cfg.use_mla:
        dh = cfg.qk_nope_dim + cfg.qk_rope_dim
    win = cfg.sliding_window or S
    eff = min(S, win)
    if shape.kind == "train":
        dense = 6.0 * N * B * S
        attn = 0.0 if cfg.attention_free else \
            6.0 * 2.0 * B * S * eff * 0.5 * H * dh * L
        ssd = 0.0
        if cfg.family == "ssm" or cfg.hybrid:
            Hs, P, Nst = cfg.n_ssm_heads, \
                cfg.dinner // max(cfg.n_ssm_heads, 1), cfg.ssm_state
            Q = cfg.ssd_chunk
            ssd = 3.0 * (2.0 * B * S * Q * Hs * P          # intra matmul
                         + 4.0 * B * S * Hs * P * Nst) * L  # state in/out
        return dense + attn + ssd
    if shape.kind == "prefill":
        dense = 2.0 * N * B * S
        attn = 0.0 if cfg.attention_free else \
            2.0 * 2.0 * B * S * eff * 0.5 * H * dh * L
        return dense + attn
    # decode: one token
    C = min(S, cfg.sliding_window) if cfg.sliding_window else S
    dense = 2.0 * N * B
    attn = 0.0
    if not cfg.attention_free:
        if cfg.use_mla:
            r = cfg.kv_lora_rank + cfg.qk_rope_dim
            attn = 4.0 * B * cfg.n_heads * r * C * L
        else:
            attn = 4.0 * B * cfg.n_kv_heads * \
                (cfg.n_heads // max(cfg.n_kv_heads, 1)) * cfg.hd * C * L
    ssd = 0.0
    if cfg.family == "ssm" or cfg.hybrid:
        Hs = cfg.n_ssm_heads
        P = cfg.dinner // max(Hs, 1)
        ssd = 6.0 * B * Hs * P * cfg.ssm_state * L
    return dense + attn + ssd


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                         n_devices: int) -> float:
    """Analytic minimum HBM traffic per device per step.

    train  : optimizer sweep (p, μ, ν read+write in f32 = 24 B/param) +
             weights touched fwd+bwd (3 passes × 4 B) + activation flow
             (≈ 12 tensors of (tokens_local × d_model) bf16 per layer,
             ×2 for remat recompute).
    prefill: weights once (4 B) + activations (≈ 12/layer) + cache write.
    decode : weights once + full cache read + activation trickle.
    """
    Np = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    tok_local = B * S / n_devices
    if shape.kind == "train":
        opt = 24.0 * Np / n_devices
        wts = 3.0 * 4.0 * Np / n_devices
        act = 2.0 * 12.0 * L * tok_local * D * 2.0
        return opt + wts + act
    if shape.kind == "prefill":
        wts = 4.0 * Np / n_devices
        act = 12.0 * L * tok_local * D * 2.0
        cache = _cache_bytes(cfg, shape) / n_devices
        return wts + act + cache
    wts = 4.0 * Np / n_devices
    cache = _cache_bytes(cfg, shape) / n_devices
    act = 12.0 * L * (B / n_devices) * D * 2.0
    return wts + cache + act


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    C = min(S, cfg.sliding_window) if cfg.sliding_window else S
    total = 0.0
    if not cfg.attention_free:
        if cfg.use_mla:
            total += cfg.n_layers * B * C * \
                (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0
        else:
            total += cfg.n_layers * B * C * 2 * cfg.n_kv_heads * cfg.hd * 2.0
    if cfg.family == "ssm" or cfg.hybrid:
        Hs = cfg.n_ssm_heads
        P = cfg.dinner // max(Hs, 1)
        total += cfg.n_layers * B * Hs * P * cfg.ssm_state * 4.0
    return total


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_dev: float
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs × devices)
    hbm_bytes_per_dev: float
    wire_bytes_per_dev: float
    bottleneck: str
    roofline_fraction: float     # best-possible-time / dominant-term
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)


def compute_roofline(arch: str, shape_name: str, mesh_name: str,
                     cfg: ModelConfig, shape: ShapeConfig,
                     n_devices: int, hlo: HLOStats,
                     note: str = "") -> Roofline:
    mf = model_flops(cfg, shape)
    hf = hlo.dot_flops
    hbm = hbm_bytes_per_device(cfg, shape, n_devices)
    wire = hlo.total_collective_bytes
    compute_s = hf / HW["peak_bf16_flops"]
    memory_s = hbm / HW["hbm_bw"]
    coll_s = wire / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    # "ideal" time = perfectly-useful FLOPs or the unavoidable HBM
    # traffic, whichever binds — decode is legitimately bandwidth-bound,
    # so its roofline target is the memory term, not the FLOP term
    ideal = max((mf / n_devices) / HW["peak_bf16_flops"], memory_s)
    dominant = max(terms.values())
    frac = ideal / dominant if dominant > 0 else 0.0
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_devices=n_devices,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        hlo_flops_per_dev=hf, model_flops_total=mf,
        useful_ratio=mf / (hf * n_devices) if hf else 0.0,
        hbm_bytes_per_dev=hbm, wire_bytes_per_dev=wire,
        bottleneck=bottleneck, roofline_fraction=min(frac, 1.0), note=note)
