"""Serving launcher: batched generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
import argparse


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import time

    import jax

    from ..configs import get_config, get_smoke_config
    from ..configs.base import ShapeConfig
    from ..data.pipeline import make_pipeline
    from ..models import init_params
    from ..serving.engine import Engine

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params)
    shape = ShapeConfig("cli", args.prompt_len, args.batch, "train")
    batch = next(make_pipeline(cfg, shape, seed=args.seed))
    batch = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
    t0 = time.perf_counter()
    out = eng.generate(batch, args.new_tokens,
                       temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("first sequences:", out[:2].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
