"""Serving engine: batched prefill + decode with greedy/temperature
sampling. One compiled prefill graph + one compiled decode graph,
re-used across requests of the same (batch, prompt-capacity) class —
the serving analogue of the clique planner's capacity buckets.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import decode_step, prefill
from ..models.layers import NO_SHARD, ShardCtx


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx = NO_SHARD):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self._prefill = jax.jit(
            lambda p, b, cl: prefill(cfg, p, b, ctx=ctx, cache_len=cl),
            static_argnums=(2,))
        self._step = jax.jit(
            lambda p, c, t, q: decode_step(cfg, p, c, t, q, ctx=ctx))

    def generate(self, batch: dict, max_new_tokens: int,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """batch: {"tokens": (B, S)} (+frames/patches). Greedy when
        temperature == 0. Returns (B, max_new_tokens) int32."""
        B, S = batch["tokens"].shape
        n_prefix = self.cfg.n_vision_tokens \
            if self.cfg.family == "vlm" else 0
        cap = n_prefix + S + max_new_tokens
        cache, logits = self._prefill(self.params, batch, cap)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        pos = n_prefix + S
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.int32(pos + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits, temperature, key):
        logits = logits[..., :self.cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
