"""Content-addressed persistent store of served :class:`CountReport`\\ s.

Every answer the serving stack produces is a pure function of
``(graph_fingerprint, CountRequest.query_key)`` — the same
signature-keyed idiom the out-of-core scheduler already relies on
(``ShardStore`` keys spill slices by ``(fingerprint, plan_sig)``,
``TaskLedger`` headers carry a query signature). The
:class:`ResultStore` persists that function: one JSON file per answer,

    <root>/reports/<fingerprint>/<query_hash>.json
    <root>/graphs/<fingerprint>.npz          (for gateway warm starts)

with ``query_hash = sha256(repr(query_key))[:16]``. Writes are atomic
(tmp + rename, the ShardStore manifest discipline) so a killed server
never leaves a half-written entry a later read could trust; reads are
tolerant (corrupt or truncated entries count as misses, are dropped,
and never poison the store — the ledger's torn-tail discipline).

What is persisted: every executed report whose request
``is_persistable`` — exact, sampled, adaptive, all-k, per-node, and
predicate-free listing queries. What is NOT: listing queries carrying a
``predicate`` — those coalesce by callable *identity*
(``id(predicate)``), which no store can reconstruct after a restart
(see :meth:`CountRequest.query_key`'s stability contract).

Thread-safe: the gateway's submit path reads while the service worker
writes.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Optional

from ..engine import (CountReport, CountRequest, report_from_json,
                      report_to_json)
from ..graphs.formats import Graph
from ..graphs.io import load_npz, save_npz

STORE_SCHEMA = 1


def result_key(req: CountRequest, default_backend: str = "local") -> str:
    """Durable content address of a request's answer: the hex-digested
    ``query_key``. Raises ``ValueError`` for non-persistable requests
    (identity-keyed listing predicates) rather than minting a key that
    could never match across restarts."""
    if not req.is_persistable:
        raise ValueError(
            "listing predicates coalesce by callable identity and cannot "
            "be content-addressed across restarts; this request is not "
            "persistable")
    key = req.query_key(default_backend)
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


class ResultStore:
    """Persist every served ``CountReport``, keyed by
    ``(graph_fingerprint, query_key)``.

    Parameters
    ----------
    root: store directory (created if absent).
    max_entries: evict oldest report entries past this bound (None =
        unbounded). Eviction is by file mtime — a RE-stored entry counts
        as fresh.
    """

    def __init__(self, root: str,
                 max_entries: Optional[int] = None) -> None:
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be ≥ 1, got {max_entries}")
        self.root = root
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.corrupt = 0
        self._lock = threading.Lock()
        self._reports_dir = os.path.join(root, "reports")
        self._graphs_dir = os.path.join(root, "graphs")
        os.makedirs(self._reports_dir, exist_ok=True)
        os.makedirs(self._graphs_dir, exist_ok=True)
        # (fingerprint, query_hash) -> path; scanned once at startup —
        # this is the restart warm start — then maintained by put/evict
        self._index: dict[tuple[str, str], str] = {}
        self._scan()

    def _scan(self) -> None:
        for fp in sorted(os.listdir(self._reports_dir)):
            fp_dir = os.path.join(self._reports_dir, fp)
            if not os.path.isdir(fp_dir):
                continue
            for f in sorted(os.listdir(fp_dir)):
                if f.endswith(".json"):
                    self._index[(fp, f[:-5])] = os.path.join(fp_dir, f)

    # -- reports -----------------------------------------------------------

    def put(self, fingerprint: str, req: CountRequest,
            report: CountReport, default_backend: str = "local") -> bool:
        """Persist one report; returns False (without writing) for
        non-persistable requests. Atomic: concurrent readers see either
        the old entry or the new one, never a torn file."""
        if not req.is_persistable:
            return False
        qhash = result_key(req, default_backend)
        payload = json.dumps({
            "schema": STORE_SCHEMA,
            "fingerprint": fingerprint,
            "query_key": qhash,
            "report": report_to_json(report),
        })
        fp_dir = os.path.join(self._reports_dir, fingerprint)
        path = os.path.join(fp_dir, qhash + ".json")
        with self._lock:
            os.makedirs(fp_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(payload)
            os.replace(tmp, path)
            self._index[(fingerprint, qhash)] = path
            self._evict_over_capacity()
        return True

    def get(self, fingerprint: str, req: CountRequest,
            default_backend: str = "local") -> Optional[CountReport]:
        """The persisted report for ``(fingerprint, req)``, or None.
        Counts a hit or a miss; a corrupt entry counts both ``corrupt``
        and a miss, and is dropped so it is rebuilt on the next put."""
        if not req.is_persistable:
            return None
        qhash = result_key(req, default_backend)
        with self._lock:
            path = self._index.get((fingerprint, qhash))
            if path is None:
                self.misses += 1
                return None
            try:
                with open(path) as f:
                    obj = json.load(f)
                if obj["schema"] != STORE_SCHEMA or \
                        obj["fingerprint"] != fingerprint or \
                        obj["query_key"] != qhash:
                    raise ValueError("store entry does not match its key")
                report = report_from_json(obj["report"])
            except (OSError, ValueError, KeyError, TypeError):
                # torn/corrupt/foreign entry: distrust it entirely —
                # drop file + index so the next execution re-persists
                self.corrupt += 1
                self.misses += 1
                self._index.pop((fingerprint, qhash), None)
                try:
                    os.unlink(path)
                except OSError:
                    pass
                return None
            self.hits += 1
            return report

    def _evict_over_capacity(self) -> None:
        """Caller holds the lock. Oldest-mtime-first eviction past
        ``max_entries``."""
        if self.max_entries is None or \
                len(self._index) <= self.max_entries:
            return
        def mtime(item):
            try:
                return os.path.getmtime(item[1])
            except OSError:
                return 0.0
        for key, path in sorted(self._index.items(), key=mtime)[
                :len(self._index) - self.max_entries]:
            self._index.pop(key, None)
            try:
                os.unlink(path)
            except OSError:
                pass
            self.evictions += 1

    # -- graphs (warm start) -----------------------------------------------

    def save_graph(self, fingerprint: str, graph: Graph) -> None:
        """Persist the graph itself so a restarted gateway can
        re-register (and optionally pre-admit) it. Idempotent per
        fingerprint; failures are swallowed — graph persistence is an
        optimization, never a serving dependency."""
        path = os.path.join(self._graphs_dir, fingerprint + ".npz")
        if os.path.exists(path):
            return
        try:
            save_npz(graph, path)
        except OSError:
            pass

    def load_graphs(self) -> list[tuple[str, Graph]]:
        """Every persisted ``(fingerprint, graph)``, most recently saved
        first (so a capacity-bounded warm start pre-admits the hottest
        graphs). Unreadable files are skipped, not fatal."""
        entries = []
        for f in os.listdir(self._graphs_dir):
            if f.endswith(".npz"):
                path = os.path.join(self._graphs_dir, f)
                try:
                    entries.append((os.path.getmtime(path), f[:-4],
                                    load_npz(path)))
                except (OSError, ValueError, KeyError):
                    continue
        entries.sort(key=lambda e: -e[0])
        return [(fp, g) for _, fp, g in entries]

    # -- telemetry ---------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._index)

    def stats(self) -> dict:
        with self._lock:
            return {
                "root": self.root,
                "entries": len(self._index),
                "graphs": sum(1 for f in os.listdir(self._graphs_dir)
                              if f.endswith(".npz")),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "corrupt": self.corrupt,
                "hit_rate": self.hits / max(self.hits + self.misses, 1),
            }
