"""Clique-query serving: a multi-graph front end over pooled
:class:`~repro.engine.CliqueEngine` sessions.

    from repro.serving.cliques import CliqueService
    from repro.engine import CountRequest

    svc = CliqueService(max_sessions=4)
    ref = svc.register(graph)                      # fingerprint handle
    tickets = svc.submit_many([(ref, CountRequest(k=k)) for k in (3, 4, 5)])
    counts = [t.result().count for t in tickets]   # drains on demand
    svc.stats()                                    # coalescing / pool telemetry

See ``docs/serving.md``.
"""
from .pool import EngineFactory, EnginePool
from .service import CancelledError, CliqueService, GraphRef, Ticket

__all__ = ["CancelledError", "CliqueService", "EnginePool",
           "EngineFactory", "GraphRef", "Ticket"]
