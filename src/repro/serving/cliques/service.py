"""Multi-graph clique-counting front end with request coalescing.

:class:`CliqueService` is the layer between many users and many
:class:`~repro.engine.CliqueEngine` sessions. The engine already
amortizes orient/plan/compile across queries *on one graph*; the
service extends that across a workload:

- **pool** — an LRU :class:`~.pool.EnginePool` keyed by graph
  fingerprint bounds resident sessions; re-submitting a served graph is
  a session hit (no re-orient, no re-upload, warm caches).
- **coalescing** — identical in-flight queries (same fingerprint and
  :meth:`CountRequest.query_key`) collapse into one execution whose
  report fans out to every waiter; exact queries even coalesce across
  users who picked different sampling seeds, adaptive
  (accuracy-targeted) queries coalesce on the accuracy contract
  ``(rel_error, confidence)`` — not on the seed or the sampling knobs
  the controller escalates past anyway — and listing queries
  (``mode="list"``, see ``docs/listing.md``) coalesce on
  ``(k, limit, predicate identity)`` with the ``chunk`` batching knob
  normalized away (fan-out copies the ``cliques`` array).
- **batching** — a drain groups queued jobs by session so each engine
  answers its whole batch back-to-back, reusing cached plans, shard
  stacks, and compiled executables across users (``submit_many``
  semantics with per-job error isolation).

Submission is thread-safe; execution is serialized (one drain at a
time), matching JAX's single-dispatch-thread model. Use it either
synchronously — ``submit(...)`` then ``drain()`` (or just
``ticket.result()``, which drains on demand) — or with a background
worker via ``start()``/``stop()``::

    svc = CliqueService(max_sessions=4)
    t1 = svc.submit(graph_a, CountRequest(k=4))
    t2 = svc.submit(graph_a, CountRequest(k=4))   # coalesces with t1
    t3 = svc.submit(graph_b, CountRequest(k=5, method="color"))
    print(t1.result().count, t3.result().count)
    svc.stats()["coalesced"]                      # -> 1
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Iterable, Optional, Union

from ...engine import (CountReport, CountRequest, derive_sweep_seed,
                       graph_fingerprint)
from ...graphs.formats import Graph
from .pool import EngineFactory, EnginePool

GraphRef = Union[Graph, str]

# observer of every successfully executed query, called BEFORE fan-out:
# (fingerprint, request-as-executed, raw engine report). The gateway's
# result store persists from here.
ReportHook = Callable[[str, CountRequest, CountReport], None]


class CancelledError(RuntimeError):
    """The ticket was cancelled before its job executed."""


class Ticket:
    """Handle to one submitted query (a minimal future).

    ``result()`` blocks until the report is available; on a service
    without a background worker it drives ``drain()`` itself, so plain
    synchronous callers never deadlock. On that worker-less path the
    drive is synchronous and unbounded — ``timeout`` applies to the
    wait *after* it; for a hard latency bound, run a worker
    (``service.start()``) so ``result`` only ever waits.
    """

    def __init__(self, service: "CliqueService") -> None:
        self._service = service
        self._event = threading.Event()
        self._report: Optional[CountReport] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> CountReport:
        if not self._event.is_set():
            self._service._ensure_progress()
        if not self._event.wait(timeout):
            raise TimeoutError("query still queued; is the service "
                               "draining (worker started or drain called)?")
        if self._exc is not None:
            raise self._exc
        assert self._report is not None
        return self._report

    def cancel(self, exc: Optional[BaseException] = None) -> bool:
        """Withdraw this ticket before its job runs (deadline expiry,
        caller giving up). Returns True if the ticket was cancelled —
        ``result()`` then raises ``exc`` (default
        :class:`CancelledError`) — or False when the report already
        landed (cancellation lost the race; the result stands). A job
        whose every ticket cancelled is skipped at drain time without
        touching an engine."""
        return self._service._cancel(
            self, exc if exc is not None
            else CancelledError("ticket cancelled before execution"))

    def _fulfill(self, report: Optional[CountReport],
                 exc: Optional[BaseException] = None) -> None:
        self._report, self._exc = report, exc
        self._event.set()


def _annotated_copy(report: CountReport, fanout: int,
                    session: str) -> CountReport:
    """Per-ticket report with serving telemetry in ``cache``. Coalesced
    waiters must not share mutable state — one user normalizing their
    ``per_node`` in place must not corrupt another's report — so fan-out
    copies the array and the per-report dicts (``mrc`` is immutable and
    stays shared)."""
    cache = {**report.cache, "coalesced": fanout, "session": session}
    if fanout == 1:
        return dataclasses.replace(report, cache=cache)
    return dataclasses.replace(
        report, cache=cache,
        per_node=None if report.per_node is None else report.per_node.copy(),
        plan_summary=dict(report.plan_summary),
        balance=dict(report.balance),
        per_round_bytes=dict(report.per_round_bytes),
        timings=dict(report.timings),
        params=dict(report.params),
        estimator=None if report.estimator is None
        else dict(report.estimator),
        cliques=None if report.cliques is None else report.cliques.copy(),
        listing=None if report.listing is None else dict(report.listing))


class _Job:
    """One pending execution; fans its report out to coalesced tickets."""

    __slots__ = ("fingerprint", "request", "tickets")

    def __init__(self, fingerprint: str, request: CountRequest) -> None:
        self.fingerprint = fingerprint
        self.request = request
        self.tickets: list[Ticket] = []


class CliqueService:
    """Serve `(graph, CountRequest)` jobs over a pooled engine fleet."""

    def __init__(self, max_sessions: int = 4, *,
                 default_backend: str = "local",
                 engine_factory: Optional[EngineFactory] = None,
                 on_report: Optional[ReportHook] = None) -> None:
        self.default_backend = default_backend
        self._on_report = on_report
        self.pool = EnginePool(max_sessions,
                               factory=engine_factory,
                               default_backend=default_backend)
        self._graphs: dict[str, Graph] = {}     # fp -> graph (re-admission)
        self._fp_by_id: dict[int, str] = {}     # id(graph) -> fp memo
        self._queue: list[_Job] = []
        self._pending: dict[tuple, _Job] = {}   # (fp, query_key) -> job
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._drain_lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._stopping = False
        self.submitted = 0
        self.coalesced = 0
        self.executed = 0
        self.failed = 0
        self.adaptive_executed = 0     # accuracy-targeted queries served
        self.adaptive_escalations = 0  # controller escalations across them
        self.adaptive_fallthroughs = 0  # resolved exact by the work model
        self.adaptive_winners: dict[str, int] = {}  # portfolio lever → wins
        self.cancelled = 0             # tickets withdrawn pre-execution
        self.cancelled_jobs = 0        # jobs skipped: every waiter gone
        self.report_hook_errors = 0    # on_report raised (query unaffected)

    # -- graph registry ----------------------------------------------------

    def register(self, graph: Graph) -> str:
        """Register a graph and return its fingerprint (the graph_ref
        accepted by :meth:`submit`). Registration is cheap — the engine
        session is built lazily on first drain touching the graph."""
        with self._lock:
            fp = self._fp_by_id.get(id(graph))
        if fp is not None:
            return fp
        fp = graph_fingerprint(graph)
        with self._lock:
            stored = self._graphs.setdefault(fp, graph)
            if stored is graph:
                # memo only objects we hold a reference to: a structural
                # duplicate may be garbage-collected and its id() reused
                # by a different graph, which would then resolve to the
                # wrong fingerprint.
                self._fp_by_id[id(graph)] = fp
        return fp

    def _resolve(self, graph_ref: GraphRef) -> str:
        if isinstance(graph_ref, Graph):
            return self.register(graph_ref)
        if graph_ref not in self._graphs:
            raise KeyError(f"unknown graph_ref {graph_ref!r}; register() "
                           "the graph first")
        return graph_ref

    # -- submission --------------------------------------------------------

    def submit(self, graph_ref: GraphRef, req: CountRequest) -> Ticket:
        """Enqueue one query; returns immediately with a :class:`Ticket`.

        The request's ``backend=None`` resolves to the service default
        here, so the coalescing key is fully determined at submit time.
        """
        fp = self._resolve(graph_ref)
        req = dataclasses.replace(
            req, backend=req.backend or self.default_backend)
        req.validate()
        if req.return_per_node and req.backend == "shard_map":
            raise ValueError("per-node attribution is a local/pallas "
                             "backend feature")
        ticket = Ticket(self)
        key = (fp, req.query_key(self.default_backend))
        with self._lock:
            job = self._pending.get(key)
            if job is None:
                job = _Job(fp, req)
                self._pending[key] = job
                self._queue.append(job)
            else:
                self.coalesced += 1
            job.tickets.append(ticket)
            self.submitted += 1
            self._cv.notify_all()
        return ticket

    def submit_many(self, jobs: Iterable[tuple[GraphRef, CountRequest]],
                    *, decorrelate: bool = True) -> list[Ticket]:
        """Batch submission with the same sampled-seed decorrelation as
        :meth:`CliqueEngine.submit_many` — and it must happen HERE,
        before :meth:`submit` computes each job's coalescing key: a
        batch of R sampled replicates built from one template would
        otherwise coalesce into ONE execution (sampled keys carry the
        seed), silently collapsing R "independent" replicates into R
        copies of a single estimate. Exact/adaptive entries are
        untouched (their keys normalize the seed away). Pass
        ``decorrelate=False`` to submit verbatim."""
        out = []
        for i, (ref, req) in enumerate(jobs):
            if decorrelate and req.effective_method != "exact" \
                    and not req.is_adaptive:
                req = dataclasses.replace(
                    req, seed=derive_sweep_seed(req.seed, i))
            out.append(self.submit(ref, req))
        return out

    # -- execution ---------------------------------------------------------

    def drain(self) -> int:
        """Execute everything queued (including jobs submitted while the
        drain runs); returns the number of engine executions performed.
        Serialized — concurrent callers queue up behind one drain."""
        executed = 0
        with self._drain_lock:
            while True:
                with self._lock:
                    batch, self._queue = self._queue, []
                if not batch:
                    return executed
                by_fp: dict[str, list[_Job]] = {}
                for job in batch:
                    by_fp.setdefault(job.fingerprint, []).append(job)
                for fp, group in by_fp.items():
                    executed += self._run_group(fp, group)

    def _run_group(self, fp: str, group: list[_Job]) -> int:
        """One session answers its whole batch back-to-back (the
        ``submit_many`` grouping), with per-job error isolation.

        The expensive admission step (orient + upload in ``pool.build``)
        runs OUTSIDE the service lock — only the cheap pool-map reads
        and mutations hold it, so concurrent submits never stall behind
        an engine build. Safe because drains are serialized: no second
        thread can admit the same fingerprint concurrently."""
        with self._lock:
            # drop jobs whose every waiter cancelled (deadline expiry):
            # done BEFORE admission so a fully-cancelled group never
            # builds an engine session at all. Popping from pending
            # under the lock means a submit racing this check either
            # joined in time (job stays live) or starts a fresh job.
            live = []
            for job in group:
                if job.tickets:
                    live.append(job)
                else:
                    self._pending.pop(
                        (fp, job.request.query_key(self.default_backend)),
                        None)
                    self.cancelled_jobs += 1
            group = live
        if not group:
            return 0
        try:
            with self._lock:
                engine = self.pool.lookup(fp)
                graph = self._graphs[fp]
            resident = engine is not None
            if engine is None:
                engine = self.pool.build(graph)
                with self._lock:
                    evicted = self.pool.admit(fp, engine)
                    for _, lru in evicted:
                        # close is cheap (hooks + cache clears); doing it
                        # under the lock keeps pool telemetry monotone —
                        # a concurrent stats() never sees a session gone
                        # from live but not yet folded into retired.
                        lru.close()
                for lru_fp, _ in evicted:
                    self._forget(lru_fp)   # takes the lock itself
        except Exception as exc:  # admission failed: fail the whole group
            for job in group:
                self._fulfill(job, None, "miss", exc)
            return 0
        session = "hit" if resident else "miss"
        executed = 0
        for job in group:
            try:
                report = engine.submit(job.request)
                executed += 1
                if self._on_report is not None:
                    # persist/observe BEFORE fan-out so a fulfilled
                    # ticket implies the hook already saw the report; a
                    # hook failure (store disk full) must not fail the
                    # query it observed
                    try:
                        self._on_report(fp, job.request, report)
                    except Exception:
                        with self._lock:
                            self.report_hook_errors += 1
                if report.estimator is not None:
                    with self._lock:
                        self.adaptive_executed += 1
                        self.adaptive_escalations += report.escalations
                        if report.estimator["resolved"] == "exact":
                            self.adaptive_fallthroughs += 1
                        else:
                            lever = report.estimator.get("lever", "?")
                            self.adaptive_winners[lever] = \
                                self.adaptive_winners.get(lever, 0) + 1
                self._fulfill(job, report, session)
            except Exception as exc:
                self._fulfill(job, None, session, exc)
            session = "hit"   # same session for the rest of the batch
        return executed

    def _fulfill(self, job: _Job, report: Optional[CountReport],
                 session: str, exc: Optional[BaseException] = None) -> None:
        """Deliver to every coalesced waiter. The job leaves the pending
        map and claims its tickets atomically, so a concurrent submit
        either joins before delivery (and is served now) or starts a
        fresh job — never lost."""
        with self._lock:
            self._pending.pop((job.fingerprint,
                               job.request.query_key(self.default_backend)),
                              None)
            tickets, job.tickets = job.tickets, []
            if exc is None:
                self.executed += 1
            else:
                self.failed += len(tickets)
        fanout = len(tickets)
        for t in tickets:
            if exc is not None:
                t._fulfill(None, exc)
            else:
                assert report is not None
                t._fulfill(_annotated_copy(report, fanout, session))

    def _cancel(self, ticket: Ticket, exc: BaseException) -> bool:
        """Back end of :meth:`Ticket.cancel`: remove the ticket from its
        pending job (if still queued) and fail it with ``exc``. The
        pending entry itself stays until drain so late duplicates keep
        coalescing; a job stripped of every ticket is skipped there."""
        with self._lock:
            if ticket.done():
                return False       # report already delivered; result stands
            found = False
            for job in self._pending.values():
                if ticket in job.tickets:
                    job.tickets.remove(ticket)
                    found = True
                    break
            if not found:
                # _fulfill claimed the job's tickets under this lock and
                # is delivering right now — the report wins the race
                return False
            self.cancelled += 1
        ticket._fulfill(None, exc)
        return True

    def _forget(self, fp: str) -> None:
        """Drop an evicted graph from the registry (unless work still
        references it), so a long-running service's host memory is
        bounded by the pool + queue, not by every graph ever served.
        Submitting the Graph object again simply re-registers it; a
        bare fingerprint ref for a forgotten graph raises KeyError."""
        with self._lock:
            if any(j.fingerprint == fp for j in self._queue) or \
                    any(k[0] == fp for k in self._pending):
                return
            g = self._graphs.pop(fp, None)
            if g is not None:
                self._fp_by_id.pop(id(g), None)

    def _ensure_progress(self) -> None:
        """Called by Ticket.result(): with a worker running the wait
        suffices; otherwise the calling thread drives the drain."""
        if self._worker is None or not self._worker.is_alive():
            self.drain()

    # -- background worker -------------------------------------------------

    def start(self) -> "CliqueService":
        """Start a worker thread that drains as jobs arrive."""
        if self._worker is not None and self._worker.is_alive():
            return self
        self._stopping = False
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="clique-service", daemon=True)
        self._worker.start()
        return self

    def stop(self, close_pool: bool = False) -> None:
        """Stop the worker after a final drain; optionally close every
        pooled session (releasing device memory)."""
        worker = self._worker
        if worker is not None and worker.is_alive():
            with self._lock:
                self._stopping = True
                self._cv.notify_all()
            worker.join()
        self._worker = None
        self.drain()   # anything submitted after the worker exited
        if close_pool:
            with self._lock:
                self.pool.close()

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stopping:
                    self._cv.wait()
                if self._stopping and not self._queue:
                    return
            self.drain()

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {
                "submitted": self.submitted,
                "coalesced": self.coalesced,
                "executed": self.executed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "cancelled_jobs": self.cancelled_jobs,
                "report_hook_errors": self.report_hook_errors,
                "coalesce_rate": self.coalesced / max(self.submitted, 1),
                "queue_depth": len(self._queue),
                "registered_graphs": len(self._graphs),
                "adaptive": {
                    "executed": self.adaptive_executed,
                    "escalations": self.adaptive_escalations,
                    "fallthroughs": self.adaptive_fallthroughs,
                    "winners": dict(self.adaptive_winners),
                },
                "pool": self.pool.stats(),
            }
