"""LRU pool of :class:`CliqueEngine` sessions keyed by graph fingerprint.

A served graph is expensive to admit — orientation, device upload, and
(lazily) plans and compiled executables — and holds device memory while
resident. The pool bounds that footprint to ``max_sessions`` live
engines with LRU eviction; an evicted session is ``close()``d so its
device CSR and executable caches are actually released, and its cache
telemetry is folded into the pool's retired totals before the refs drop
(via the engine's close hook).

The pool itself is not thread-safe; :class:`~.service.CliqueService`
serializes access under its own lock.
"""
from __future__ import annotations

import collections
from typing import Callable, Optional

from ...engine import CliqueEngine, graph_fingerprint
from ...graphs.formats import Graph

EngineFactory = Callable[[Graph], CliqueEngine]


class EnginePool:
    """Get-or-build engine sessions with LRU eviction and telemetry.

    Parameters
    ----------
    max_sessions: most engines resident at once (≥ 1).
    factory: builds an engine for an admitted graph; defaults to
        ``CliqueEngine(graph, backend=default_backend)``.
    default_backend: backend for the default factory.
    """

    def __init__(self, max_sessions: int = 4, *,
                 factory: Optional[EngineFactory] = None,
                 default_backend: str = "local") -> None:
        if max_sessions < 1:
            raise ValueError(f"max_sessions must be ≥ 1, got {max_sessions}")
        self.max_sessions = max_sessions
        self._factory = factory or (
            lambda g: CliqueEngine(g, backend=default_backend))
        self._engines: "collections.OrderedDict[str, CliqueEngine]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.warmed = 0    # sessions prebuilt outside the serving path
        # per-session telemetry survives eviction: close hooks fold the
        # dying session's stats in here so service totals stay monotone.
        self._retired_queries = 0
        self._retired_plan_hits = 0
        self._retired_exec_hits = 0

    # -- admission / lookup ------------------------------------------------

    def get(self, graph: Graph,
            fingerprint: Optional[str] = None) -> tuple[CliqueEngine, bool]:
        """Return ``(engine, was_resident)`` for ``graph``, admitting it
        (and possibly evicting the LRU session) if absent."""
        fp = fingerprint or graph_fingerprint(graph)
        eng = self.lookup(fp)
        if eng is not None:
            return eng, True
        eng = self.build(graph)
        for _, lru in self.admit(fp, eng):
            lru.close()
        return eng, False

    def lookup(self, fp: str) -> Optional[CliqueEngine]:
        """Resident engine for ``fp`` (counts a hit/miss, refreshes LRU
        order), or None. Cheap — safe to call under a service lock."""
        eng = self._engines.get(fp)
        if eng is None:
            self.misses += 1
            return None
        self.hits += 1
        self._engines.move_to_end(fp)
        return eng

    def build(self, graph: Graph) -> CliqueEngine:
        """Construct a session for ``graph`` WITHOUT touching the pool —
        the expensive step (orient + device upload), so callers can run
        it outside any lock and :meth:`admit` the result after."""
        eng = self._factory(graph)
        eng.register_close_hook(self._on_close)
        return eng

    def admit(self, fp: str,
              eng: CliqueEngine) -> list[tuple[str, CliqueEngine]]:
        """Insert a built session; returns the LRU sessions evicted past
        capacity WITHOUT closing them — the caller closes (and may do so
        outside its own lock, since close hooks can call back into it).
        :meth:`get` is the close-for-you convenience path."""
        self._engines[fp] = eng
        self._engines.move_to_end(fp)
        evicted = []
        while len(self._engines) > self.max_sessions:
            lru_fp, lru = self._engines.popitem(last=False)
            self.evictions += 1
            evicted.append((lru_fp, lru))
        return evicted

    def warm(self, graph: Graph,
             fingerprint: Optional[str] = None) -> bool:
        """Prebuild + admit a session outside the serving path (the
        gateway's store-driven warm start). Counted in ``warmed``, not
        hits/misses, so serving telemetry stays traffic-only. Returns
        False when the session was already resident."""
        fp = fingerprint or graph_fingerprint(graph)
        if fp in self._engines:
            return False
        eng = self.build(graph)
        for _, lru in self.admit(fp, eng):
            lru.close()
        self.warmed += 1
        return True

    def peek(self, fingerprint: str) -> Optional[CliqueEngine]:
        """Resident engine for ``fingerprint`` without touching LRU order."""
        return self._engines.get(fingerprint)

    def evict(self, fingerprint: str) -> bool:
        """Explicitly close + drop one session (True if it was resident)."""
        eng = self._engines.pop(fingerprint, None)
        if eng is None:
            return False
        self.evictions += 1
        eng.close()
        return True

    def close(self) -> None:
        """Close every resident session (service shutdown)."""
        while self._engines:
            _, eng = self._engines.popitem(last=False)
            eng.close()

    def _on_close(self, eng: CliqueEngine) -> None:
        stats = eng.session_stats()
        self._retired_queries += stats["n_queries"]
        self._retired_plan_hits += stats["plans"]["hits"]
        self._retired_exec_hits += stats["executables"]["hits"]

    # -- telemetry ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self._engines)

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._engines

    def stats(self) -> dict:
        live = [e.session_stats() for e in self._engines.values()]
        return {
            "max_sessions": self.max_sessions,
            "live": len(self._engines),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "warmed": self.warmed,
            "queries": self._retired_queries + sum(s["n_queries"]
                                                   for s in live),
            "plan_hits": self._retired_plan_hits + sum(s["plans"]["hits"]
                                                       for s in live),
            "exec_hits": self._retired_exec_hits + sum(
                s["executables"]["hits"] for s in live),
            "resident": [s["graph"] for s in live],
        }
