"""Production serving gateway: admission control, deadlines, and a
persistent result store over :class:`~repro.serving.cliques.CliqueService`.

The service (PR 2) solved *efficiency* — pooled sessions, coalescing,
batching. The gateway adds the *operational* layer a server facing real
traffic needs:

- **admission control** — a bounded in-flight queue
  (``max_queue_depth``) and per-tenant in-flight quotas
  (``tenant_quota``). Work past either bound is shed at submit time
  with :class:`GatewayOverloaded` (and counted), instead of growing an
  unbounded queue whose tail latencies nobody asked for. Store hits
  bypass admission entirely: they cost one file read, not an engine.
- **deadlines** — per-request ``deadline_s`` (or a gateway-wide
  default). An expired ticket is cancelled cleanly: the waiter gets
  :class:`DeadlineExceeded`, the service skips jobs whose every waiter
  expired before touching an engine, and late results of already-failed
  tickets are discarded, never delivered twice.
- **persistent results** — every executed report is written through to
  a content-addressed :class:`~repro.serving.store.ResultStore` keyed
  by ``(graph_fingerprint, query_key)``. A repeated analytics query is
  served from disk without building an engine session; a restarted
  gateway re-registers persisted graphs and pre-warms its pool
  (``warm_start``). Identity-keyed listing predicates are excluded
  (see the store's module docs).
- **graceful shutdown** — ``shutdown()`` stops admitting, drains queued
  work to completion, then closes the pool; anything still unresolved
  fails with :class:`GatewayClosed` rather than hanging.

Synchronous callers block on ``ticket.result()``; async front ends
await ``ticket.async_result()`` (the same wait, run in an executor —
the engine's dispatch is thread-serial anyway, so an asyncio-native
execution path would buy nothing).

    gw = ServingGateway(store_dir="/var/lib/clique-store")
    t = gw.submit(graph, CountRequest(k=4), tenant="analytics",
                  deadline_s=30.0)
    t.result().count
    gw.stats()["store"]["hit_rate"]
    gw.shutdown()
"""
from __future__ import annotations

import asyncio
import threading
import time
from typing import Optional

from ..engine import CountReport, CountRequest
from ..graphs.formats import Graph
from .cliques import CliqueService, GraphRef, Ticket
from .store import ResultStore


class GatewayError(RuntimeError):
    """Base class for gateway-level (non-query) failures."""


class GatewayOverloaded(GatewayError):
    """Admission control shed this request (queue depth or tenant
    quota); retry with backoff."""


class GatewayClosed(GatewayError):
    """The gateway is shutting down and no longer admits work."""


class DeadlineExceeded(GatewayError, TimeoutError):
    """The request's deadline expired before its report landed."""


class GatewayTicket:
    """Handle to one admitted query. ``result()`` blocks (bounded by the
    request deadline, if any); ``async_result()`` is the awaitable
    adapter. Store hits are born resolved."""

    def __init__(self, gateway: "ServingGateway", tenant: str,
                 deadline_at: Optional[float],
                 inner: Optional[Ticket] = None,
                 report: Optional[CountReport] = None) -> None:
        self._gateway = gateway
        self.tenant = tenant
        self._deadline_at = deadline_at     # time.monotonic() timestamp
        self._inner = inner                 # None ⇔ resolved from store
        self._report = report

    @property
    def from_store(self) -> bool:
        return self._inner is None

    def done(self) -> bool:
        return self._inner is None or self._inner.done()

    def cancel(self) -> bool:
        """Withdraw the query (True if it had not produced a report)."""
        if self._inner is None:
            return False
        return self._inner.cancel()

    def result(self, timeout: Optional[float] = None) -> CountReport:
        if self._inner is None:
            assert self._report is not None
            return self._report
        if self._deadline_at is not None:
            remaining = self._deadline_at - time.monotonic()
            timeout = remaining if timeout is None \
                else min(timeout, remaining)
            if timeout <= 0 and not self._inner.done():
                self._gateway._expire(self)
                # short grace: if cancellation lost the race to an
                # in-flight delivery, let the report land
                return self._inner.result(0.1)
        try:
            return self._inner.result(timeout)
        except DeadlineExceeded:
            raise
        except TimeoutError:
            if self._deadline_at is not None and \
                    time.monotonic() >= self._deadline_at:
                # the wait outlived the deadline: expire (unless a
                # report won the race at the boundary) and re-read
                self._gateway._expire(self)
                return self._inner.result(0.1)
            raise

    async def async_result(self,
                           timeout: Optional[float] = None) -> CountReport:
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            None, lambda: self.result(timeout))


class ServingGateway:
    """Admission-controlled, deadline-aware, store-backed front end.

    Parameters
    ----------
    store_dir: result-store directory; None disables persistence (the
        gateway is then admission control + deadlines only).
    max_sessions: engine-pool capacity of the underlying service.
    default_backend: backend for requests that don't pick one.
    max_queue_depth: most queries in flight (queued or executing) at
        once; submits past it shed with :class:`GatewayOverloaded`.
    tenant_quota: most in-flight queries per tenant.
    default_deadline_s: deadline applied when ``submit`` doesn't pass
        one; None = no default.
    store_max_entries: result-store eviction bound (None = unbounded).
    warm_start: re-register persisted graphs (and pre-admit up to the
        pool capacity) at startup.
    monitor_poll_s: deadline-monitor period.
    """

    def __init__(self, *, store_dir: Optional[str] = None,
                 max_sessions: int = 4,
                 default_backend: str = "local",
                 max_queue_depth: int = 64,
                 tenant_quota: int = 8,
                 default_deadline_s: Optional[float] = None,
                 store_max_entries: Optional[int] = None,
                 warm_start: bool = True,
                 monitor_poll_s: float = 0.05) -> None:
        if max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be ≥ 1, got {max_queue_depth}")
        if tenant_quota < 1:
            raise ValueError(f"tenant_quota must be ≥ 1, got {tenant_quota}")
        self.store = (ResultStore(store_dir, max_entries=store_max_entries)
                      if store_dir else None)
        self.service = CliqueService(max_sessions,
                                     default_backend=default_backend,
                                     on_report=self._persist)
        self.max_queue_depth = max_queue_depth
        self.tenant_quota = tenant_quota
        self.default_deadline_s = default_deadline_s
        self._lock = threading.Lock()
        self._live: list[GatewayTicket] = []
        self._closed = False
        self.shed = 0                 # queue-depth rejections
        self.shed_tenant = 0          # tenant-quota rejections
        self.deadline_expired = 0
        self.monitor_errors = 0       # per-ticket expiry faults survived
        self.warmed_graphs = 0
        self.warmed_sessions = 0
        if self.store is not None and warm_start:
            self.warm_start()
        # worker first, monitor second: deadlines only matter once jobs
        # can actually execute
        self.service.start()
        self._monitor_stop = threading.Event()
        self._monitor = threading.Thread(
            target=self._monitor_loop, args=(monitor_poll_s,),
            name="gateway-deadline-monitor", daemon=True)
        self._monitor.start()

    # -- submission --------------------------------------------------------

    def submit(self, graph_ref: GraphRef, req: CountRequest, *,
               tenant: str = "default",
               deadline_s: Optional[float] = None) -> GatewayTicket:
        """Admit one query. Order of checks: closed → validity → store
        (a persisted answer is served even when the gateway is at
        capacity — it costs a file read) → admission → service submit."""
        if self._closed:
            raise GatewayClosed("gateway is shut down")
        req.validate()   # invalid requests are neither shed nor stored
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline_at = (None if deadline_s is None
                       else time.monotonic() + deadline_s)
        default_backend = self.service.default_backend
        if isinstance(graph_ref, Graph):
            fp = self.service.register(graph_ref)
            if self.store is not None and req.is_persistable:
                self.store.save_graph(fp, graph_ref)
        else:
            fp = graph_ref
        if self.store is not None and req.is_persistable:
            stored = self.store.get(fp, req, default_backend)
            if stored is not None:
                stored.cache["store"] = "hit"
                return GatewayTicket(self, tenant, deadline_at,
                                     report=stored)
        with self._lock:
            self._prune_locked()
            if len(self._live) >= self.max_queue_depth:
                self.shed += 1
                raise GatewayOverloaded(
                    f"queue depth {self.max_queue_depth} reached; "
                    "retry with backoff")
            tenant_live = sum(1 for t in self._live if t.tenant == tenant)
            if tenant_live >= self.tenant_quota:
                self.shed += 1
                self.shed_tenant += 1
                raise GatewayOverloaded(
                    f"tenant {tenant!r} has {tenant_live} queries in "
                    f"flight (quota {self.tenant_quota})")
            # graph_ref resolution errors (unknown fingerprint) raise
            # KeyError out of service.submit below — after admission,
            # but admission state is pruned lazily so nothing leaks
            inner = self.service.submit(graph_ref, req)
            ticket = GatewayTicket(self, tenant, deadline_at, inner=inner)
            self._live.append(ticket)
        return ticket

    def _prune_locked(self) -> None:
        """In-flight = not yet resolved. Resolved tickets leave the
        admission set lazily, on the next submit or monitor tick."""
        self._live = [t for t in self._live if not t.done()]

    # -- deadlines ---------------------------------------------------------

    def _expire(self, ticket: GatewayTicket) -> None:
        if ticket._inner is None:
            return
        if ticket._inner.cancel(DeadlineExceeded(
                "deadline expired before the query executed")):
            with self._lock:
                self.deadline_expired += 1

    def _monitor_loop(self, poll_s: float) -> None:
        while not self._monitor_stop.wait(poll_s):
            now = time.monotonic()
            with self._lock:
                expired = [t for t in self._live
                           if t._deadline_at is not None
                           and now >= t._deadline_at and not t.done()]
                self._prune_locked()
            for t in expired:       # outside the lock: _expire re-takes it
                try:
                    self._expire(t)
                except Exception:   # noqa: BLE001 — monitor must outlive
                    # a single ticket's cancel blowing up: count it and
                    # keep enforcing the *other* deadlines. Dying here
                    # would silently leave every later deadline
                    # unenforced for the life of the gateway.
                    with self._lock:
                        self.monitor_errors += 1

    # -- persistence / warm start ------------------------------------------

    def _persist(self, fingerprint: str, req: CountRequest,
                 report: CountReport) -> None:
        """Service ``on_report`` hook: write-through every executed
        report (non-persistable requests are skipped inside put)."""
        if self.store is not None:
            self.store.put(fingerprint, req, report,
                           self.service.default_backend)

    def warm_start(self, build_sessions: Optional[int] = None) -> dict:
        """Re-register every graph the store persisted, and prebuild
        engine sessions for the ``build_sessions`` most recently saved
        (default: pool capacity). After this, bare-fingerprint refs
        resolve again and the first queries on warmed graphs are
        session hits — a restarted server picks up where it left off."""
        assert self.store is not None
        if build_sessions is None:
            build_sessions = self.service.pool.max_sessions
        graphs = self.store.load_graphs()
        for i, (fp, g) in enumerate(graphs):
            self.service.register(g)
            self.warmed_graphs += 1
            if i < build_sessions:
                # safe outside the service lock: called from __init__
                # (before the worker starts) or by an operator during a
                # quiet spell; drains serialize behind _drain_lock
                with self.service._drain_lock:
                    if self.service.pool.warm(g, fp):
                        self.warmed_sessions += 1
        return {"graphs": self.warmed_graphs,
                "sessions": self.warmed_sessions}

    # -- shutdown ----------------------------------------------------------

    def shutdown(self, close_pool: bool = True) -> None:
        """Graceful: stop admitting, drain everything already admitted,
        stop the worker (and optionally release the pool), fail any
        straggler ticket with :class:`GatewayClosed`. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._monitor_stop.set()
        self._monitor.join(timeout=5.0)
        self.service.stop(close_pool=close_pool)
        with self._lock:
            leftovers, self._live = list(self._live), []
        for t in leftovers:
            if not t.done() and t._inner is not None:
                t._inner.cancel(GatewayClosed("gateway shut down"))

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            self._prune_locked()
            out = {
                "inflight": len(self._live),
                "shed": self.shed,
                "shed_tenant": self.shed_tenant,
                "deadline_expired": self.deadline_expired,
                "monitor_errors": self.monitor_errors,
                "warmed_graphs": self.warmed_graphs,
                "warmed_sessions": self.warmed_sessions,
                "closed": self._closed,
            }
        out["store"] = None if self.store is None else self.store.stats()
        out["service"] = self.service.stats()
        return out
