"""Disk-backed CSR shards: the out-of-core half of the scheduler.

The oriented CSR is spilled once per (graph fingerprint, task ledger)
into one *slice* per task: the CSR rows of the task's work units plus
the rows of every out-neighbor they reference (the closure G⁺ needs for
its pair-existence joins), with each halo row filtered to entries
inside the closure. A worker executing a task therefore mmaps and
uploads only its slice — host memory per worker is O(closure(chunk)),
not O(m) — which is the paper's round-3 locality property made literal:
reducer (u) only ever touches Γ⁺(u) and the edges among it.

Slices keep *global* node indexing (a full-length ``offsets`` array
whose non-closure rows are empty): this costs O(n) int32 per slice but
buys exactness for free — unit ids, per-node sampling keys
(``fold_in(key, u)``), out-degrees, and per-node attribution are all
identical to the single-host backends, so the ooc backend is bit-exact
against them by construction rather than by remapping bookkeeping.

Layout under ``<root>/<fingerprint>/<plan_sig>/``:

  manifest.json            graph + ledger identity, per-task byte sizes
  out_deg.npy              true global out-degrees (shared by all tasks)
  t_<id>.offsets.npy       per-task slice CSR (global-length offsets)
  t_<id>.rank.npy          rank-sorted filtered rows
  t_<id>.byid.npy          id-sorted filtered rows

The manifest is written last (tmp + rename), so a spill killed midway
is invisible and rebuilt; a complete spill is reused by every later
run, query, and resume on the same ledger.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import NamedTuple

import numpy as np

from ..core.csr import OrientedGraph
from .tasks import Task

MANIFEST = "manifest.json"


class SliceCSR(NamedTuple):
    """One task's mmapped shard slice (global node indexing)."""
    offsets: np.ndarray    # (n+1,) int32, empty rows outside the closure
    nbrs_rank: np.ndarray  # (E_c,) int32 filtered rank-sorted rows
    nbrs_byid: np.ndarray  # (E_c,) int32 filtered id-sorted rows
    out_deg: np.ndarray    # (n,) int32 TRUE global out-degrees

    @property
    def nbytes(self) -> int:
        return (self.offsets.nbytes + self.nbrs_rank.nbytes
                + self.nbrs_byid.nbytes + self.out_deg.nbytes)


def _closure_slice(og: OrientedGraph, units: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build (offsets, nbrs_rank, nbrs_byid) for the closure of
    ``units``: full rows for the units, halo rows filtered to closure
    members. Filtering halo rows is safe because the only queries ever
    issued against them are pair-existence joins whose right-hand side
    lives in some Γ⁺(u) ⊆ closure, and dropping entries keeps each row
    sorted (in both the rank and the id order)."""
    units = units[units >= 0].astype(np.int64)
    starts = og.offsets[units].astype(np.int64)
    lens = og.offsets[units + 1].astype(np.int64) - starts
    total = int(lens.sum())
    if total:
        base = np.repeat(starts, lens)
        step = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        halo = og.nbrs_rank[base + step]
    else:
        halo = np.zeros(0, np.int32)
    closure = np.union1d(units, halo).astype(np.int64)
    in_closure = np.zeros(og.n, bool)
    in_closure[closure] = True

    cstarts = og.offsets[closure].astype(np.int64)
    clens = og.offsets[closure + 1].astype(np.int64) - cstarts
    ctotal = int(clens.sum())
    offsets = np.zeros(og.n + 1, np.int64)
    if ctotal:
        base = np.repeat(cstarts, clens)
        step = np.arange(ctotal) - np.repeat(np.cumsum(clens) - clens,
                                             clens)
        idx = base + step
        row_of = np.repeat(closure, clens)
        ent_rank = og.nbrs_rank[idx]
        ent_byid = og.nbrs_byid[idx]
        keep_rank = in_closure[ent_rank]
        keep_byid = in_closure[ent_byid]
        # same multiset per row in both orders → identical kept lengths
        kept_lens = np.bincount(row_of[keep_rank], minlength=og.n)
        offsets[1:] = np.cumsum(kept_lens)
        nbrs_rank = ent_rank[keep_rank].astype(np.int32)
        nbrs_byid = ent_byid[keep_byid].astype(np.int32)
    else:
        nbrs_rank = np.zeros(0, np.int32)
        nbrs_byid = np.zeros(0, np.int32)
    return offsets.astype(np.int32), nbrs_rank, nbrs_byid


@dataclasses.dataclass
class ShardStore:
    """Spill + load interface for one (fingerprint, plan_sig) ledger."""
    root: str
    fingerprint: str
    plan_sig: str

    @property
    def dir(self) -> str:
        return os.path.join(self.root, self.fingerprint, self.plan_sig)

    def _files(self, task_id: str) -> dict:
        d = self.dir
        return {"offsets": os.path.join(d, f"t_{task_id}.offsets.npy"),
                "rank": os.path.join(d, f"t_{task_id}.rank.npy"),
                "byid": os.path.join(d, f"t_{task_id}.byid.npy")}

    def manifest_path(self) -> str:
        return os.path.join(self.dir, MANIFEST)

    def load_manifest(self) -> dict | None:
        try:
            with open(self.manifest_path()) as f:
                man = json.load(f)
        except (OSError, ValueError):
            return None
        if (man.get("fingerprint") != self.fingerprint
                or man.get("plan_sig") != self.plan_sig):
            return None
        return man

    def ensure(self, og: OrientedGraph, tasks: list[Task]) -> dict:
        """Spill slices for every task (idempotent). Returns spill
        telemetry: whether shards were built or reused, total spilled
        bytes, and the largest single slice."""
        man = self.load_manifest()
        if man is not None and set(man["tasks"]) == \
                {t.task_id for t in tasks}:
            return {"spill": "reused", "spill_bytes": man["spill_bytes"],
                    "max_slice_bytes": man["max_slice_bytes"]}
        os.makedirs(self.dir, exist_ok=True)
        np.save(os.path.join(self.dir, "out_deg.npy"),
                og.out_deg.astype(np.int32))
        per_task = {}
        spill_bytes = int(og.out_deg.astype(np.int32).nbytes)
        max_slice = 0
        for t in tasks:
            offsets, nbrs_rank, nbrs_byid = _closure_slice(og, t.units)
            files = self._files(t.task_id)
            np.save(files["offsets"], offsets)
            np.save(files["rank"], nbrs_rank)
            np.save(files["byid"], nbrs_byid)
            nbytes = int(offsets.nbytes + nbrs_rank.nbytes
                         + nbrs_byid.nbytes)
            per_task[t.task_id] = {"slice_bytes": nbytes,
                                   "edges": int(nbrs_rank.size)}
            spill_bytes += nbytes
            max_slice = max(max_slice, nbytes)
        man = {"fingerprint": self.fingerprint, "plan_sig": self.plan_sig,
               "n": int(og.n), "m": int(og.m),
               "spill_bytes": spill_bytes, "max_slice_bytes": max_slice,
               "tasks": per_task}
        tmp = self.manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(man, f, indent=1)
        os.replace(tmp, self.manifest_path())   # manifest last = valid
        return {"spill": "built", "spill_bytes": spill_bytes,
                "max_slice_bytes": max_slice}

    def load(self, task_id: str) -> SliceCSR:
        """mmap one task's slice (pages fault in as the extraction
        touches them and are dropped when the arrays are released)."""
        files = self._files(task_id)
        return SliceCSR(
            offsets=np.load(files["offsets"], mmap_mode="r"),
            nbrs_rank=np.load(files["rank"], mmap_mode="r"),
            nbrs_byid=np.load(files["byid"], mmap_mode="r"),
            out_deg=np.load(os.path.join(self.dir, "out_deg.npy"),
                            mmap_mode="r"))


def csr_footprint_bytes(og: OrientedGraph) -> int:
    """Bytes of the full single-host device CSR (the thing a worker
    does NOT have to hold): offsets + both row orders + out_deg."""
    return 4 * (og.n + 1) + 4 * og.m + 4 * og.m + 4 * og.n
