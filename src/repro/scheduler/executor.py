"""Executor: one worker process of the multi-host scheduler.

Runnable as ``python -m repro.scheduler.executor --connect HOST:PORT``
on any machine that sees the shared spill directory. The process:

1. connects and says hello;
2. receives the *jobspec* — everything needed to rebuild the per-task
   runner locally (spill location, lookup iterations, k / method /
   sampling knobs, seed, tile budget) — note: no graph bytes; slices
   are mmapped from the shared ``ShardStore``;
3. pulls tasks one at a time (``ready`` → ``task``/``wait``/
   ``shutdown``), executing each through the *same*
   :func:`repro.scheduler.driver._make_runner` body the in-process
   pool uses, so distributed results are bit-exact by construction;
4. beats a background heartbeat the whole time, which is what keeps
   its leases alive at the coordinator.

There is no local retry: the coordinator owns retry, speculation, and
reassignment. An executor that fails a task reports the error and asks
for the next one; an executor that dies mid-task simply stops beating
and the lease machinery takes over.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
import types

from .transport import Channel, result_to_wire, task_from_wire


def build_runner(job: dict):
    """Rebuild the per-task execution body from a jobspec. The engine
    shim carries exactly what the executable builders consume — a
    fresh per-process ``ExecutableCache`` and the graph's bitset
    lookup-iteration count — so no engine (and no graph) is needed."""
    import jax

    from ..engine.backends import ExecutableCache
    from .driver import SchedulerConfig, _make_runner
    from .store import ShardStore

    eng = types.SimpleNamespace(
        executables=ExecutableCache(),
        og=types.SimpleNamespace(
            lookup_iters=int(job["lookup_iters"])))
    k = job["k"]
    req = types.SimpleNamespace(
        k=(k if k == "all" else int(k)),
        effective_method=str(job["method"]),
        p=float(job["p"]),
        colors=int(job["colors"]),
        return_per_node=bool(job["per_node"]))
    key = (None if job.get("seed") is None
           else jax.random.PRNGKey(int(job["seed"])))
    store = ShardStore(root=job["spill_root"],
                       fingerprint=job["fingerprint"],
                       plan_sig=job["plan_sig"])
    cfg = SchedulerConfig(
        tile_elem_budget=int(job["tile_elem_budget"]))
    return _make_runner(eng, store, req, key, cfg)


def serve(chan: Channel, name: str) -> int:
    chan.send({"type": "hello", "executor": name, "pid": os.getpid()})
    job = chan.recv()
    if job is None or job.get("type") != "job":
        return 1
    runner = build_runner(job)
    delay = float(job.get("task_delay_s", 0.0))
    stop = threading.Event()

    def beat() -> None:
        hb = float(job.get("heartbeat_s", 1.0))
        while not stop.wait(hb):
            try:
                chan.send({"type": "heartbeat"})
            except OSError:
                return
    threading.Thread(target=beat, daemon=True,
                     name="executor-heartbeat").start()

    try:
        while True:
            chan.send({"type": "ready"})
            msg = chan.recv()
            if msg is None or msg.get("type") == "shutdown":
                break
            if msg.get("type") == "wait":
                time.sleep(float(msg.get("wait_s", 0.05)))
                continue
            if msg.get("type") != "task":
                continue
            task = task_from_wire(msg["task"])
            if delay > 0:
                time.sleep(delay)   # chaos "slow": a deterministic
                #                     straggler for the speculation path
            try:
                res, loaded = runner(task)
            except BaseException as e:  # noqa: BLE001 — reported upstream
                chan.send({"type": "error", "task": task.task_id,
                           "error": f"{type(e).__name__}: {e}"})
                continue
            out = {"type": "result", "task": task.task_id,
                   "loaded": int(loaded)}
            out.update(result_to_wire(res))
            chan.send(out)
    except OSError:
        return 1    # coordinator went away: nothing left to report to
    finally:
        stop.set()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro out-of-core scheduler executor")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="coordinator address")
    ap.add_argument("--id", default=None,
                    help="executor name (default pid-derived)")
    args = ap.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=60)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    chan = Channel(sock)
    try:
        return serve(chan, args.id or f"pid{os.getpid()}")
    finally:
        chan.close()


if __name__ == "__main__":
    sys.exit(main())
