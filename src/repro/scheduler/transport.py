"""Wire protocol for the coordinator/executor pair: length-prefixed
JSON frames over a TCP socket, plus the task/result codecs.

Framing is a 4-byte big-endian payload length followed by that many
bytes of UTF-8 JSON. JSON keeps the protocol debuggable (``tcpdump``
shows the conversation) and — crucially for bit-exactness — Python's
``json`` round-trips ``float`` via ``repr``, so a task's f64 partial
sum survives the socket unchanged and the distributed aggregation
matches the in-process backends bit for bit.

A truncated read (peer died mid-frame) surfaces as ``None`` from
:func:`recv_frame`, never as a partial object: the coordinator treats
it like any other disconnect and the lease machinery takes over.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Optional

import numpy as np

from .ledger import TaskResult
from .tasks import Task

# refuse absurd frames before allocating for them; the largest real
# frame is a per-node result (~4096 units of id+float ≈ a few hundred
# KB), so 64 MiB is orders of magnitude of headroom, not a limit
MAX_FRAME = 64 << 20
_LEN = struct.Struct(">I")


def send_frame(sock: socket.socket, obj: dict) -> None:
    payload = json.dumps(obj, separators=(",", ":")).encode()
    if len(payload) > MAX_FRAME:
        raise ValueError(f"frame of {len(payload)} bytes exceeds cap")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None     # EOF mid-frame: peer is gone
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[dict]:
    """One frame, or ``None`` on EOF/truncation (peer disconnect)."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ValueError(f"frame header claims {n} bytes (cap "
                         f"{MAX_FRAME}); refusing")
    payload = _recv_exact(sock, n)
    if payload is None:
        return None
    obj = json.loads(payload.decode())
    if not isinstance(obj, dict):
        raise ValueError("frame payload is not a JSON object")
    return obj


class Channel:
    """A socket with a send lock: the executor's heartbeat thread and
    its task loop (and, coordinator-side, dispatch vs shutdown) share
    one socket, and interleaved ``sendall`` calls would tear frames."""

    def __init__(self, sock: socket.socket) -> None:
        self.sock = sock
        self._send_lock = threading.Lock()

    def send(self, obj: dict) -> None:
        with self._send_lock:
            send_frame(self.sock, obj)

    def recv(self) -> Optional[dict]:
        try:
            return recv_frame(self.sock)
        except OSError:
            return None     # closed under us: same as a disconnect

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


# -- task / result codecs ---------------------------------------------------

def task_to_wire(task: Task) -> dict:
    d = {"task_id": task.task_id, "kind": task.kind,
         "capacity": int(task.capacity), "tile_repr": task.tile_repr,
         "units": [int(u) for u in np.asarray(task.units)],
         "cost": float(task.cost), "r": int(task.r)}
    if task.pivots is not None:
        d["pivots"] = [int(p) for p in np.asarray(task.pivots)]
    return d


def task_from_wire(d: dict) -> Task:
    pivots = d.get("pivots")
    return Task(task_id=d["task_id"], kind=d["kind"],
                capacity=int(d["capacity"]), tile_repr=d["tile_repr"],
                units=np.asarray(d["units"], np.int32),
                pivots=(None if pivots is None
                        else np.asarray(pivots, np.int32)),
                cost=float(d["cost"]), r=int(d["r"]))


def result_to_wire(res: TaskResult) -> dict:
    # same field names as the ledger records: the wire format IS the
    # commit format, minus the coordinator-side fsync
    d = {"sum": res.task_sum, "elapsed_s": res.elapsed_s}
    if res.unit_ids is not None:
        d["units"] = [int(u) for u in res.unit_ids]
        d["values"] = [float(v) for v in res.unit_vals]
    if res.profile is not None:
        d["profile"] = [float(v) for v in res.profile]
    return d


def result_from_wire(d: dict) -> TaskResult:
    res = TaskResult(task_sum=float(d["sum"]),
                     elapsed_s=float(d.get("elapsed_s", 0.0)))
    if "units" in d:
        res.unit_ids = np.asarray(d["units"], np.int64)
        res.unit_vals = np.asarray(d["values"], np.float64)
    if "profile" in d:
        res.profile = np.asarray(d["profile"], np.float64)
    return res
