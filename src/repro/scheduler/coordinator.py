"""Coordinator for the multi-host out-of-core scheduler.

One coordinator process owns the compiled task ledger and hands tasks
to N executor processes (:mod:`repro.scheduler.executor`) over the
length-prefixed JSON protocol in :mod:`repro.scheduler.transport`.
Executors fetch their closure slices straight from the shared
``ShardStore`` spill directory — the coordinator never moves graph
bytes, only task ids and partial sums.

Fault model
-----------
- **Leases.** Every assignment carries a monotonic-clock lease
  (``cfg.lease_s``); any frame from the executor — heartbeat, ready,
  result — renews all of its leases. A SIGSTOPped or wedged executor
  stops beating, its leases expire, and the tasks are reassigned to
  live executors; a SIGKILLed executor's socket closes, which expires
  its leases immediately. An executor that keeps losing leases is
  re-admitted on an exponential backoff
  (:func:`repro.runtime.faults.backoff_delay`) so a flapping host
  cannot keep reclaiming work it will never finish.
- **Ledger as commit protocol.** A task counts exactly once, and only
  once its result is fsynced to the coordinator's JSONL ledger
  (:meth:`CompletionCore.commit`). Crashes, duplicate completions from
  lease races, and cross-host speculation all resolve to
  first-committed-wins, and ``resume=True`` replays the ledger across
  topologies (in-process pool ↔ any executor count share signatures).
- **Graceful degradation.** Down to one surviving executor the run
  completes (work stealing drains dead executors' queues). If *every*
  executor is lost the coordinator fails loudly pointing at the
  ledger; a coordinator crash is recoverable the same way — ledger +
  spill are the entire durable state.
- **Speculation across hosts.** The same p95-rate envelope as the
  in-process pool (:meth:`CompletionCore.straggler_envelope`), with
  the duplicate handed only to a *different* host than every current
  lease holder, so a systematically slow machine cannot speculate
  against itself.

Chaos (``cfg.chaos``, see :mod:`repro.runtime.chaos`) injects kills /
hangs / partitions / slowdowns on deterministic commit-count schedules
for the tier-1 smoke and the fault-drill tests.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import re
import signal
import socket
import subprocess
import sys
import threading
import time
import zlib
from typing import Optional

from ..runtime.chaos import ChaosMonkey, parse_chaos
from ..runtime.faults import backoff_delay
from .driver import CompletionCore, SchedulerConfig
from .ledger import TaskLedger, TaskResult
from .store import ShardStore
from .tasks import Task, lpt_assign
from .transport import Channel, result_from_wire, task_to_wire


@dataclasses.dataclass
class Lease:
    """One executor's claim on one task."""
    task: Task
    executor: str
    deadline: float     # monotonic; renewed by any frame from the owner
    since: float        # assignment time (feeds the straggler envelope)
    spec: bool = False  # a speculative duplicate, not the original


class Coordinator:
    """Runs one compiled task ledger to completion on N executors."""

    def __init__(self, store: ShardStore, req, cfg: SchedulerConfig,
                 tasks: list[Task], ledger: TaskLedger,
                 completed: dict[str, TaskResult], *,
                 key_seed: Optional[int],
                 lookup_iters: int) -> None:
        self.cfg = cfg
        self.core = CompletionCore(tasks, ledger, completed, cfg)
        self.tasks = self.core.tasks
        self.ledger = ledger
        # the jobspec every executor receives right after hello; the
        # executor rebuilds the per-task runner from this alone (plus
        # the spill dir), so a remote host needs nothing but the wheel
        # and the shared filesystem
        self.job = {
            "type": "job",
            "spill_root": store.root,
            "fingerprint": store.fingerprint,
            "plan_sig": store.plan_sig,
            "lookup_iters": int(lookup_iters),
            "k": req.k,
            "method": req.effective_method,
            "p": float(req.p),
            "colors": int(req.colors),
            "per_node": bool(req.return_per_node),
            "seed": key_seed,
            "tile_elem_budget": int(cfg.tile_elem_budget),
            "heartbeat_s": float(cfg.heartbeat_s
                                 if cfg.heartbeat_s is not None
                                 else cfg.lease_s / 4.0),
        }
        # all mutable state below is guarded by this (reentrant, so the
        # chaos monkey's holds_lease probe works from the monitor tick)
        self.lock = threading.RLock()
        self.cond = threading.Condition(self.lock)
        pending = [t for t in tasks if t.task_id not in completed]
        n_queues = max(int(cfg.executors), 1)
        self.queues = [collections.deque(d)
                       for d in lpt_assign(pending, n_queues)]
        self.reassign: collections.deque[Task] = collections.deque()
        self.spec_queue: collections.deque[Task] = collections.deque()
        self.spec_issued: set[str] = set()
        self.leases: dict[tuple[str, str], Lease] = {}
        self.hosts: dict[str, dict] = {}
        self.retries: collections.Counter = collections.Counter()
        self.retry_after: dict[str, float] = {}
        self.expiries: collections.Counter = collections.Counter()
        self.penalty_until: dict[str, float] = {}
        self.stats = collections.Counter(
            run=0, stolen=0, speculated=0, speculation_wins=0, retried=0,
            abandoned_failures=0, lease_expiries=0, reassigned=0,
            heartbeats_missed=0)
        self.peak_task_bytes = 0
        self.commits_run = 0
        self.failure: Optional[BaseException] = None
        self.failed_task: Optional[str] = None
        self.done = False
        self.address: Optional[tuple[str, int]] = None
        self._listener: Optional[socket.socket] = None
        self._threads: list[threading.Thread] = []
        self._procs: list[subprocess.Popen] = []
        self._stopped: set[int] = set()   # SIGSTOPped executor indices
        self._hello_count = 0
        self._ever_connected = False
        self._last_alive = time.monotonic()
        self.chaos: Optional[ChaosMonkey] = None
        if cfg.chaos:
            self.chaos = ChaosMonkey(
                parse_chaos(cfg.chaos),
                kill=self._chaos_kill, stop=self._chaos_stop,
                cont=self._chaos_cont, partition=self._chaos_part)

    # -- chaos callbacks (process-level how; chaos.py owns the when) -------

    def _signal_proc(self, idx: int, sig: int) -> None:
        if 0 <= idx < len(self._procs):
            try:
                os.kill(self._procs[idx].pid, sig)
            except (OSError, ProcessLookupError):
                pass

    def _chaos_kill(self, idx: int) -> None:
        self._signal_proc(idx, signal.SIGKILL)

    def _chaos_stop(self, idx: int) -> None:
        self._stopped.add(idx)
        self._signal_proc(idx, signal.SIGSTOP)

    def _chaos_cont(self, idx: int) -> None:
        self._stopped.discard(idx)
        self._signal_proc(idx, signal.SIGCONT)

    def _chaos_part(self, idx: int) -> None:
        with self.lock:
            chans = [h["chan"] for h in self.hosts.values()
                     if h["index"] == idx and h["alive"]]
        for chan in chans:
            chan.close()    # its serve thread sees EOF → disconnect path

    def _holds_lease(self, idx: int) -> bool:
        with self.lock:
            return any(
                e in self.hosts and self.hosts[e]["index"] == idx
                for (_, e) in self.leases)

    # -- connection lifecycle ----------------------------------------------

    def _spawn(self) -> None:
        host, port = self.address
        src = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        for i in range(self.cfg.executors):
            self._procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.scheduler.executor",
                 "--connect", f"{host}:{port}", "--id", f"e{i}"],
                env=env, stdout=subprocess.DEVNULL))

    def _accept_loop(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return      # listener closed: shutdown
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve, args=(Channel(sock),),
                                 daemon=True)
            t.start()
            with self.lock:
                self._threads.append(t)

    def _serve(self, chan: Channel) -> None:
        hello = chan.recv()
        if not hello or hello.get("type") != "hello":
            chan.close()
            return
        eid = str(hello.get("executor") or "anon")
        now = time.monotonic()
        with self.cond:
            if self.done:
                chan.close()
                return
            while eid in self.hosts:
                eid += "+"      # never alias a reconnecting name
            m = re.fullmatch(r"e(\d+)", eid)
            idx = int(m.group(1)) if m else self._hello_count
            self._hello_count += 1
            self._ever_connected = True
            self.hosts[eid] = {
                "index": idx, "queue": idx % len(self.queues),
                "chan": chan, "alive": True, "last_seen": now,
                "assigned": 0, "committed": 0, "stolen": 0,
                "lease_expiries": 0}
            job = dict(self.job)
            job["executor"] = eid
            job["task_delay_s"] = float(
                self.cfg.task_delay_s
                + (self.chaos.task_delay(idx) if self.chaos else 0.0))
        try:
            chan.send(job)
            while True:
                msg = chan.recv()
                if msg is None:
                    break
                typ = msg.get("type")
                now = time.monotonic()
                if typ == "heartbeat":
                    with self.cond:
                        self._renew(eid, now)
                elif typ == "ready":
                    if not self._handle_ready(eid, chan, now):
                        break
                elif typ == "result":
                    self._handle_result(eid, msg, now)
                elif typ == "error":
                    self._handle_error(eid, msg, now)
                elif typ == "goodbye":
                    break
        except OSError:
            pass
        finally:
            self._on_disconnect(eid)
            chan.close()

    def _renew(self, eid: str, now: float) -> None:
        """Any frame from an executor is proof of life: bump its
        last-seen and push every lease it holds out by one period."""
        h = self.hosts.get(eid)
        if h is not None:
            h["last_seen"] = now
        for (tid, e), lease in self.leases.items():
            if e == eid:
                lease.deadline = now + self.cfg.lease_s

    # -- scheduling --------------------------------------------------------

    def _queued(self, tid: str) -> bool:
        return (any(t.task_id == tid for t in self.reassign)
                or any(t.task_id == tid for t in self.spec_queue)
                or any(t.task_id == tid for q in self.queues for t in q))

    def _next_task(self, eid: str, now: float
                   ) -> Optional[tuple[Task, bool]]:
        """Pick the next task for ``eid`` (lock held): reassigned work
        first (it is already late), then the executor's own queue, then
        steal from the fullest peer's tail, then a cross-host
        speculative duplicate. Returns (task, is_speculative)."""
        if now < self.penalty_until.get(eid, 0.0):
            return None     # flapping host: paced re-admission
        h = self.hosts[eid]
        for _ in range(len(self.reassign)):
            t = self.reassign.popleft()
            if t.task_id in self.core.results:
                continue    # a zombie original committed it meanwhile
            if now < self.retry_after.get(t.task_id, 0.0):
                self.reassign.append(t)     # still backing off
                continue
            return t, False
        q = self.queues[h["queue"]]
        if q:
            return q.popleft(), False
        victims = sorted(range(len(self.queues)),
                         key=lambda w: -len(self.queues[w]))
        for v in victims:
            if v != h["queue"] and self.queues[v]:
                self.stats["stolen"] += 1
                h["stolen"] += 1
                return self.queues[v].pop(), False  # steal the tail
        for _ in range(len(self.spec_queue)):
            t = self.spec_queue.popleft()
            if t.task_id in self.core.results:
                continue
            holders = [e for (tid, e) in self.leases
                       if tid == t.task_id]
            if eid in holders:
                self.spec_queue.append(t)   # same host: no point
                continue
            return t, True
        return None

    def _handle_ready(self, eid: str, chan: Channel,
                      now: float) -> bool:
        """Reply to a work request. Returns False once the executor has
        been told to shut down."""
        with self.cond:
            self._renew(eid, now)
            if (self.done or self.core.finished()
                    or self.failure is not None):
                reply: dict = {"type": "shutdown"}
            else:
                pick = self._next_task(eid, now)
                if pick is None:
                    reply = {"type": "wait",
                             "wait_s": max(self.cfg.poll_s, 0.02)}
                else:
                    task, spec = pick
                    self.leases[(task.task_id, eid)] = Lease(
                        task=task, executor=eid,
                        deadline=now + self.cfg.lease_s, since=now,
                        spec=spec)
                    self.hosts[eid]["assigned"] += 1
                    reply = {"type": "task", "task": task_to_wire(task)}
        try:
            chan.send(reply)
        except OSError:
            return False    # disconnect path cleans up the fresh lease
        return reply["type"] != "shutdown"

    def _handle_result(self, eid: str, msg: dict, now: float) -> None:
        tid = msg.get("task")
        try:
            res = result_from_wire(msg)
        except (KeyError, ValueError, TypeError):
            return          # malformed frame: drop; the lease recovers it
        fire = None
        with self.cond:
            self._renew(eid, now)
            lease = self.leases.pop((tid, eid), None)
            if tid in self.tasks and self.core.commit(tid, res):
                self.stats["run"] += 1
                self.commits_run += 1
                self.retry_after.pop(tid, None)
                h = self.hosts.get(eid)
                if h is not None:
                    h["committed"] += 1
                if lease is not None and lease.spec:
                    self.stats["speculation_wins"] += 1
                if self.chaos is not None:
                    fire = self.commits_run
            self.peak_task_bytes = max(self.peak_task_bytes,
                                       int(msg.get("loaded", 0)))
            self.cond.notify_all()
        if fire is not None:
            self.chaos.on_commit(fire, self._holds_lease)

    def _handle_error(self, eid: str, msg: dict, now: float) -> None:
        tid = msg.get("task")
        with self.cond:
            self._renew(eid, now)
            self.leases.pop((tid, eid), None)
            if tid not in self.tasks or tid in self.core.results:
                self.cond.notify_all()
                return
            self.retries[tid] += 1
            if self.retries[tid] > self.cfg.max_retries:
                # terminal only when this was the last path to a result
                # (same discipline as the in-process pool)
                alive = (any(t == tid for (t, _) in self.leases)
                         or self._queued(tid))
                if alive:
                    self.stats["abandoned_failures"] += 1
                elif self.failure is None:
                    self.failure = RuntimeError(
                        f"executor {eid}: {msg.get('error')}")
                    self.failed_task = tid
            else:
                self.stats["retried"] += 1
                self.retry_after[tid] = now + backoff_delay(
                    self.retries[tid], base_s=self.cfg.retry_backoff_s,
                    factor=2.0, cap_s=self.cfg.retry_backoff_cap_s,
                    jitter=self.cfg.retry_jitter,
                    seed=zlib.crc32(tid.encode()))
                if not any(t == tid for (t, _) in self.leases) \
                        and not self._queued(tid):
                    self.reassign.append(self.tasks[tid])
            self.cond.notify_all()

    def _on_disconnect(self, eid: str) -> None:
        """A closed socket (SIGKILL, partition, clean exit) expires the
        executor's leases immediately — no need to wait out the clock;
        the kernel told us the owner is gone."""
        with self.cond:
            h = self.hosts.get(eid)
            if h is None or not h["alive"]:
                return
            h["alive"] = False
            if not (self.done or self.core.finished()):
                for (tid, e) in list(self.leases):
                    if e != eid:
                        continue
                    del self.leases[(tid, e)]
                    if tid in self.core.results:
                        continue
                    self.stats["lease_expiries"] += 1
                    h["lease_expiries"] += 1
                    self._requeue_lost(tid)
            self.cond.notify_all()

    def _requeue_lost(self, tid: str) -> None:
        """Put an expired lease's task back in rotation unless some
        other live lease or queue already covers it (lock held)."""
        if any(t == tid for (t, _) in self.leases) or self._queued(tid):
            return
        self.reassign.append(self.tasks[tid])
        self.stats["reassigned"] += 1

    # -- monitor -----------------------------------------------------------

    def _tick(self, now: float, t_start: float) -> Optional[int]:
        """One monitor pass (lock held): expire overdue leases, issue
        speculation, check liveness. Returns a commit count when the
        chaos monkey should be poked (outside the tick's hot path)."""
        # lease expiry: the owner stopped heartbeating but its socket
        # is still open (SIGSTOP, wedged GC, network half-up)
        for (tid, eid), lease in list(self.leases.items()):
            if now <= lease.deadline:
                continue
            del self.leases[(tid, eid)]
            self.stats["lease_expiries"] += 1
            self.expiries[eid] += 1
            h = self.hosts.get(eid)
            if h is not None and h["alive"]:
                self.stats["heartbeats_missed"] += 1
                h["lease_expiries"] += 1
            # pace re-admission: each expiry doubles the penalty window
            self.penalty_until[eid] = now + backoff_delay(
                self.expiries[eid], base_s=self.cfg.host_backoff_s,
                factor=2.0, cap_s=self.cfg.host_backoff_cap_s,
                jitter=self.cfg.retry_jitter,
                seed=zlib.crc32(eid.encode()))
            if tid not in self.core.results:
                self._requeue_lost(tid)
        # cross-host speculation: same envelope as the in-process pool
        tail = (not any(self.queues) and not self.reassign
                and not self.spec_queue)
        threshold = self.core.straggler_envelope(tail)
        if threshold is not None:
            live = sum(1 for h in self.hosts.values() if h["alive"])
            for (tid, eid), lease in list(self.leases.items()):
                if (live < 2 or tid in self.core.results
                        or tid in self.spec_issued):
                    continue
                if now - lease.since > threshold(lease.task.cost):
                    self.spec_issued.add(tid)
                    self.spec_queue.append(lease.task)
                    self.stats["speculated"] += 1
                    self.cond.notify_all()
        # liveness: every executor gone and none coming back
        if not any(h["alive"] for h in self.hosts.values()) \
                and self.failure is None:
            procs_dead = self._procs and all(
                p.poll() is not None for p in self._procs)
            waited_out = (now - max(self._last_alive, t_start)
                          > self.cfg.connect_timeout_s)
            if (self._ever_connected and (procs_dead or waited_out)) \
                    or (not self._ever_connected and waited_out):
                self.failure = RuntimeError(
                    "all executors lost" if self._ever_connected
                    else "no executor connected within "
                         f"{self.cfg.connect_timeout_s:.0f}s")
        else:
            self._last_alive = now
        if self.chaos is not None and self.chaos.pending():
            return self.commits_run
        return None

    def run(self) -> dict[str, TaskResult]:
        if self.core.finished():
            # a fully-replayed resume: nothing to execute — do not bind
            # a port or spawn a single process
            return self.core.results
        self._listener = socket.socket(socket.AF_INET,
                                       socket.SOCK_STREAM)
        self._listener.bind((self.cfg.bind_host, self.cfg.bind_port))
        self._listener.listen(64)
        self.address = self._listener.getsockname()[:2]
        acceptor = threading.Thread(target=self._accept_loop,
                                    daemon=True, name="ooc-accept")
        acceptor.start()
        if self.cfg.spawn_executors:
            self._spawn()
        t_start = time.monotonic()
        period = min(self.cfg.poll_s, max(self.cfg.lease_s / 8.0, 0.005))
        try:
            with self.cond:
                while not self.core.finished() \
                        and self.failure is None:
                    self.cond.wait(period)
                    fire = self._tick(time.monotonic(), t_start)
                    if fire is not None:
                        self.chaos.on_commit(fire, self._holds_lease)
        finally:
            self._shutdown(acceptor)
        if self.failure is not None:
            raise RuntimeError(
                f"task {self.failed_task} failed after "
                f"{self.cfg.max_retries} retries; completed work is "
                f"journaled in {self.ledger.path} — rerun with "
                f"resume=True"
                if self.failed_task is not None else
                f"{self.failure}; completed work is journaled in "
                f"{self.ledger.path} — rerun with resume=True"
            ) from self.failure
        return self.core.results

    def _shutdown(self, acceptor: threading.Thread) -> None:
        with self.cond:
            self.done = True
            self.cond.notify_all()
        if self.chaos is not None:
            self.chaos.cancel()
            for idx in list(self._stopped):
                self._chaos_cont(idx)   # let frozen executors exit
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        with self.lock:
            chans = [h["chan"] for h in self.hosts.values()]
            threads = list(self._threads)
        for chan in chans:
            try:
                chan.send({"type": "shutdown"})
            except OSError:
                pass
            chan.close()
        # serve threads must be parked before the caller closes the
        # ledger: a result landing after close would be dropped on the
        # floor *silently* (ledger._write tolerates closed handles)
        for t in threads:
            t.join(timeout=5.0)
        acceptor.join(timeout=5.0)
        for p in self._procs:
            if p.poll() is None:
                p.terminate()
        for p in self._procs:
            if p.poll() is None:
                try:
                    p.wait(timeout=2.0)
                except subprocess.TimeoutExpired:
                    p.kill()
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                pass

    # -- telemetry ---------------------------------------------------------

    def extra_stats(self) -> dict:
        with self.lock:
            per_host = {
                eid: {"assigned": h["assigned"],
                      "committed": h["committed"],
                      "stolen": h["stolen"],
                      "lease_expiries": h["lease_expiries"]}
                for eid, h in self.hosts.items()}
            out = {"executors": int(self.cfg.executors),
                   "spawned": len(self._procs),
                   "per_host": per_host}
            if self.chaos is not None:
                out["chaos"] = list(self.chaos.applied)
        return out
