"""The scheduler's driver: a work-stealing worker pool with straggler
speculation, per-task retry, and ledger checkpointing.

Execution model
---------------
``compile_tasks`` turns the cached plan into idempotent tasks;
``lpt_assign`` seeds one deque per worker (heaviest-first, least-loaded
— the plan partitioner's LPT balancing at task granularity). Each
worker pops from the *front* of its own deque (its heaviest remaining
task) and, when empty, steals from the *back* of the fullest peer (the
lightest task — the classic deque discipline that keeps steals cheap
and rare). Tasks are pure functions of (graph, plan, request, seed), so
every recovery mechanism below is safe by idempotence:

- **retry** — a failed execution (worker fault, injected or real) is
  retried up to ``max_retries`` times with exponential backoff +
  deterministic per-task jitter (:mod:`repro.runtime.faults`).
- **speculation** — the paper's Fig. 6 "curse of the last reducer" at
  runtime: once enough tasks have finished to estimate a per-cost rate
  distribution, any task whose elapsed time exceeds
  ``factor × p95_rate × cost`` is re-enqueued speculatively;
  first-result-wins, the loser is discarded.
- **resume** — completions are journaled to the task ledger the moment
  they land; a killed driver replays the ledger and recounts nothing.

Aggregation is associative and performed in sorted-task-id order, so
the answer is independent of completion order, worker count, stealing,
and speculation — bit-exact against the single-host backends.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import tempfile
import threading
import time
import zlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.count import _pick_tile_b, tile_batch_repr
from ..core.extract import DeviceCSR
from ..runtime.faults import FaultDomain, backoff_delay
from .ledger import TaskLedger, TaskResult, query_signature
from .store import ShardStore, csr_footprint_bytes
from .tasks import (Task, compile_profile_tasks, compile_tasks, lpt_assign,
                    plan_signature)


@dataclasses.dataclass
class SchedulerConfig:
    """Knobs for the out-of-core backend (``CountRequest(backend="ooc")``
    on an engine built with ``CliqueEngine(g, ooc=SchedulerConfig(...))``).
    """
    n_workers: int = 4
    spill_dir: Optional[str] = None      # default: $TMPDIR/repro-ooc
    resume: bool = False                 # replay a prior run's ledger
    tile_elem_budget: int = 1 << 21      # per-worker tile budget (f32 elems)
    target_tasks: int = 32               # ledger granularity (W-independent)
    max_units_per_task: int = 4096
    # straggler re-execution
    speculate: bool = True
    speculation_factor: float = 4.0      # × expected (p95 rate · cost)
    speculation_quantile: float = 0.95
    speculation_min_done: int = 3        # completions before rates exist
    speculation_min_s: float = 0.2       # absolute floor (no µs-task churn)
    poll_s: float = 0.02                 # monitor period
    # per-task retry (exponential backoff, deterministic per-task jitter)
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    retry_backoff_cap_s: float = 2.0
    retry_jitter: float = 0.25
    # test/CI hooks
    faults: Optional[FaultDomain] = None  # injected failures (maybe_fail)
    delay_hook: Optional[Callable[[str, int], float]] = None
    # delay_hook(task_id, execution_index) -> extra seconds; execution 0
    # is the original run, ≥1 are speculative re-executions — so a test
    # can delay only the original and watch speculation win
    # multi-host: executors > 0 switches the run from the in-process
    # worker pool to a coordinator driving that many real executor
    # subprocesses over sockets (:mod:`repro.scheduler.coordinator`);
    # faults/delay_hook are in-process hooks and don't cross the
    # boundary — use ``chaos``/``task_delay_s`` there instead
    executors: int = 0
    lease_s: float = 5.0                 # task lease; any frame renews it
    heartbeat_s: Optional[float] = None  # executor beat; default lease/4
    bind_host: str = "127.0.0.1"
    bind_port: int = 0                   # 0 = ephemeral
    spawn_executors: bool = True         # False: external --connect hosts
    connect_timeout_s: float = 120.0     # first hello must land by then
    host_backoff_s: float = 0.25         # flapping-host re-admission base
    host_backoff_cap_s: float = 5.0
    task_delay_s: float = 0.0            # uniform executor-side delay
    chaos: Optional[str] = None          # runtime.chaos schedule spec


def _pow2_pad(a: np.ndarray, fill: int) -> np.ndarray:
    """Pad a 1-D array to the next power of two so slice shapes repeat
    across tasks and the jitted tile executables compile once per size
    class instead of once per task."""
    n = max(int(a.size), 1)
    target = 1 << (n - 1).bit_length()
    if target == a.size:
        return np.ascontiguousarray(a)
    out = np.full(target, fill, a.dtype)
    out[:a.size] = a
    return out


def _fixed_batches(arr: np.ndarray, B: int, fill: int):
    """Yield fixed-width (B,) tiles of ``arr``, padding the last with
    ``fill``. An empty input yields nothing: a zero-unit task must do
    zero device work, not dispatch one tile of pure padding."""
    for i in range(0, len(arr), B):
        tile = arr[i:i + B]
        if len(tile) < B:
            tile = np.concatenate(
                [tile, np.full(B - len(tile), fill, arr.dtype)])
        yield tile


def _make_runner(eng, store: ShardStore, req, key, cfg: SchedulerConfig):
    """Build the pure per-task execution body. Returns
    ``run(task) -> (TaskResult, loaded_bytes)``."""
    from ..engine.backends import (profile_executable, split_executable,
                                   tile_executable)
    # profile (k="all") tasks carry their own depth in task.r
    r = req.k - 1 if isinstance(req.k, int) else 0
    method = req.effective_method
    p, c = float(req.p), int(req.colors)
    per_node = bool(req.return_per_node)

    def run(task: Task) -> tuple[TaskResult, int]:
        t0 = time.perf_counter()
        sl = store.load(task.task_id)
        csr = DeviceCSR(
            offsets=jnp.asarray(np.ascontiguousarray(sl.offsets)),
            nbrs_rank=jnp.asarray(_pow2_pad(sl.nbrs_rank, -1)),
            nbrs_byid=jnp.asarray(_pow2_pad(sl.nbrs_byid, -1)),
            out_deg=jnp.asarray(np.ascontiguousarray(sl.out_deg)))
        loaded = int(csr.offsets.nbytes + csr.nbrs_rank.nbytes
                     + csr.nbrs_byid.nbytes + csr.out_deg.nbytes)
        batch_repr = tile_batch_repr(task.tile_repr, method)
        # pow2-rounded unit count, so tile widths fall into a handful of
        # size classes shared across tasks (≤ log₂ distinct compiles per
        # capacity) instead of one compile per task — while still
        # shrinking with the task so small tasks aren't mostly padding
        width = 1 << (max(task.n_units, 1) - 1).bit_length()
        B = _pick_tile_b(width, task.capacity, cfg.tile_elem_budget,
                         batch_repr)
        total = 0.0
        ids: list[np.ndarray] = []
        vals: list[np.ndarray] = []

        def accumulate(v, tile):
            nonlocal total
            v = np.asarray(jax.block_until_ready(v), np.float64)
            total += float(v.sum())
            if per_node:
                sel = tile >= 0
                ids.append(tile[sel].astype(np.int64))
                vals.append(v[sel])

        if task.kind == "profile":
            fn = profile_executable(eng, "jnp", task.tile_repr,
                                    task.capacity, task.r)
            prof = np.zeros(task.r - 1, np.float64)
            for tile in _fixed_batches(task.units, B, -1):
                prof += np.asarray(jax.block_until_ready(
                    fn(csr, jnp.asarray(tile))), np.float64).sum(axis=0)
            return TaskResult(task_sum=float(prof.sum()),
                              elapsed_s=time.perf_counter() - t0,
                              profile=prof), loaded
        if task.kind == "bucket":
            fn = tile_executable(eng, "jnp", task.tile_repr,
                                 task.capacity, r, method)
            for tile in _fixed_batches(task.units, B, -1):
                accumulate(fn(csr, jnp.asarray(tile), key, p=p, c=c),
                           tile)
        else:
            fn = split_executable(eng, "jnp", task.tile_repr,
                                  task.capacity, r, method)
            pivots = list(_fixed_batches(task.pivots, B, 0))
            for tile, tp in zip(_fixed_batches(task.units, B, -1),
                                pivots):
                accumulate(fn(csr, jnp.asarray(tile), jnp.asarray(tp),
                              key, p=p, c=c), tile)
        res = TaskResult(task_sum=total,
                         elapsed_s=time.perf_counter() - t0)
        if per_node:
            res.unit_ids = (np.concatenate(ids) if ids
                            else np.zeros(0, np.int64))
            res.unit_vals = (np.concatenate(vals) if vals
                             else np.zeros(0, np.float64))
        return res, loaded

    return run


class CompletionCore:
    """The completion/speculation state machine shared by the
    in-process pool (:class:`Driver`) and the distributed pool
    (:class:`repro.scheduler.coordinator.Coordinator`): first-
    committed-wins ledger commit, per-cost rate tracking, and the p95
    straggler envelope. The caller provides its own locking — every
    method here must be invoked under the pool's completion lock."""

    def __init__(self, tasks: list[Task], ledger: TaskLedger,
                 completed: dict[str, TaskResult],
                 cfg: SchedulerConfig) -> None:
        self.cfg = cfg
        self.tasks = {t.task_id: t for t in tasks}
        self.ledger = ledger
        self.results: dict[str, TaskResult] = dict(completed)
        # duplicate completions discarded by first-committed-wins
        # (lease races, cross-host speculation losers, thawed hangs)
        self.commit_dups = 0
        # per-cost completion rates feed the straggler detector; resumed
        # completions contribute too, so a resumed run can speculate
        # from its first fresh task
        self.rates: list[float] = [
            res.elapsed_s / max(self.tasks[tid].cost, 1.0)
            for tid, res in completed.items()
            if res.elapsed_s > 0 and tid in self.tasks]
        self.elapsed: list[float] = [
            res.elapsed_s for tid, res in completed.items()
            if res.elapsed_s > 0 and tid in self.tasks]

    def finished(self) -> bool:
        return len(self.results) >= len(self.tasks)

    def commit(self, task_id: str, res: TaskResult) -> bool:
        """First-committed-wins: a task counts exactly once, and only
        once its result is fsynced to the ledger. Returns False for the
        duplicate (discarded) completion."""
        if task_id in self.results:
            self.commit_dups += 1
            return False
        self.results[task_id] = res
        self.ledger.append(task_id, res)
        self.rates.append(res.elapsed_s
                          / max(self.tasks[task_id].cost, 1.0))
        self.elapsed.append(res.elapsed_s)
        return True

    def straggler_envelope(self, tail: bool):
        """``None`` while speculation can't run (disabled, or too few
        completions to estimate rates), else ``threshold(cost)`` — the
        elapsed seconds past which a running task of that analytic cost
        is declared a straggler. In the tail of the run (every queue
        drained — the paper's last-reducer regime) the envelope is
        capped by absolute p95 completion time: per-cost normalization
        is the right model when runtime tracks cost, but a straggler
        whose slowness is *not* cost (bad node, page-cache miss storm,
        injected delay) must not hide behind a large cost either."""
        cfg = self.cfg
        if not cfg.speculate or len(self.rates) < cfg.speculation_min_done:
            return None
        q = cfg.speculation_quantile
        p95_rate = float(np.quantile(np.asarray(self.rates), q))
        p95_elapsed = float(np.quantile(np.asarray(self.elapsed), q))

        def threshold(cost: float) -> float:
            expected = p95_rate * max(cost, 1.0)
            if tail:
                expected = min(expected, p95_elapsed)
            return max(cfg.speculation_min_s,
                       cfg.speculation_factor * expected)

        return threshold


class Driver:
    """Runs one compiled task ledger to completion."""

    def __init__(self, tasks: list[Task], run_task, cfg: SchedulerConfig,
                 ledger: TaskLedger,
                 completed: dict[str, TaskResult]) -> None:
        self.cfg = cfg
        self.core = CompletionCore(tasks, ledger, completed, cfg)
        self.tasks = self.core.tasks
        self.run_task = run_task
        self.ledger = ledger
        pending = [t for t in tasks if t.task_id not in completed]
        self.deques = [collections.deque(d)
                       for d in lpt_assign(pending, cfg.n_workers)]
        self.spec_queue: collections.deque[Task] = collections.deque()
        self.spec_issued: set[str] = set()
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        # (task_id, execution_idx) -> {"since": t, "cost": c}
        self.running: dict[tuple[str, int], dict] = {}
        self.exec_counts: collections.Counter = collections.Counter()
        self.failure: Optional[BaseException] = None
        self.failed_task: Optional[str] = None
        self.stats = collections.Counter(
            run=0, stolen=0, speculated=0, speculation_wins=0, retried=0,
            abandoned_failures=0)
        self.peak_task_bytes = 0

    @property
    def results(self) -> dict[str, TaskResult]:
        return self.core.results

    # -- scheduling --------------------------------------------------------

    def _finished(self) -> bool:
        return self.core.finished()

    def _take(self, wid: int) -> Optional[tuple[Task, bool]]:
        """Next task for worker ``wid`` (caller holds the lock)."""
        if self.deques[wid]:
            return self.deques[wid].popleft(), False
        if self.spec_queue:
            return self.spec_queue.popleft(), True
        victims = sorted(range(len(self.deques)),
                         key=lambda w: -len(self.deques[w]))
        for v in victims:
            if v != wid and self.deques[v]:
                self.stats["stolen"] += 1
                return self.deques[v].pop(), False   # steal the tail
        return None

    def _worker_loop(self, wid: int) -> None:
        while True:
            with self.cond:
                item = self._take(wid)
                while item is None:
                    if self._finished() or self.failure is not None:
                        return
                    if not self.running:
                        # nothing queued, nothing running, not finished:
                        # every remaining task failed — bail out
                        return
                    self.cond.wait(self.cfg.poll_s)
                    item = self._take(wid)
                task, is_spec = item
                if task.task_id in self.results:   # speculation leftover
                    continue
                exec_idx = self.exec_counts[task.task_id]
                self.exec_counts[task.task_id] += 1
                self.running[(task.task_id, exec_idx)] = {
                    "since": time.perf_counter(), "cost": task.cost}
            self._execute(task, exec_idx, is_spec)

    def _execute(self, task: Task, exec_idx: int, is_spec: bool) -> None:
        res = loaded = None
        attempt = 0
        while True:
            try:
                if self.cfg.delay_hook is not None and attempt == 0:
                    d = float(self.cfg.delay_hook(task.task_id, exec_idx))
                    if d > 0:
                        time.sleep(d)
                if self.cfg.faults is not None:
                    self.cfg.faults.maybe_fail()
                res, loaded = self.run_task(task)
                break
            except BaseException as e:  # noqa: BLE001 — retried/reported
                attempt += 1
                with self.cond:
                    self.stats["retried"] += 1
                    give_up = attempt > self.cfg.max_retries
                    if give_up:
                        self.stats["retried"] -= 1  # last one wasn't a retry
                        self.running.pop((task.task_id, exec_idx), None)
                        # an exhausted execution is only terminal when it
                        # was the LAST path to a result. A speculative
                        # duplicate dying of its own retries while the
                        # healthy original still grinds (or already
                        # finished) is a discard, not a run failure —
                        # and symmetrically for a dead original whose
                        # speculation is still alive or queued.
                        alive = (
                            task.task_id in self.results
                            or any(tid == task.task_id
                                   for tid, _ in self.running)
                            or any(t.task_id == task.task_id
                                   for t in self.spec_queue)
                            or any(t.task_id == task.task_id
                                   for dq in self.deques for t in dq))
                        if alive:
                            self.stats["abandoned_failures"] += 1
                        elif self.failure is None:
                            self.failure = e
                            self.failed_task = task.task_id
                        self.cond.notify_all()
                        return
                time.sleep(backoff_delay(
                    attempt, base_s=self.cfg.retry_backoff_s,
                    factor=2.0, cap_s=self.cfg.retry_backoff_cap_s,
                    jitter=self.cfg.retry_jitter,
                    seed=zlib.crc32(task.task_id.encode())))
        with self.cond:
            self.running.pop((task.task_id, exec_idx), None)
            if self.core.commit(task.task_id, res):  # first result wins
                self.stats["run"] += 1
                if is_spec:
                    self.stats["speculation_wins"] += 1
            self.peak_task_bytes = max(self.peak_task_bytes, loaded or 0)
            self.cond.notify_all()

    def _check_stragglers(self) -> None:
        """Caller holds the lock. Re-enqueue any running task whose
        elapsed time exceeds the cost-normalized p95 envelope."""
        tail = not self.spec_queue and not any(self.deques)
        threshold = self.core.straggler_envelope(tail)
        if threshold is None:
            return
        now = time.perf_counter()
        for (tid, _), info in list(self.running.items()):
            if tid in self.results or tid in self.spec_issued:
                continue
            if now - info["since"] > threshold(info["cost"]):
                self.spec_issued.add(tid)
                self.spec_queue.append(self.tasks[tid])
                self.stats["speculated"] += 1
                self.cond.notify_all()

    def run(self) -> dict[str, TaskResult]:
        workers = [threading.Thread(target=self._worker_loop, args=(w,),
                                    name=f"ooc-worker-{w}", daemon=True)
                   for w in range(self.cfg.n_workers)]
        for t in workers:
            t.start()
        with self.cond:
            while not self._finished() and self.failure is None:
                if not self.running and not any(self.deques) \
                        and not self.spec_queue:
                    break   # workers bailed (shouldn't happen w/o failure)
                self.cond.wait(self.cfg.poll_s)
                self._check_stragglers()
            self.cond.notify_all()
        # deliberately NOT joined: once every task has a result the run
        # is over — a straggler that lost its speculation race may still
        # be grinding, and waiting for it would forfeit exactly the
        # wall-clock speculation recovered. Losers find their task id
        # already in ``results`` and discard themselves (daemon threads).
        if self.failure is not None:
            raise RuntimeError(
                f"task {self.failed_task} failed after "
                f"{self.cfg.max_retries} retries; completed work is "
                f"journaled in {self.ledger.path} — rerun with "
                f"resume=True") from self.failure
        if not self._finished():
            # the monitor's break path: queues drained, nothing running,
            # no recorded failure — yet tasks are missing results. A
            # partial dict here would flow into ``aggregate`` and sum to
            # a silently wrong count; fail loudly and point at the
            # ledger instead.
            missing = sorted(set(self.tasks) - set(self.results))
            raise RuntimeError(
                f"scheduler lost {len(missing)} task(s) without a "
                f"recorded failure (e.g. {missing[0]}); refusing to "
                f"aggregate a partial result — completed work is "
                f"journaled in {self.ledger.path}, rerun with "
                f"resume=True")
        return self.results


def aggregate(results: dict[str, TaskResult], n: int,
              per_node: bool) -> tuple[float, Optional[np.ndarray]]:
    """Order-independent reduction: sorted-task-id f64 sums, so the
    estimate is identical across worker counts, stealing patterns, and
    fresh-vs-resumed runs."""
    total = 0.0
    out = np.zeros(n, np.float64) if per_node else None
    for tid in sorted(results):
        res = results[tid]
        total += res.task_sum
        if out is not None and res.unit_ids is not None:
            np.add.at(out, res.unit_ids, res.unit_vals)
    return total, out


def _empty_stats(og, t0: float) -> dict:
    return {"tasks": 0, "run": 0, "stolen": 0,
            "speculated": 0, "speculation_wins": 0,
            "retried": 0, "resumed": 0, "spill": "empty",
            "csr_bytes": csr_footprint_bytes(og),
            "wall_s": time.perf_counter() - t0}


def _drive_tasks(eng, req, key, cfg: SchedulerConfig, tasks: list[Task],
                 t0: float) -> tuple[dict[str, TaskResult], dict]:
    """Spill, replay, and run one compiled ledger to completion — the
    scaffolding shared by the per-k and all-k query paths."""
    og = eng.og
    fp = eng.fingerprint
    plan_sig = plan_signature(fp, tasks)
    root = cfg.spill_dir or os.path.join(tempfile.gettempdir(),
                                         "repro-ooc")
    store = ShardStore(root=root, fingerprint=fp, plan_sig=plan_sig)
    spill = store.ensure(og, tasks)

    qsig = query_signature(fp, plan_sig, req)
    ledger = TaskLedger(os.path.join(store.dir, f"ledger-{qsig}.jsonl"),
                        qsig)
    completed: dict[str, TaskResult] = {}
    if cfg.resume:
        completed = {tid: res for tid, res in ledger.load().items()
                     if tid in {t.task_id for t in tasks}}
    if completed:
        ledger.open_append(completed)
    else:
        ledger.open_fresh()

    if cfg.executors > 0:
        # distributed pool: a coordinator hands tasks to real executor
        # subprocesses; the ledger write below IS the commit protocol
        from .coordinator import Coordinator
        pool = Coordinator(store, req, cfg, tasks, ledger, completed,
                           key_seed=(None if key is None
                                     else int(req.seed)),
                           lookup_iters=int(og.lookup_iters))
    else:
        runner = _make_runner(eng, store, req, key, cfg)
        pool = Driver(tasks, runner, cfg, ledger, completed)
    try:
        results = pool.run()
    finally:
        ledger.close()
    stats = {"tasks": len(tasks), "resumed": len(completed),
             **{k: int(v) for k, v in pool.stats.items()},
             "n_workers": cfg.n_workers,
             "commit_dups": pool.core.commit_dups,
             "ledger_errors": ledger.errors,
             "ledger_warnings": ledger.replay_warnings,
             "peak_task_bytes": pool.peak_task_bytes,
             "max_slice_bytes": spill.get("max_slice_bytes", 0),
             "csr_bytes": csr_footprint_bytes(og),
             "spill": spill["spill"],
             "spill_bytes": spill.get("spill_bytes", 0),
             "ledger": ledger.path,
             "wall_s": time.perf_counter() - t0}
    if cfg.executors > 0:
        stats.update(pool.extra_stats())
    return results, stats


def run_query(eng, entry, req, key,
              cfg: SchedulerConfig) -> tuple[float, Optional[np.ndarray],
                                             dict]:
    """Execute one counting query out-of-core. Returns
    (estimate, per_node, scheduler telemetry)."""
    t0 = time.perf_counter()
    og = eng.og
    tasks = compile_tasks(entry, og, req,
                          elem_budget=cfg.tile_elem_budget,
                          target_tasks=cfg.target_tasks,
                          max_units_per_task=cfg.max_units_per_task)
    if not tasks:
        per = np.zeros(og.n, np.float64) if req.return_per_node else None
        return 0.0, per, _empty_stats(og, t0)
    results, stats = _drive_tasks(eng, req, key, cfg, tasks, t0)
    total, per_node = aggregate(results, og.n,
                                bool(req.return_per_node))
    return total, per_node, stats


def run_profile_query(eng, req, cfg: SchedulerConfig, groups,
                      L: int) -> tuple[np.ndarray, dict]:
    """Execute one k="all" profile pass out-of-core over the
    depth-regrouped units. Returns ((L,) f64 device profile, scheduler
    telemetry). Aggregation zero-pads each task's (r−1,) profile into
    the common length, in sorted-task-id order — bit-exact against the
    in-memory backends for the same reason the scalar path is."""
    t0 = time.perf_counter()
    og = eng.og
    tasks = compile_profile_tasks(groups, og, req,
                                  elem_budget=cfg.tile_elem_budget,
                                  target_tasks=cfg.target_tasks,
                                  max_units_per_task=cfg.max_units_per_task)
    if not tasks:
        return np.zeros(L, np.float64), _empty_stats(og, t0)
    results, stats = _drive_tasks(eng, req, key=None, cfg=cfg,
                                  tasks=tasks, t0=t0)
    profile = np.zeros(L, np.float64)
    for tid in sorted(results):
        p = results[tid].profile
        if p is not None:
            profile[:p.size] += p
    return profile, stats
