"""The ``"ooc"`` engine backend: out-of-core partitioned execution.

A thin :class:`~repro.engine.backends.Backend` adapter around
:func:`repro.scheduler.driver.run_query` — compile the cached plan into
a task ledger, spill shard slices, and drive them through the
work-stealing pool. Stashes the scheduler telemetry of the last run so
``CliqueEngine.submit`` can surface it as ``report.cache["scheduler"]``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..engine.backends import Backend
from .driver import SchedulerConfig, run_query


class OocBackend(Backend):
    name = "ooc"

    def __init__(self, cfg: Optional[SchedulerConfig] = None) -> None:
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self._last_stats: Optional[dict] = None

    @property
    def n_workers(self) -> int:
        return self.cfg.n_workers

    def run(self, eng, entry, req, key) -> tuple[float,
                                                 Optional[np.ndarray]]:
        estimate, per_node, stats = run_query(eng, entry, req, key,
                                              self.cfg)
        self._last_stats = stats
        return estimate, per_node

    def pop_telemetry(self) -> Optional[dict]:
        stats, self._last_stats = self._last_stats, None
        return stats
