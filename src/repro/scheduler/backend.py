"""The ``"ooc"`` engine backend: out-of-core partitioned execution.

A thin :class:`~repro.engine.backends.Backend` adapter around
:func:`repro.scheduler.driver.run_query` — compile the cached plan into
a task ledger, spill shard slices, and drive them through the
work-stealing pool. Stashes the scheduler telemetry of the last run so
``CliqueEngine.submit`` can surface it as ``report.cache["scheduler"]``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..engine.backends import Backend
from .driver import SchedulerConfig, run_profile_query, run_query


class OocBackend(Backend):
    name = "ooc"
    supports_listing = False     # spilled slices have no emit residency

    def __init__(self, cfg: Optional[SchedulerConfig] = None) -> None:
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self._last_stats: Optional[dict] = None

    @property
    def n_workers(self) -> int:
        # distributed runs: the executor count is the worker count
        if self.cfg.executors > 0:
            return self.cfg.executors
        return self.cfg.n_workers

    def validate(self, req) -> None:
        # the same guards CountRequest.validate applies to an *explicit*
        # backend="ooc" — enforced here too so a request that merely
        # resolves to ooc (engine default) cannot slip past them
        super().validate(req)
        if req.is_adaptive:
            raise ValueError(
                "adaptive (accuracy-targeted) queries probe "
                "interactively; run them on local/pallas and save the "
                "ooc backend for the full-size exact pass")

    def run(self, eng, entry, req, key) -> tuple[float,
                                                 Optional[np.ndarray]]:
        estimate, per_node, stats = run_query(eng, entry, req, key,
                                              self.cfg)
        self._last_stats = stats
        return estimate, per_node

    def run_profile(self, eng, groups, L, req) -> np.ndarray:
        profile, stats = run_profile_query(eng, req, self.cfg, groups, L)
        self._last_stats = stats
        return profile

    def pop_telemetry(self) -> Optional[dict]:
        stats, self._last_stats = self._last_stats, None
        return stats
