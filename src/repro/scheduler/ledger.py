"""Resumable progress: a JSONL ledger of completed tasks.

One line per completed task — appended and flushed the moment the task
finishes, so a SIGKILLed driver loses at most the in-flight tasks. On
resume the ledger is replayed: completed task ids are skipped and their
recorded partial sums (and, for ``return_per_node`` queries, the
per-unit count vectors) feed straight into the final aggregation, so
nothing is recounted.

The first line is a header carrying the *query signature* — a hash of
everything answer-defining (graph fingerprint, ledger/plan signature,
k, method, sampling knobs, tile-repr choice, per-node flag). A ledger
whose header doesn't match the current query is ignored and truncated:
resuming a k=4 run into a k=5 query can never smuggle counts across.

Tolerant reader: a line that fails to parse (the torn tail of a killed
write) ends the replay — everything before it is trusted, everything
after recomputed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TaskResult:
    """What aggregation needs from one completed task."""
    task_sum: float
    elapsed_s: float
    unit_ids: Optional[np.ndarray] = None     # per-node queries only
    unit_vals: Optional[np.ndarray] = None
    profile: Optional[np.ndarray] = None      # k="all" tasks: (r−1,) f64


def query_signature(fingerprint: str, plan_sig: str, req) -> str:
    """Hash of the answer-defining request fields. Exact queries
    normalize the sampling knobs away (like ``CountRequest.query_key``)
    so an exact run can resume under a different seed; sampled queries
    keep (method, p, colors, seed) — their partial sums are
    seed-specific."""
    if req.effective_method == "exact":
        knobs = ("exact",)
    else:
        knobs = (req.effective_method, float(req.p), int(req.colors),
                 int(req.seed))
    if req.k == "all":
        # max_k changes the per-unit recursion depths, hence the answer;
        # int-k signatures stay byte-stable with prior releases
        knobs = knobs + (req.max_k,)
    payload = (fingerprint, plan_sig, req.k, req.engine,
               bool(req.return_per_node)) + knobs
    return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]


class TaskLedger:
    """Append-only completion journal for one query on one task set."""

    def __init__(self, path: str, query_sig: str) -> None:
        self.path = path
        self.query_sig = query_sig
        self._fh = None
        # journal writes that failed at the OS layer (disk full, dead
        # volume); surfaced as ``ledger_errors`` in scheduler telemetry
        self.errors = 0
        # replay lines abandoned as torn/malformed (including a torn
        # header); surfaced as ``ledger_warnings`` in telemetry
        self.replay_warnings = 0

    # -- replay ------------------------------------------------------------

    def load(self) -> dict[str, TaskResult]:
        """Replay a prior run's ledger; {} when absent, foreign (header
        mismatch), or empty."""
        try:
            with open(self.path) as f:
                lines = f.read().splitlines()
        except OSError:
            return {}
        if not lines:
            return {}
        try:
            header = json.loads(lines[0])
        except ValueError:
            header = None   # torn header of a write killed mid-line
        if not isinstance(header, dict):
            # a header torn by a crash during ``open_fresh`` may fail to
            # parse OR parse to a JSON scalar/array prefix (e.g. a bare
            # number) — both mean nothing below it is trusted. Treat it
            # exactly like a torn tail: fresh ledger, counted, never an
            # exception that kills the resume.
            self.replay_warnings += 1
            return {}
        if header.get("query_sig") != self.query_sig:
            return {}
        done: dict[str, TaskResult] = {}
        for line in lines[1:]:
            try:
                rec = json.loads(line)
                if not isinstance(rec, dict):
                    raise ValueError("non-dict record")
                res = TaskResult(task_sum=float(rec["sum"]),
                                 elapsed_s=float(rec.get("elapsed_s",
                                                         0.0)))
                tid = rec["task"]
            except (ValueError, TypeError, KeyError):
                # torn tail of a killed write; stop trusting
                self.replay_warnings += 1
                break
            if "units" in rec:
                res.unit_ids = np.asarray(rec["units"], np.int64)
                res.unit_vals = np.asarray(rec["values"], np.float64)
            if "profile" in rec:
                res.profile = np.asarray(rec["profile"], np.float64)
            done[tid] = res
        return done

    # -- writing -----------------------------------------------------------

    def open_fresh(self) -> None:
        """Start a new journal (truncates any prior one)."""
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._fh = open(self.path, "w")
        self._write({"query_sig": self.query_sig})

    def open_append(self, resumed: dict[str, TaskResult]) -> None:
        """Continue a replayed journal. Rewritten rather than appended:
        the prior file may end in a torn line, and rewriting the trusted
        prefix is cheap next to recounting it."""
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        self._fh = open(self.path, "w")
        self._write({"query_sig": self.query_sig})
        for task_id, res in resumed.items():
            self.append(task_id, res)

    def _write(self, obj: dict) -> None:
        if self._fh is None:
            return          # straggler finishing after the run closed
        try:
            self._fh.write(json.dumps(obj) + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())
        except ValueError:  # closed between the check and the write
            pass
        except OSError:
            # write/flush/fsync failure (disk full, dead volume). The
            # result is already recorded in memory — the run stays
            # correct, only resume coverage degrades (this task would be
            # recounted). Raising here would kill a worker inside the
            # completion lock and silently shrink the pool, which is
            # strictly worse; count it and drop to in-memory completion.
            self.errors += 1

    def append(self, task_id: str, res: TaskResult) -> None:
        rec = {"task": task_id, "sum": res.task_sum,
               "elapsed_s": round(res.elapsed_s, 6)}
        if res.unit_ids is not None:
            rec["units"] = [int(u) for u in res.unit_ids]
            rec["values"] = [float(v) for v in res.unit_vals]
        if res.profile is not None:
            rec["profile"] = [float(v) for v in res.profile]
        self._write(rec)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
