"""Out-of-core partitioned execution (``CountRequest(backend="ooc")``).

The scheduler runs one planned counting query as a ledger of
idempotent bucket-chunk tasks over disk-backed CSR shard slices:

- :mod:`repro.scheduler.tasks` — compile the cached plan into tasks
  carrying analytic cost (LPT seeding, straggler normalization)
- :mod:`repro.scheduler.store` — spill/mmap per-task closure slices;
  host memory per worker is O(slice), not O(graph)
- :mod:`repro.scheduler.ledger` — JSONL completion journal; a killed
  driver resumes without recounting
- :mod:`repro.scheduler.driver` — work-stealing pool with straggler
  re-execution and backoff retry
- :mod:`repro.scheduler.backend` — the engine-facing ``"ooc"`` backend
- :mod:`repro.scheduler.transport` — length-prefixed JSON frames and
  the task/result wire codecs
- :mod:`repro.scheduler.coordinator` /
  :mod:`repro.scheduler.executor` — the multi-host pool
  (``SchedulerConfig(executors=N)``): leases, heartbeats,
  ledger-as-commit-protocol, cross-host speculation

See ``docs/scheduler.md``.
"""
from .backend import OocBackend
from .coordinator import Coordinator
from .driver import CompletionCore, SchedulerConfig, run_query
from .ledger import TaskLedger, TaskResult, query_signature
from .store import ShardStore, SliceCSR, csr_footprint_bytes
from .tasks import Task, compile_tasks, lpt_assign, plan_signature

__all__ = [
    "OocBackend", "SchedulerConfig", "run_query",
    "Coordinator", "CompletionCore",
    "TaskLedger", "TaskResult", "query_signature",
    "ShardStore", "SliceCSR", "csr_footprint_bytes",
    "Task", "compile_tasks", "lpt_assign", "plan_signature",
]
