"""Compile a plan into a ledger of idempotent bucket-chunk tasks.

A *task* is the scheduler's unit of dispatch, retry, speculation, and
checkpointing: a contiguous chunk of one capacity class's work units
(or of one §6 split class), small enough that tens of them exist per
query — enough granularity for work stealing and straggler
re-execution — and large enough that per-task overhead (mmap + device
upload of its shard slice) stays amortized.

Tasks carry their analytic cost from :func:`repro.core.plan.unit_cost`
(the paper's |Γ⁺(u)|^{k−1} local-work bound; D^{k−2} per split unit),
which is what LPT-seeds the worker deques and cost-normalizes the
straggler detector. Task ids are pure functions of the unit arrays, so
a resumed run recomputes the identical ledger and can trust the
completed-task journal.

Chunking is deliberately *independent of the worker count*: a run
killed at W=2 workers can resume at W=8 and every completed task id
still matches.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

from ..core.count import pick_tile_repr
from ..core.csr import OrientedGraph
from ..core.plan import unit_cost


@dataclasses.dataclass(frozen=True)
class Task:
    """One idempotent bucket-chunk work unit.

    ``units`` are global node ids (the scheduler's slices keep global
    indexing, see :mod:`repro.scheduler.store`); ``pivots`` are local
    row indices within each unit's adjacency for §6 split tasks.
    """
    task_id: str
    kind: str                       # "bucket" | "split" | "profile"
    capacity: int
    tile_repr: str                  # "dense" | "bits"
    units: np.ndarray               # (U,) int32 global node ids
    pivots: Optional[np.ndarray]    # (U,) int32, split tasks only
    cost: float                     # Σ analytic unit cost (LPT + straggler)
    r: int = 0                      # profile tasks: recursion depth rmax

    @property
    def n_units(self) -> int:
        return int(self.units.size)


def _unit_hash(units: np.ndarray,
               pivots: Optional[np.ndarray] = None) -> str:
    h = hashlib.sha256(np.ascontiguousarray(units, np.int64).tobytes())
    if pivots is not None:
        h.update(np.ascontiguousarray(pivots, np.int64).tobytes())
    return h.hexdigest()[:10]


def _chunk_by_cost(order_costs: np.ndarray, target_cost: float,
                   max_units: int) -> list[slice]:
    """Greedy contiguous chunking of a cost-descending unit list: close
    a chunk once its cumulative cost reaches the target or it holds
    ``max_units`` units. Heaviest units therefore land in the smallest
    chunks — exactly the ones speculation may need to re-run cheaply."""
    chunks = []
    start, acc = 0, 0.0
    for i, c in enumerate(order_costs):
        acc += float(c)
        if acc >= target_cost or (i - start + 1) >= max_units:
            chunks.append(slice(start, i + 1))
            start, acc = i + 1, 0.0
    if start < len(order_costs):
        chunks.append(slice(start, len(order_costs)))
    return chunks


def compile_tasks(entry, og: OrientedGraph, req, *,
                  elem_budget: int, target_tasks: int = 32,
                  max_units_per_task: int = 4096) -> list[Task]:
    """Turn a cached :class:`~repro.engine.PlanEntry` into the task
    ledger. Deterministic in (plan, request knobs, chunking config) —
    the resume contract. The depth comes from the *request*: plans are
    k-agnostic (built once per session at the k=3 reference), so
    ``entry.plan.k`` is not this query's k."""
    k = req.k
    r = k - 1
    split_costs = []
    for sp in entry.splits:
        real = sp.nodes[:sp.n_real]
        split_costs.append(og.out_deg[np.maximum(real, 0)]
                           .astype(np.float64) ** max(k - 2, 1))
    total = entry.plan.total_cost + sum(float(c.sum())
                                        for c in split_costs)
    target = max(total / max(target_tasks, 1), 1.0)

    tasks: list[Task] = []
    for b in entry.plan.buckets:
        real = b.nodes[:b.n_real]
        if real.size == 0:
            continue
        costs = unit_cost(og.out_deg[real], k)
        # build_plan orders units cost-descending already; keep that
        # order so chunk boundaries are stable across runs
        repr_ = pick_tile_repr(r=r, capacity=b.capacity,
                               method=req.method, choice=req.engine,
                               elem_budget=elem_budget)
        for i, sl in enumerate(_chunk_by_cost(costs, target,
                                              max_units_per_task)):
            u = np.ascontiguousarray(real[sl], np.int32)
            tasks.append(Task(
                task_id=f"b{b.capacity}-{i:04d}-{_unit_hash(u)}",
                kind="bucket", capacity=b.capacity, tile_repr=repr_,
                units=u, pivots=None, cost=float(costs[sl].sum())))
    for sp, costs in zip(entry.splits, split_costs):
        real = sp.nodes[:sp.n_real]
        pv = sp.pivots[:sp.n_real]
        if real.size == 0:
            continue
        repr_ = pick_tile_repr(r=r, capacity=sp.capacity,
                               method=req.method, choice=req.engine,
                               elem_budget=elem_budget)
        for i, sl in enumerate(_chunk_by_cost(costs, target,
                                              max_units_per_task)):
            u = np.ascontiguousarray(real[sl], np.int32)
            p = np.ascontiguousarray(pv[sl], np.int32)
            tasks.append(Task(
                task_id=f"s{sp.capacity}-{i:04d}-{_unit_hash(u, p)}",
                kind="split", capacity=sp.capacity, tile_repr=repr_,
                units=u, pivots=p, cost=float(costs[sl].sum())))
    return tasks


def compile_profile_tasks(groups, og: OrientedGraph, req, *,
                          elem_budget: int, target_tasks: int = 32,
                          max_units_per_task: int = 4096) -> list[Task]:
    """Task ledger for one all-k profile pass: one chunked task stream
    per :class:`~repro.core.plan.DepthGroup` (same-capacity units
    sharing a certificate-clamped recursion depth). Task ids carry the
    depth — two ledgers differing only in ``max_k`` never collide."""
    group_costs = []
    for g in groups:
        real = g.nodes[g.nodes >= 0]
        group_costs.append(unit_cost(og.out_deg[real], g.rmax + 1))
    total = sum(float(c.sum()) for c in group_costs)
    target = max(total / max(target_tasks, 1), 1.0)
    tasks: list[Task] = []
    for g, costs in zip(groups, group_costs):
        real = g.nodes[g.nodes >= 0]
        if real.size == 0:
            continue
        repr_ = pick_tile_repr(r=g.rmax, capacity=g.capacity,
                               choice=req.engine, elem_budget=elem_budget)
        for i, sl in enumerate(_chunk_by_cost(costs, target,
                                              max_units_per_task)):
            u = np.ascontiguousarray(real[sl], np.int32)
            tasks.append(Task(
                task_id=f"p{g.capacity}-r{g.rmax}-{i:04d}-{_unit_hash(u)}",
                kind="profile", capacity=g.capacity, tile_repr=repr_,
                units=u, pivots=None, cost=float(costs[sl].sum()),
                r=g.rmax))
    return tasks


def plan_signature(fingerprint: str, tasks: list[Task]) -> str:
    """Content hash of the compiled ledger — the shard-manifest key.
    Any change to the plan, the chunking, or the graph produces a new
    signature and therefore a fresh spill directory."""
    h = hashlib.sha256(fingerprint.encode())
    for t in tasks:
        h.update(t.task_id.encode())
    return h.hexdigest()[:16]


def lpt_assign(tasks: list[Task], n_workers: int) -> list[list[Task]]:
    """Seed the worker deques: heaviest task to the least-loaded worker
    (the plan partitioner's LPT balancing, applied at task granularity).
    Work stealing corrects whatever the analytic model gets wrong at
    runtime; LPT just makes stealing rare."""
    order = sorted(tasks, key=lambda t: (-t.cost, t.task_id))
    deques: list[list[Task]] = [[] for _ in range(n_workers)]
    loads = np.zeros(n_workers)
    for t in order:
        w = int(np.argmin(loads))
        deques[w].append(t)
        loads[w] += t.cost
    return deques
