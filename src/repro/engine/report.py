"""Request/report types for the session engine.

One request type and one report type cover every backend — the engine's
answer to the seed API's fork into ``CountResult`` (single host) vs
``DistributedResult`` (shard_map) with incompatible fields.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import mrc as mrc_mod

METHODS = ("exact", "edge", "color", "color_smooth", "ni++")
BACKENDS = ("local", "pallas", "shard_map")


@dataclasses.dataclass(frozen=True)
class CountRequest:
    """One query against a :class:`CliqueEngine` session.

    ``backend=None`` uses the engine's default; any request may override
    it, so one session can serve e.g. exact shard_map sweeps and quick
    local sampled probes side by side.
    """
    k: int
    method: str = "exact"
    p: float = 0.1                       # edge-sampling rate
    colors: int = 10                     # SIC_k color count c
    seed: int = 0
    backend: Optional[str] = None        # None → engine default
    return_per_node: bool = False        # local/pallas backends only
    split_threshold: Optional[int] = None  # §6 split round for |Γ⁺|>thr
    max_capacity: Optional[int] = None   # clamp the planner's classes

    def validate(self) -> None:
        if self.k < 3:
            raise ValueError(f"k must be ≥ 3, got {self.k}")
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}")
        if self.method == "ni++" and self.k != 3:
            raise ValueError("NI++ is a triangle-counting baseline (k=3)")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def effective_method(self) -> str:
        """NI++ shares the exact tile path (it differs only in round
        accounting, reported through the MRC stats)."""
        return "exact" if self.method == "ni++" else self.method

    def plan_key(self) -> tuple:
        return (self.k, self.max_capacity, self.split_threshold)

    def query_key(self, default_backend: str = "local") -> tuple:
        """Identity of the *answer* this request produces — the coalescing
        key used by ``repro.serving.cliques``. Two requests with equal
        keys are satisfiable by one execution. Exact counting ignores the
        sampling knobs (p/colors/seed change nothing), so exact queries
        coalesce across users who picked different seeds; sampled methods
        keep all three, since the estimate depends on them.
        """
        backend = self.backend or default_backend
        if self.effective_method == "exact":
            p, colors, seed = 0.0, 0, 0
        else:
            p, colors, seed = self.p, self.colors, self.seed
        return (self.k, self.method, p, colors, seed, backend,
                self.return_per_node, self.split_threshold,
                self.max_capacity)


@dataclasses.dataclass
class CountReport:
    """Unified per-query result: estimate + MRC accounting + balance +
    timings + cache telemetry, identical across backends."""
    k: int
    method: str
    backend: str
    estimate: float
    per_node: Optional[np.ndarray]   # local/pallas + return_per_node only
    mrc: "mrc_mod.MRCStats"
    plan_summary: dict
    balance: dict                    # LPT straggler profile over n_workers
    per_round_bytes: dict            # modeled communication volumes
    timings: dict                    # plan_s / count_s / total_s
    cache: dict                      # {"plan": hit|miss, "exec_hits": …}
    n_workers: int
    params: dict

    @property
    def count(self) -> int:
        return int(round(self.estimate))
