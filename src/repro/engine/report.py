"""Request/report types for the session engine.

One request type and one report type cover every backend — the engine's
answer to the seed API's fork into ``CountResult`` (single host) vs
``DistributedResult`` (shard_map) with incompatible fields.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import numpy as np

from ..core import mrc as mrc_mod
from ..estimator.methods import DEPRECATED_STRINGS, from_string

METHODS = ("exact", "edge", "color", "color_smooth", "ni++", "wedge",
           "sparsify", "auto")
BACKENDS = ("local", "pallas", "shard_map", "ooc")
# listing streams tiles through in-memory emit kernels; the ooc backend
# trades that residency away for bounded memory, so it only counts
LISTING_BACKENDS = ("local", "pallas", "shard_map")
# methods that may carry a rel_error target (the portfolio controller)
ADAPTIVE_METHODS = ("auto", "edge", "color", "wedge", "sparsify")
TILE_ENGINES = ("auto", "dense", "bitset")     # tile representation choice
MODES = ("count", "list")                      # scalar answer vs enumeration


@dataclasses.dataclass(frozen=True)
class CountRequest:
    """One query against a :class:`CliqueEngine` session.

    ``backend=None`` uses the engine's default; any request may override
    it, so one session can serve e.g. exact shard_map sweeps and quick
    local sampled probes side by side.

    ``engine`` picks the tile *representation* (orthogonal to the
    backend): ``"dense"`` is the f32 adjacency + matmul-pivot path,
    ``"bitset"`` the packed uint32 + AND/popcount path (32× smaller
    tiles, bit-exact counts), and ``"auto"`` (default) lets a per-bucket
    bytes-based cost model choose — see
    :func:`repro.core.count.pick_tile_repr` and ``docs/kernels.md``.

    Listing queries: ``mode="list"`` asks for the cliques themselves
    instead of a count — the exact tile pipeline with the emit kernels
    (:mod:`repro.listing`). ``chunk`` bounds the per-chunk buffer (and
    the stream's host memory), ``limit`` early-stops after that many
    cliques, and ``predicate`` (a vectorized host callable
    ``(n, k) int rows → (n,) bool``) filters each chunk before it
    counts toward the limit. Listing is exact-method only; consume it
    via ``CliqueEngine.stream`` (bounded memory) or ``submit``
    (materialized ``report.cliques``).

    Methods: ``method`` accepts a typed spec from
    :mod:`repro.estimator.methods` — ``Exact()``, ``EdgeSample(p=...)``,
    ``ColorCoding(colors=...)``, ``WedgeSample(samples=...)``,
    ``Sparsify(q=...)``, ``Auto(rel_error=..., confidence=...)`` — or a
    method string. A spec is normalized into the legacy knob fields at
    construction (knob slot-reuse: wedge's ``samples`` rides ``colors``,
    sparsify's ``q`` rides ``p``), so a spec and the legacy spelling it
    replaces produce the *same* ``query_key`` and hit the same persisted
    store entries. Legacy strings other than ``"exact"`` and the new
    canonical ``"wedge"``/``"sparsify"`` emit a ``DeprecationWarning``.

    Accuracy-targeted queries: ``method="auto"`` (or any method in
    ``ADAPTIVE_METHODS`` with ``rel_error`` set) hands the query to the
    portfolio controller in :mod:`repro.estimator`, which races the
    method portfolio and escalates the winner until the confidence
    interval half-width is within ``rel_error``·estimate at ``confidence``
    — or falls through to exact counting when the work model says exact
    is cheaper. For these requests ``p``/``colors``/``seed`` stop being
    answer-defining (the controller owns the operating point).

    All-k profiles: ``k="all"`` asks for the full clique-number profile
    q_3..q_kmax from one tile pass (the Pivoter-carried recursion —
    ``report.profile[j]`` is q_{j+3}). Exact counting only: no listing,
    no adaptive methods, no sampling, no per-node attribution, no §6
    split round. ``max_k`` caps the discovered profile (and the device
    recursion depth) — required when the certificate pass finds a clique
    bound deeper than the auto limit.
    """
    k: "int | str"                       # k ≥ 3, or "all" for the profile
    method: str = "exact"
    p: float = 0.1                       # edge-sampling rate
    colors: int = 10                     # SIC_k color count c
    seed: int = 0
    backend: Optional[str] = None        # None → engine default
    engine: str = "auto"                 # tile repr: auto | dense | bitset
    return_per_node: bool = False        # local/pallas backends only
    split_threshold: Optional[int] = None  # §6 split round for |Γ⁺|>thr
    max_capacity: Optional[int] = None   # clamp the planner's classes
    rel_error: Optional[float] = None    # accuracy target (adaptive only)
    confidence: float = 0.99             # CI level for rel_error
    # listing (mode="list") — streaming enumeration; see repro.listing
    mode: str = "count"                  # "count" | "list"
    limit: Optional[int] = None          # stop after this many cliques
    chunk: int = 1 << 16                 # listing buffer rows per chunk
    predicate: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # all-k (k="all") only: cap the profile at q_max_k (and the device
    # recursion depth at max_k − 1)
    max_k: Optional[int] = None

    def __post_init__(self) -> None:
        # typed MethodSpec normalization: a spec collapses into its
        # canonical method string + the knob fields it pins, so every
        # downstream consumer (dispatch, traced operands, query_key)
        # sees exactly what the legacy spelling produced. Duck-typed on
        # request_kwargs() rather than isinstance to keep this module
        # importable without the estimator package's class objects.
        m = self.method
        if not isinstance(m, str):
            object.__setattr__(self, "method", m.method)
            for field, value in m.request_kwargs().items():
                # a None knob (e.g. Auto's rel_error default) pins
                # nothing — it must not clobber an explicit kwarg
                if value is not None:
                    object.__setattr__(self, field, value)
        elif m in DEPRECATED_STRINGS:
            warnings.warn(
                f"method={m!r} as a string is deprecated; pass the typed "
                f"spec repro.estimator.{type(from_string(m)).__name__}"
                f"(...) instead (identical query_key — persisted results "
                f"still hit)", DeprecationWarning, stacklevel=3)

    def validate(self) -> None:
        if self.k == "all":
            if self.mode == "list":
                raise ValueError(
                    'k="all" returns the clique-number profile; listing '
                    "enumerates one fixed size — pick a concrete k")
            if self.is_adaptive or self.rel_error is not None:
                raise ValueError(
                    'k="all" is exact-only; adaptive (accuracy-targeted) '
                    "methods need a single target q_k")
            if self.effective_method != "exact":
                raise ValueError(
                    'k="all" is exact-only: one sampled pass cannot '
                    "rescale every profile column at once "
                    f"(got method={self.method!r})")
            if self.return_per_node:
                raise ValueError(
                    'per-node attribution of k="all" is a (n, kmax) '
                    "matrix; not supported — query a concrete k")
            if self.split_threshold is not None:
                raise ValueError(
                    "the §6 split round runs units at one fixed depth; "
                    'not supported with k="all" — drop split_threshold')
            if self.max_k is not None and (
                    not isinstance(self.max_k, int) or self.max_k < 3):
                raise ValueError(f"max_k must be an int ≥ 3, "
                                 f"got {self.max_k!r}")
        elif not isinstance(self.k, int) or isinstance(self.k, bool):
            raise ValueError(f'k must be an int ≥ 3 or "all", '
                             f"got {self.k!r}")
        elif self.k < 3:
            raise ValueError(f"k must be ≥ 3, got {self.k}")
        elif self.max_k is not None:
            raise ValueError('max_k only applies to k="all" requests')
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}")
        if self.method == "ni++" and self.k != 3:
            raise ValueError("NI++ is a triangle-counting baseline (k=3)")
        if self.method == "wedge":
            if self.colors < 1:
                # slot-reuse: colors carries the per-unit draw count
                raise ValueError(f"wedge sampling needs ≥ 1 draw per "
                                 f"unit, got samples={self.colors}")
            if self.split_threshold is not None:
                raise ValueError(
                    "the §6 split round has no wedge sampling path (its "
                    "units would be counted exactly, silently mixing "
                    "estimators) — drop split_threshold for wedge")
        if self.method == "sparsify" and not 0.0 < self.p <= 1.0:
            # slot-reuse: p carries the edge keep-rate q
            raise ValueError(f"sparsify keeps each edge with probability "
                             f"q ∈ (0, 1], got q={self.p}")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.engine not in TILE_ENGINES:
            raise ValueError(f"unknown tile engine {self.engine!r}; "
                             f"one of {TILE_ENGINES}")
        if not 0.0 < self.confidence < 1.0:
            raise ValueError(f"confidence must be in (0, 1), "
                             f"got {self.confidence}")
        if self.rel_error is not None:
            if self.rel_error <= 0.0:
                raise ValueError(f"rel_error must be > 0, "
                                 f"got {self.rel_error}")
            if self.method not in ADAPTIVE_METHODS:
                raise ValueError(
                    f"rel_error targets need an adaptive method "
                    f"{ADAPTIVE_METHODS}, got {self.method!r}")
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; one of {MODES}")
        if self.backend == "ooc":
            if self.mode == "list":
                raise ValueError(
                    "listing needs the in-memory emit path; the ooc "
                    f"backend only counts (backends: {LISTING_BACKENDS})")
            if self.is_adaptive:
                raise ValueError(
                    "adaptive (accuracy-targeted) queries probe "
                    "interactively; run them on local/pallas and save "
                    "the ooc backend for the full-size exact pass")
        if self.mode == "list":
            if self.method != "exact":
                raise ValueError(
                    "listing is an exact-path feature: a sampled tile has "
                    "no witnesses to emit for the cliques it skipped "
                    f"(got method={self.method!r})")
            if self.rel_error is not None:
                raise ValueError("rel_error targets are a counting "
                                 "(mode='count') feature")
            if self.return_per_node:
                raise ValueError("per-node attribution of a listing is "
                                 "the listing itself; drop "
                                 "return_per_node")
            if self.split_threshold is not None:
                raise ValueError(
                    "the §6 split round re-partitions one unit across "
                    "pivot lanes; its emission path is not implemented "
                    "(ROADMAP) — drop split_threshold for mode='list'")
            if self.chunk < 1:
                raise ValueError(f"chunk must be ≥ 1, got {self.chunk}")
            if self.limit is not None and self.limit < 1:
                raise ValueError(f"limit must be ≥ 1, got {self.limit}")
        elif self.limit is not None or self.predicate is not None:
            raise ValueError("limit/predicate are listing knobs; set "
                             "mode='list'")
        if self.is_adaptive and self.split_threshold is not None:
            # the estimator's density certificates (and hence the CI's
            # certified range term) only cover plan buckets; §6 split
            # units would be sampled but never certified, understating
            # the error bar — reject rather than lie
            raise ValueError("adaptive (accuracy-targeted) requests "
                             "manage their own work partition; "
                             "split_threshold is not supported")

    @property
    def effective_method(self) -> str:
        """NI++ shares the exact tile path (it differs only in round
        accounting, reported through the MRC stats)."""
        return "exact" if self.method == "ni++" else self.method

    @property
    def is_adaptive(self) -> bool:
        """True when the query is accuracy-targeted and must be driven by
        the :mod:`repro.estimator` controller rather than a single
        backend execution."""
        return self.method == "auto" or (
            self.rel_error is not None
            and self.method in ("edge", "color", "wedge", "sparsify"))

    @property
    def spec(self):
        """The typed :class:`~repro.estimator.methods.MethodSpec` this
        request's (method, knobs) resolve to. Derived, never stored —
        ``dataclasses.replace`` on knob fields can't leave a stale spec
        behind."""
        return from_string(self.method, p=self.p, colors=self.colors,
                           rel_error=self.rel_error,
                           confidence=self.confidence)

    def plan_key(self) -> tuple:
        # k-agnostic: one plan (built at the k=3 eligibility reference)
        # serves every k of a session, including k="all"
        return (self.max_capacity, self.split_threshold)

    @property
    def is_persistable(self) -> bool:
        """True when the answer's identity survives a process restart.

        Listing predicates coalesce by *callable identity*
        (``id(self.predicate)`` inside :meth:`query_key`) — an address
        that means nothing in the next process, so no store could ever
        match a persisted entry back to the "same" predicate. Every
        other request is content-keyed end to end and safe to persist
        in :class:`repro.serving.store.ResultStore`.
        """
        return self.predicate is None

    def query_key(self, default_backend: str = "local") -> tuple:
        """Identity of the *answer* this request produces — the coalescing
        key used by ``repro.serving.cliques``. Two requests with equal
        keys are satisfiable by one execution. Exact counting ignores the
        sampling knobs (p/colors/seed change nothing), so exact queries
        coalesce across users who picked different seeds; sampled methods
        keep all three, since the estimate depends on them. Adaptive
        (accuracy-targeted) queries coalesce on the accuracy target
        instead: two users asking for "q_k within 5% at 99%" are served
        by one controller run regardless of their seeds or the sampling
        starting points the controller will escalate past anyway.

        Stability contract: for persistable requests (see
        :attr:`is_persistable`) the key is also the *durable* content
        address of :class:`repro.serving.store.ResultStore` — it is
        hashed via ``repr()`` and compared across process restarts, so
        it must contain only process-independent primitives (ints,
        floats, strings, bools, None, nested tuples thereof; the one
        exception, ``id(predicate)``, is exactly what
        ``is_persistable`` excludes). Reordering or widening this tuple
        silently invalidates every persisted store entry — acceptable
        (the store recomputes misses) but never free, so change the
        layout deliberately, not incidentally.
        """
        backend = self.backend or default_backend
        if self.is_adaptive:
            p, colors, seed = 0.0, 0, 0
            target = (self.rel_error, self.confidence)
        elif self.effective_method == "exact":
            p, colors, seed = 0.0, 0, 0
            target = None
        else:
            p, colors, seed = self.p, self.colors, self.seed
            target = None
            # slot-reuse normalization: every legacy or typed spelling of
            # the same answer maps to one durable key. Wedge never reads
            # p (its kernel has no pair mask) and sparsify never reads
            # colors, so pin the dead slot to its no-op value.
            if self.method == "wedge":
                p = 1.0
            elif self.method == "sparsify":
                colors = 1
        # listing: the answer is the clique set up to (limit, predicate).
        # chunk is pure batching (same cliques at any chunk) and stays
        # out; predicates coalesce by identity — the same callable object
        # filters to the same rows, distinct objects never coalesce.
        listing = (None if self.mode == "count"
                   else ("list", self.limit,
                         None if self.predicate is None
                         else id(self.predicate)))
        return (self.k, self.method, p, colors, seed, backend,
                self.engine, self.return_per_node, self.split_threshold,
                self.max_capacity, target, listing, self.max_k)


@dataclasses.dataclass
class CountReport:
    """Unified per-query result: estimate + MRC accounting + balance +
    timings + cache telemetry, identical across backends."""
    k: "int | str"
    method: str
    backend: str
    estimate: float
    per_node: Optional[np.ndarray]   # local/pallas + return_per_node only
    mrc: "mrc_mod.MRCStats"
    plan_summary: dict
    balance: dict                    # LPT straggler profile over n_workers
    per_round_bytes: dict            # modeled communication volumes
    timings: dict                    # plan_s / count_s / total_s
    cache: dict                      # {"plan": hit|miss, "exec_hits": …}
    n_workers: int
    params: dict
    # adaptive (accuracy-targeted) queries only; None/0 otherwise
    ci_low: Optional[float] = None
    ci_high: Optional[float] = None
    achieved_rel_error: Optional[float] = None
    escalations: int = 0
    estimator: Optional[dict] = None  # controller telemetry (see docs)
    # listing (mode="list") queries only; estimate is then the number of
    # cliques listed (post predicate/limit). For unbounded streams use
    # CliqueEngine.stream — a materialized report is O(#cliques) host
    # memory by construction.
    cliques: Optional[np.ndarray] = None   # (N, k) int32 global node ids
    listing: Optional[dict] = None         # stream telemetry (see docs)
    # all-k (k="all") queries only: profile[j] = q_{j+3}, trimmed at the
    # clique number (or max_k); estimate is then sum(profile)
    profile: Optional[np.ndarray] = None   # (kmax−2,) int64

    @property
    def count(self) -> int:
        return int(round(self.estimate))


# -- JSON round-trip ---------------------------------------------------------
#
# The persistent result store (repro.serving.store) saves every report
# as JSON. Python's json module prints floats with repr (shortest
# round-tripping form), so float64 payloads — estimate, per_node, the
# CI fields — survive save→load bit-exactly; int payloads (profile,
# cliques) are exact by construction. Tuples inside telemetry dicts
# (plan_summary buckets, estimator knobs) normalize to lists: telemetry
# is for reading, not re-keying, so list-vs-tuple identity is not part
# of the round-trip contract. numpy scalars are converted to their
# Python equivalents on the way out.

REPORT_SCHEMA = 1


def _jsonable(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def report_to_json(report: CountReport) -> dict:
    """Serialize a :class:`CountReport` to a JSON-able dict.

    ``report_from_json(report_to_json(r))`` reconstructs every
    answer-bearing field bit-exactly: ``estimate``/``count``,
    ``per_node`` (float64), ``profile`` (int64), ``cliques`` (int32,
    shape preserved), and the CI fields. ``mrc`` round-trips as a flat
    scalar dataclass.
    """
    cliques = report.cliques
    return {
        "schema": REPORT_SCHEMA,
        "k": report.k,
        "method": report.method,
        "backend": report.backend,
        "estimate": float(report.estimate),
        "per_node": (None if report.per_node is None
                     else [float(v) for v in report.per_node]),
        "mrc": _jsonable(dataclasses.asdict(report.mrc)),
        "plan_summary": _jsonable(report.plan_summary),
        "balance": _jsonable(report.balance),
        "per_round_bytes": _jsonable(report.per_round_bytes),
        "timings": _jsonable(report.timings),
        "cache": _jsonable(report.cache),
        "n_workers": int(report.n_workers),
        "params": _jsonable(report.params),
        "ci_low": None if report.ci_low is None else float(report.ci_low),
        "ci_high": (None if report.ci_high is None
                    else float(report.ci_high)),
        "achieved_rel_error": (None if report.achieved_rel_error is None
                               else float(report.achieved_rel_error)),
        "escalations": int(report.escalations),
        "estimator": _jsonable(report.estimator),
        "cliques": (None if cliques is None
                    else {"shape": [int(s) for s in cliques.shape],
                          "rows": _jsonable(cliques)}),
        "listing": _jsonable(report.listing),
        "profile": (None if report.profile is None
                    else [int(v) for v in report.profile]),
    }


def report_from_json(obj: dict) -> CountReport:
    """Inverse of :func:`report_to_json`. Raises ``KeyError`` /
    ``TypeError`` / ``ValueError`` on malformed input — callers that
    must tolerate corruption (the result store's disk reads) catch and
    treat it as a miss, mirroring the task ledger's torn-tail
    discipline."""
    schema = obj["schema"]
    if schema != REPORT_SCHEMA:
        raise ValueError(f"unknown report schema {schema!r}")
    cliques = obj["cliques"]
    if cliques is not None:
        cliques = np.asarray(cliques["rows"], np.int32).reshape(
            cliques["shape"])
    return CountReport(
        k=obj["k"],
        method=obj["method"],
        backend=obj["backend"],
        estimate=float(obj["estimate"]),
        per_node=(None if obj["per_node"] is None
                  else np.asarray(obj["per_node"], np.float64)),
        mrc=mrc_mod.MRCStats(**obj["mrc"]),
        plan_summary=obj["plan_summary"],
        balance=obj["balance"],
        per_round_bytes=obj["per_round_bytes"],
        timings=obj["timings"],
        cache=obj["cache"],
        n_workers=int(obj["n_workers"]),
        params=obj["params"],
        ci_low=obj["ci_low"],
        ci_high=obj["ci_high"],
        achieved_rel_error=obj["achieved_rel_error"],
        escalations=int(obj["escalations"]),
        estimator=obj["estimator"],
        cliques=cliques,
        listing=obj["listing"],
        profile=(None if obj["profile"] is None
                 else np.asarray(obj["profile"], np.int64)),
    )
