"""Engine backends: one shared tile path, three execution strategies.

  "local"     — jnp tile path on the default device
  "pallas"    — same tile path with the Pallas MXU kernel for round 3
  "shard_map" — workers-axis mesh; per-capacity bucket shards + psum

All three consume the same plan, the same device CSR, and the same
sampling/count math from ``repro.core.count`` — the collapse of the
seed's duplicated ``_count_tile`` vs ``_apply_sampling``/
``_worker_bucket_sum`` forks. Orthogonal to the backend, every bucket
picks a tile *representation* (dense f32 vs packed uint32 bitset) via
``repro.core.count.pick_tile_repr`` — forced by the request's
``engine`` knob or chosen per (r, capacity) by the bytes-based cost
model (see ``docs/kernels.md``).

The engine's ExecutableCache keys by ``(kind, capacity, r, method, …)``.
For the shard_map backend it caches the actual ``jit(shard_map(...))``
objects the seed rebuilt (and so recompiled) on every distributed call
— that is where the cache saves real compilation. For the local/pallas
backends the tile functions are jitted at module scope in
``repro.core.count`` with jax's process-wide compile cache, so even a
throwaway engine skips recompiles there; the engine-level entries are
cheap partial bindings whose hit/miss counts serve as per-session
telemetry, not as the thing preventing recompilation.
"""
from __future__ import annotations

import abc
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map, shard_map_unchecked
from ..core.count import (_bits_profile_tile, _bits_split_tile, _bits_tile,
                          _count_tile, _pick_tile_b, _profile_tile,
                          _split_batches, _split_tile, _tile_batches,
                          bits_profile_tile_values, bits_split_tile_values,
                          bits_tile_values, pick_tile_repr,
                          profile_tile_values, split_tile_values,
                          tile_batch_repr, tile_values)


class ExecutableCache:
    """Session-lifetime cache of compiled callables with hit telemetry."""

    def __init__(self) -> None:
        self._fns: dict[tuple, Callable] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = build()
        else:
            self.hits += 1
        return fn

    def snapshot(self) -> tuple[int, int]:
        return self.hits, self.misses

    def __len__(self) -> int:
        return len(self._fns)


class Backend(abc.ABC):
    """Executes one planned query against the engine's device CSR."""

    name: str
    # the streaming emit path needs in-memory tile residency; backends
    # that trade it away (ooc) override this so listing is rejected at
    # the *resolved* backend, not just on an explicit request knob
    supports_listing = True

    @property
    @abc.abstractmethod
    def n_workers(self) -> int:
        ...

    @abc.abstractmethod
    def run(self, eng, entry, req, key) -> tuple[float, Optional[np.ndarray]]:
        """Returns (estimate, per_node or None)."""

    def run_profile(self, eng, groups, L: int, req) -> np.ndarray:
        """All-k: execute the depth-regrouped profile tiles and return
        the (L,) f64 device half of the q_3.. profile (entry j is the
        device units' contribution to q_{j+3})."""
        raise NotImplementedError(
            f"backend {self.name!r} does not implement k='all'")

    def validate(self, req) -> None:
        """Backend-specific request validation, called by the engine
        after the default backend is resolved (a request with
        ``backend=None`` must hit the same guards an explicit one
        does)."""
        if not self.supports_listing and req.mode == "list":
            raise ValueError(
                "listing needs the in-memory emit path; the "
                f"{self.name} backend only counts")

    def pop_telemetry(self) -> Optional[dict]:
        """Backend-specific telemetry of the last ``run`` (consumed by
        ``CliqueEngine.submit`` into ``report.cache``), or None."""
        return None


def tile_executable(eng, kind: str, tile_repr: str, capacity: int, r: int,
                    method: str):
    """Session-cached per-node tile executable for one (representation,
    capacity, r, method) combination — shared by the local backend and
    the adaptive estimator so both hit the same cache entries."""
    fn = _bits_tile if tile_repr == "bits" else _count_tile
    return eng.executables.get(
        ("tile", kind, tile_repr, capacity, r, method),
        lambda: functools.partial(
            fn, capacity=capacity, n_iters=eng.og.lookup_iters, r=r,
            method=method, engine=kind))


def split_executable(eng, kind: str, tile_repr: str, capacity: int, r: int,
                     method: str):
    """Same, for the §6 split-unit tile path."""
    fn = _bits_split_tile if tile_repr == "bits" else _split_tile
    return eng.executables.get(
        ("split", kind, tile_repr, capacity, r, method),
        lambda: functools.partial(
            fn, capacity=capacity, n_iters=eng.og.lookup_iters, r=r,
            method=method, engine=kind))


def profile_executable(eng, kind: str, tile_repr: str, capacity: int,
                       rmax: int):
    """Same, for the all-k profile tile path (exact-only, so no method/
    sampling in the key — one executable per (capacity, repr, depth))."""
    fn = _bits_profile_tile if tile_repr == "bits" else _profile_tile
    return eng.executables.get(
        ("ptile", kind, tile_repr, capacity, rmax),
        lambda: functools.partial(
            fn, capacity=capacity, n_iters=eng.og.lookup_iters, r=rmax,
            engine=kind))


# --------------------------------------------------------------------------
# local (single-device) backend: jnp or pallas round-3 kernel
# --------------------------------------------------------------------------

class LocalBackend(Backend):
    def __init__(self, kind: str = "jnp",
                 tile_elem_budget: int = 1 << 23) -> None:
        assert kind in ("jnp", "pallas")
        self.kind = kind
        self.name = "pallas" if kind == "pallas" else "local"
        self.budget = tile_elem_budget

    @property
    def n_workers(self) -> int:
        return 1

    def run(self, eng, entry, req, key):
        r = req.k - 1
        method = req.effective_method
        p, c = float(req.p), int(req.colors)
        total = 0.0
        per_node = (np.zeros(eng.og.n, np.float64)
                    if req.return_per_node else None)

        def accumulate(vals, ids):
            nonlocal total
            vals = np.asarray(jax.block_until_ready(vals), np.float64)
            total += float(vals.sum())
            if per_node is not None:
                sel = ids >= 0
                np.add.at(per_node, ids[sel], vals[sel])

        for b in entry.plan.buckets:
            repr_ = pick_tile_repr(r=r, capacity=b.capacity,
                                   method=req.method, choice=req.engine,
                                   elem_budget=self.budget)
            fn = tile_executable(eng, self.kind, repr_, b.capacity, r,
                                 method)
            for tile in _tile_batches(b.nodes, b.capacity, self.budget,
                                      tile_batch_repr(repr_, method)):
                accumulate(fn(eng.csr, jnp.asarray(tile), key, p=p, c=c),
                           tile)
        for sp in entry.splits:
            repr_ = pick_tile_repr(r=r, capacity=sp.capacity,
                                   method=req.method, choice=req.engine,
                                   elem_budget=self.budget)
            fn = split_executable(eng, self.kind, repr_, sp.capacity, r,
                                  method)
            for tn, tp in _split_batches(sp.nodes, sp.pivots, sp.capacity,
                                         self.budget,
                                         tile_batch_repr(repr_, method)):
                accumulate(fn(eng.csr, jnp.asarray(tn), jnp.asarray(tp),
                              key, p=p, c=c), tn)
        return total, per_node

    def run_profile(self, eng, groups, L, req):
        profile = np.zeros(L, np.float64)
        for g in groups:
            repr_ = pick_tile_repr(r=g.rmax, capacity=g.capacity,
                                   choice=req.engine,
                                   elem_budget=self.budget)
            fn = profile_executable(eng, self.kind, repr_, g.capacity,
                                    g.rmax)
            for tile in _tile_batches(g.nodes, g.capacity, self.budget,
                                      repr_):
                vals = np.asarray(jax.block_until_ready(
                    fn(eng.csr, jnp.asarray(tile))), np.float64)
                profile[:g.rmax - 1] += vals.sum(axis=0)
        return profile


# --------------------------------------------------------------------------
# shard_map backend: workers-axis mesh, per-capacity shards, psum
# --------------------------------------------------------------------------

def _worker_bucket_sum(csr, nodes_shard, key, p, c, *, capacity, n_iters,
                       r, method, tile_b, axis, tile_repr="dense"):
    """Runs on each worker: count its shard of one capacity class.

    nodes_shard: (1, T·tile_b) on this device — reshaped to tiles and
    folded with `lax.map` so the compiled program is one tile body —
    the same ``tile_values``/``bits_tile_values`` body the local
    backend jits (``tile_repr`` picks the representation).
    """
    nodes = nodes_shard.reshape(-1, tile_b)
    tv = bits_tile_values if tile_repr == "bits" else tile_values

    def one_tile(tile_nodes):
        return jnp.sum(tv(csr, tile_nodes, key, p=p, c=c,
                          capacity=capacity, n_iters=n_iters,
                          r=r, method=method))

    local = jnp.sum(jax.lax.map(one_tile, nodes))
    return jax.lax.psum(local, axis)


def _worker_split_sum(csr, nodes_shard, pivots_shard, key, p, c, *,
                      capacity, n_iters, r, method, tile_b, axis,
                      tile_repr="dense"):
    """§6 split units: one (node, pivot) per unit; counts (k−2)-cliques in
    A_u masked by pivot row v — ``split_tile_values`` (or its packed
    twin), the dense analogue of replicating G⁺(u) to reducer (u, v)."""
    nodes = nodes_shard.reshape(-1, tile_b)
    pivots = pivots_shard.reshape(-1, tile_b)
    tv = bits_split_tile_values if tile_repr == "bits" else \
        split_tile_values

    def one_tile(args):
        tile_nodes, tile_pivots = args
        return jnp.sum(tv(csr, tile_nodes, tile_pivots,
                          key, p=p, c=c, capacity=capacity,
                          n_iters=n_iters, r=r, method=method))

    local = jnp.sum(jax.lax.map(one_tile, (nodes, pivots)))
    return jax.lax.psum(local, axis)


def _worker_bucket_profile(csr, nodes_shard, *, capacity, n_iters, rmax,
                           tile_b, axis, tile_repr="dense"):
    """All-k twin of :func:`_worker_bucket_sum`: each worker folds its
    shard of one (capacity, rmax) depth group into an (rmax−1,) profile
    and psums across the axis. Exact-only, so no key/p/c operands."""
    nodes = nodes_shard.reshape(-1, tile_b)
    tv = (bits_profile_tile_values if tile_repr == "bits"
          else profile_tile_values)

    def one_tile(tile_nodes):
        return jnp.sum(tv(csr, tile_nodes, capacity=capacity,
                          n_iters=n_iters, r=rmax), axis=0)

    local = jnp.sum(jax.lax.map(one_tile, nodes), axis=0)
    return jax.lax.psum(local, axis)


class ShardMapBackend(Backend):
    name = "shard_map"

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None,
                 axis: str = "workers",
                 tile_elem_budget: int = 1 << 22) -> None:
        if mesh is None:
            mesh = jax.sharding.Mesh(np.array(jax.devices()), (axis,))
        self.mesh = mesh
        self.axis = axis
        self.budget = tile_elem_budget

    @property
    def n_workers(self) -> int:
        return self.mesh.shape[self.axis]

    def _wrap(self, body, n_arrays: int, check_rep: bool = True):
        """jit(shard_map(body)): csr replicated, stacked work arrays
        sharded over the workers axis, (key, p, c) replicated.
        ``check_rep=False`` is for bodies carrying a ``fori_loop`` (the
        wedge kernel's sample loop), which the static replication
        checker cannot type — the psum'd scalar is replicated either
        way."""
        in_specs = ((P(),) + (P(self.axis, None),) * n_arrays
                    + (P(), P(), P()))
        smap = shard_map if check_rep else shard_map_unchecked
        return jax.jit(smap(body, mesh=self.mesh, in_specs=in_specs,
                            out_specs=P()))

    def run(self, eng, entry, req, key):
        W = self.n_workers
        r = req.k - 1

        method = req.effective_method

        def repr_of(capacity: int) -> tuple[str, str]:
            """(counting repr, byte-accounting repr) per capacity."""
            tr = pick_tile_repr(r=r, capacity=capacity,
                                method=req.method, choice=req.engine,
                                elem_budget=self.budget)
            return tr, tile_batch_repr(tr, method)

        reprs = tuple(sorted(
            {(b.capacity,) + repr_of(b.capacity)
             for b in entry.plan.buckets} |
            {(sp.capacity,) + repr_of(sp.capacity)
             for sp in entry.splits}))
        sharded = entry.sharded(eng.og, W, self.budget, reprs)
        p = jnp.float32(req.p)
        c = jnp.int32(req.colors)
        total = 0.0
        for sb in sharded.buckets:
            fn = eng.executables.get(
                ("wsum", sb.capacity, sb.tile_repr, sb.tile_b, r, method,
                 W, self.axis),
                lambda sb=sb: self._wrap(functools.partial(
                    _worker_bucket_sum, capacity=sb.capacity,
                    n_iters=eng.og.lookup_iters, r=r, method=method,
                    tile_b=sb.tile_b, axis=self.axis,
                    tile_repr=sb.tile_repr), n_arrays=1,
                    check_rep=method != "wedge"))
            total += float(fn(eng.csr, sb.nodes, key, p, c))
        for ss in sharded.splits:
            fn = eng.executables.get(
                ("wsplit", ss.capacity, ss.tile_repr, ss.tile_b, r,
                 method, W, self.axis),
                lambda ss=ss: self._wrap(functools.partial(
                    _worker_split_sum, capacity=ss.capacity,
                    n_iters=eng.og.lookup_iters, r=r, method=method,
                    tile_b=ss.tile_b, axis=self.axis,
                    tile_repr=ss.tile_repr), n_arrays=2))
            total += float(fn(eng.csr, ss.nodes, ss.pivots, key, p, c))
        return total, None

    def run_profile(self, eng, groups, L, req):
        W = self.n_workers
        profile = np.zeros(L, np.float64)
        for g in groups:
            repr_ = pick_tile_repr(r=g.rmax, capacity=g.capacity,
                                   choice=req.engine,
                                   elem_budget=self.budget)
            # contiguous split is balanced by construction: every unit in
            # a depth group shares (capacity, rmax), hence the same cost
            per_w = -(-len(g.nodes) // W)
            tile_b = _pick_tile_b(per_w, g.capacity, self.budget, repr_)
            per_w += (-per_w) % tile_b
            nodes = np.full(W * per_w, -1, np.int32)
            nodes[:len(g.nodes)] = g.nodes
            stacked = jnp.asarray(nodes.reshape(W, per_w))
            fn = eng.executables.get(
                ("wprof", g.capacity, repr_, tile_b, g.rmax, W, self.axis),
                lambda g=g, repr_=repr_, tile_b=tile_b: jax.jit(shard_map(
                    functools.partial(
                        _worker_bucket_profile, capacity=g.capacity,
                        n_iters=eng.og.lookup_iters, rmax=g.rmax,
                        tile_b=tile_b, axis=self.axis, tile_repr=repr_),
                    mesh=self.mesh,
                    in_specs=(P(), P(self.axis, None)),
                    out_specs=P())))
            vals = np.asarray(jax.block_until_ready(fn(eng.csr, stacked)),
                              np.float64)
            profile[:g.rmax - 1] += vals
        return profile
