"""One-pass all-k clique profiles (``CountRequest(k="all")``).

The per-k engine answers "how many k-cliques" with one recursion depth
per query; a sweep over k = 3..kmax re-extracts and re-walks the same
tiles kmax−2 times. The profile recursion instead carries one counter
per recursion level (the Pivoter trick restricted to our pivot-free
DAG recursion): a single depth-r walk of G⁺(u) yields the unit's whole
clique-size histogram, and the host sums histograms — q_3..q_kmax from
ONE tile pass.

Depth is where the win is made or lost. Running every unit at the
global worst-case depth would make the one pass cost as much as the
deepest per-k query times the batch; instead each unit gets a
*certificate-clamped* depth from the same (d_u, e_u) certificates the
adaptive estimator computes (one exact r=2 popcount pass):

  - complete units (e_u = C(d_u, 2)): G⁺(u) is a clique — the whole
    histogram is C(d_u, k−1), computed on the host, no device work;
  - Kruskal–Katona: any c-clique inside G⁺(u) needs C(c, 2) ≤ e_u, so
    depth is clamped to the largest s with C(s, 2) ≤ e_u;
  - shallow units (clamped depth < 3): only q_3 = e_u survives — host;
  - everything else runs on the device, regrouped by (capacity, depth)
    so a bucket's light units never pay its heavy units' D^rmax.

Without ``max_k`` the device depth is capped at :data:`MAX_AUTO_RMAX`;
graphs with genuinely deep cliques must say how far to count (the cost
is exponential in depth — that choice belongs to the caller).
"""
from __future__ import annotations

import math

import numpy as np

from ..core.plan import regroup_by_depth

# deepest device recursion we will enter without an explicit max_k:
# depth 8 ≈ counting up to 9-cliques, already ~D^8 work per unit
MAX_AUTO_RMAX = 8


def _kk_depth(deg: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Per-unit depth clamp: the largest clique inside a neighborhood
    with e edges has ≤ s nodes where C(s, 2) ≤ e (Kruskal–Katona /
    Turán direction), and trivially ≤ d nodes."""
    e = np.maximum(np.asarray(edges, np.float64), 0.0)
    s = np.floor((1.0 + np.sqrt(1.0 + 8.0 * e)) / 2.0)
    return np.minimum(deg.astype(np.int64), s.astype(np.int64))


def _host_complete_profile(deg: np.ndarray, L: int) -> np.ndarray:
    """Σ_u C(d_u, k−1) for k = 3..L+2 over the complete units, exact in
    f64 via integer ``math.comb`` aggregated by degree value."""
    prof = np.zeros(L, np.float64)
    if deg.size == 0:
        return prof
    counts = np.bincount(deg)
    for d in np.nonzero(counts)[0]:
        mult = int(counts[d])
        for k in range(3, min(int(d) + 1, L + 2) + 1):
            prof[k - 3] += mult * float(math.comb(int(d), k - 1))
    return prof


def run_allk(eng, entry, req, backend) -> tuple[np.ndarray, dict]:
    """Execute ``k="all"``: returns (profile, telemetry) where
    ``profile[j] = q_{j+3}`` as int64, trimmed at the graph's clique
    number (or at ``req.max_k``)."""
    from ..estimator import _certificates

    # certificates come from the exact r=2 tile pass; always computed
    # via the local kind so every backend shares one cached pass (the
    # values are representation- and backend-independent)
    cert = _certificates(eng, eng._backend("local"), entry, 2, req.engine)
    deg = eng.og.out_deg.astype(np.int64)

    cap = (req.max_k - 1) if req.max_k is not None else None
    cache_key = ("allk", cap)
    cached = entry._aux.get(cache_key)
    if cached is None:
        depth = _kk_depth(deg, cert.edges)
        if cap is not None:
            depth = np.minimum(depth, cap)
        complete = cert.complete
        in_plan = cert.in_plan
        # device set: in-plan, not complete, deep enough to matter
        device_mask = in_plan & ~complete & (depth >= 3)
        if cap is None:
            rmax_dev = int(depth[device_mask].max()) if device_mask.any() \
                else 0
            if rmax_dev > MAX_AUTO_RMAX:
                raise ValueError(
                    f"k='all' would recurse to depth {rmax_dev} "
                    f"(> {MAX_AUTO_RMAX}) on this graph; pass "
                    "CountRequest(k='all', max_k=K) to bound the profile")
        dev_depth = np.where(device_mask, depth, 0)
        groups = regroup_by_depth(entry.plan, dev_depth)
        # profile length: deepest host-exact clique vs deepest device walk
        comp_deg = deg[in_plan & complete]
        kmax_complete = int(comp_deg.max()) + 1 if comp_deg.size else 0
        if cap is not None:
            kmax_complete = min(kmax_complete, cap + 1)
        kmax_device = max((g.rmax for g in groups), default=0) + 1
        shallow = in_plan & ~complete & (depth < 3)
        kmax_host3 = 3 if float(cert.edges[shallow].sum()) > 0 else 0
        L = max(kmax_complete, kmax_device, kmax_host3) - 2
        host = np.zeros(max(L, 0), np.float64)
        if L > 0:
            host += _host_complete_profile(deg[in_plan & complete], L)
            host[0] += float(cert.edges[shallow].sum())
        cached = {"groups": groups, "L": max(L, 0), "host": host,
                  "n_complete": int((in_plan & complete).sum()),
                  "n_shallow": int(shallow.sum()),
                  "n_device": int(device_mask.sum())}
        entry._aux[cache_key] = cached

    groups, L, host = cached["groups"], cached["L"], cached["host"]
    if L == 0:
        profile = np.zeros(0, np.int64)
    else:
        device = backend.run_profile(eng, groups, L, req)
        total = host + device
        profile = np.rint(total).astype(np.int64)
        nz = np.nonzero(profile)[0]
        profile = profile[:int(nz[-1]) + 1] if nz.size else profile[:0]
    telemetry = {
        "n_complete": cached["n_complete"],
        "n_shallow": cached["n_shallow"],
        "n_device": cached["n_device"],
        "device_groups": [(g.capacity, g.rmax, g.n_real) for g in groups],
        "kmax": int(profile.size) + 2 if profile.size else 0,
    }
    return profile, telemetry
