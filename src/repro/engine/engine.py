"""Session-style clique-counting engine.

The paper's pipeline (orient → plan → reduce-3 count) is amortizable:
the oriented CSR and the capacity-bucket plan are pure functions of the
graph (and of (k, max_capacity, split_threshold)), so a session serving
many ``(k, method)`` queries on one graph should pay for them once. The
seed API instead rebuilt everything per call; :class:`CliqueEngine`
builds and uploads the CSR once, caches plans and compiled tile
executables, and routes each request through a per-request backend.

    eng = CliqueEngine(graph)                      # orient + upload once
    rep = eng.submit(CountRequest(k=4))            # exact q_4
    reps = eng.submit_many(
        [CountRequest(k=k) for k in (3, 4, 5)] +
        [CountRequest(k=5, method="color", colors=10)])
    eng.session_stats()["executables"]             # cache telemetry
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import mrc as mrc_mod
from ..core.count import _pick_tile_b
from ..core.csr import OrientedGraph, build_oriented
from ..core.extract import DeviceCSR, to_device
from ..core.plan import (Plan, balance_report, build_plan,
                         partition_for_workers)
from ..core.split import SplitPlan, split_heavy
from ..graphs.formats import Graph
from .allk import run_allk
from .backends import (Backend, ExecutableCache, LocalBackend,
                       ShardMapBackend)
from .report import CountReport, CountRequest


def derive_sweep_seed(seed: int, index: int) -> int:
    """Per-request seed for sweep entry ``index``: fold the index into
    the template seed with the same counter-based PRNG the samplers use,
    so sweep replicates are decorrelated but fully reproducible."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), index)
    data = np.asarray(jax.random.key_data(key)).ravel()
    return int(data[-1]) & 0x7FFFFFFF


def graph_fingerprint(graph: Graph) -> str:
    """Content hash of a canonical graph — the session-pool key.

    ``Graph`` stores edges canonicalized (u < v, sorted, deduplicated),
    so two structurally identical graphs hash equal regardless of the
    edge order / duplicates / self-loops they were built from. Isolated
    tail nodes change ``n`` and therefore the fingerprint: q_k is the
    same, but per-node attributions are not.
    """
    h = hashlib.sha256()
    h.update(int(graph.n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(graph.edges, dtype=np.int64).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class _ShardBucket:
    capacity: int
    tile_b: int
    tile_repr: str            # "dense" f32 or "bits" packed uint32
    nodes: jax.Array          # (W, width) int32, −1 padding


@dataclasses.dataclass
class _ShardSplit:
    capacity: int
    tile_b: int
    tile_repr: str
    nodes: jax.Array          # (W, width) int32, −1 padding
    pivots: jax.Array         # (W, width) int32


@dataclasses.dataclass
class _ShardedPlan:
    buckets: list[_ShardBucket]
    splits: list[_ShardSplit]


@dataclasses.dataclass
class PlanEntry:
    """One cached plan: the bucketed work units, the §6 split units, and
    (lazily) the per-worker stacked/staged device arrays per mesh width."""
    plan: Plan
    splits: tuple[SplitPlan, ...]
    _sharded: dict = dataclasses.field(default_factory=dict)
    _balance: dict = dataclasses.field(default_factory=dict)
    _mrc: dict = dataclasses.field(default_factory=dict)
    # plan-lifetime scratch for the adaptive estimator: density
    # certificates and the key-independent exact bucket partials, both
    # pure functions of (plan, backend kind) — see repro.estimator
    _aux: dict = dataclasses.field(default_factory=dict)

    def sharded(self, og: OrientedGraph, n_workers: int,
                tile_elem_budget: int,
                reprs: tuple = ()) -> _ShardedPlan:
        """``reprs`` is a sorted tuple of (capacity, tile_repr,
        batch_repr) triples — the per-bucket representation choice, part
        of the cache key because it sets each bucket's tile batch
        (exact packed tiles are 32× smaller, so their tile_b grows
        accordingly; sampled packed tiles batch at dense sizes since
        their transient mask is dense)."""
        key = (n_workers, tile_elem_budget, reprs)
        if key not in self._sharded:
            self._sharded[key] = _stack_for_workers(
                self.plan, self.splits, og, n_workers, tile_elem_budget,
                {cap: (tr, br) for cap, tr, br in reprs})
        return self._sharded[key]

    def balance(self, og: OrientedGraph, n_workers: int) -> dict:
        """balance_report is a pure function of (plan, W) and redoes the
        LPT partition — cache it so repeat queries don't pay it."""
        if n_workers not in self._balance:
            self._balance[n_workers] = balance_report(self.plan, og,
                                                      n_workers)
        return self._balance[n_workers]

    def stats(self, og: OrientedGraph, method: str, p: float,
              colors: int, k: Optional[int] = None) -> "mrc_mod.MRCStats":
        """compute_stats is likewise pure in (plan, method, p, colors, k)
        — cached so repeat queries skip the O(n) host-side pass. Since
        plans went k-agnostic, the query's k is part of the key (the
        work bounds are per-query)."""
        key = (method, p, colors, k)
        if key not in self._mrc:
            self._mrc[key] = mrc_mod.compute_stats(
                og, self.plan, method=method, p=p, colors=colors, k=k)
        return self._mrc[key]


def _stack_for_workers(plan: Plan, splits: Sequence[SplitPlan],
                       og: OrientedGraph, W: int, tile_elem_budget: int,
                       repr_of: Optional[dict] = None) -> _ShardedPlan:
    """LPT-partition the plan and stack each capacity class into one
    (W, width) array — identical static shapes on every device, so the
    shard_map sees no stragglers by construction. ``repr_of`` maps each
    capacity to its (counting, byte-accounting) representation pair;
    tile batches are byte-accounted per representation (exact packed
    tiles batch up to 32× wider)."""
    repr_of = repr_of or {}
    worker_plans = partition_for_workers(plan, og, W)
    buckets = []
    caps = sorted({b.capacity for wp in worker_plans for b in wp.buckets})
    for cap in caps:
        per_w = []
        for wp in worker_plans:
            arrs = [b.nodes for b in wp.buckets if b.capacity == cap]
            per_w.append(np.concatenate(arrs) if arrs
                         else np.zeros(0, np.int32))
        width = max(len(a) for a in per_w)
        repr_, batch_repr = repr_of.get(cap, ("dense", "dense"))
        tile_b = _pick_tile_b(width, cap, tile_elem_budget, batch_repr)
        width += (-width) % tile_b
        stacked = np.full((W, width), -1, np.int32)
        for i, a in enumerate(per_w):
            stacked[i, :len(a)] = a
        buckets.append(_ShardBucket(capacity=cap, tile_b=tile_b,
                                    tile_repr=repr_,
                                    nodes=jnp.asarray(stacked)))
    split_stacks = []
    for sp in splits:
        units = np.stack([sp.nodes, sp.pivots], axis=1)
        pad = (-len(units)) % (8 * W)
        units = np.concatenate(
            [units, np.tile([[-1, 0]], (pad, 1)).astype(np.int32)])
        per = len(units) // W
        repr_, batch_repr = repr_of.get(sp.capacity, ("dense", "dense"))
        tile_b = _pick_tile_b(per, sp.capacity, tile_elem_budget,
                              batch_repr)
        per += (-per) % tile_b
        stacked_n = np.full((W, per), -1, np.int32)
        stacked_p = np.zeros((W, per), np.int32)
        # round-robin so consecutive pivots of one node spread out (LPT-ish)
        for i in range(len(units)):
            w, j = i % W, i // W
            stacked_n[w, j], stacked_p[w, j] = units[i]
        split_stacks.append(_ShardSplit(capacity=sp.capacity, tile_b=tile_b,
                                        tile_repr=repr_,
                                        nodes=jnp.asarray(stacked_n),
                                        pivots=jnp.asarray(stacked_p)))
    return _ShardedPlan(buckets=buckets, splits=split_stacks)


class CliqueEngine:
    """One session over one graph; many queries, shared preprocessing.

    Parameters
    ----------
    graph: the input graph (undirected edge list container).
    backend: default execution backend — "local" (jnp), "pallas",
        "shard_map", or "ooc" (out-of-core partitioned execution, see
        :mod:`repro.scheduler`); any :class:`CountRequest` may override
        per query.
    mesh/axis: mesh for the shard_map backend (default: 1-D mesh over
        all local devices).
    ooc: a :class:`repro.scheduler.SchedulerConfig` for the "ooc"
        backend (worker count, spill dir, resume, speculation knobs);
        None uses the scheduler defaults.
    og: precomputed oriented CSR (skips round 1 — used by the legacy
        wrappers; normal callers let the engine build it).
    """

    def __init__(self, graph: Graph, backend: str = "local", *,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 axis: str = "workers",
                 og: Optional[OrientedGraph] = None,
                 local_tile_budget: int = 1 << 23,
                 dist_tile_budget: int = 1 << 22,
                 ooc=None) -> None:
        t0 = time.perf_counter()
        self.graph = graph
        self.og = og if og is not None else build_oriented(graph)
        t1 = time.perf_counter()
        self.csr: DeviceCSR = to_device(self.og)   # uploaded once
        self.timings = {"orient_s": t1 - t0,
                        "upload_s": time.perf_counter() - t1}
        self.default_backend = backend
        self._backends: dict[str, Backend] = {}
        self._mesh, self._axis = mesh, axis
        self._local_budget = local_tile_budget
        self._dist_budget = dist_tile_budget
        self._ooc_cfg = ooc        # scheduler.SchedulerConfig or None
        self._plans: dict[tuple, PlanEntry] = {}
        self._plan_hits = 0
        self._plan_misses = 0
        self.executables = ExecutableCache()
        self.n_queries = 0
        # adaptive-controller knobs + telemetry (repro.estimator)
        self.estimator_policy = None   # None → estimator.DEFAULT_POLICY
        self.adaptive_stats = {"queries": 0, "sampled": 0,
                               "fallthroughs": 0, "escalations": 0,
                               "replicates": 0, "winners": {}}
        # sparsified child sessions, LRU-keyed (q, seed): one DOULION
        # replicate = one exact count on a child graph, and adjacent
        # requests (sweeps, repeated queries) reuse the child's CSR
        self._sparsify_children: dict[tuple, "CliqueEngine"] = {}
        self._fingerprint: Optional[str] = None
        self._closed = False
        self._close_hooks: list[Callable[["CliqueEngine"], None]] = []
        self._backend(backend)  # validate the default name eagerly

    # -- session lifecycle -------------------------------------------------

    @property
    def fingerprint(self) -> str:
        """Content hash of the session's graph (the pool key)."""
        if self._fingerprint is None:
            self._fingerprint = graph_fingerprint(self.graph)
        return self._fingerprint

    @property
    def closed(self) -> bool:
        return self._closed

    def register_close_hook(self,
                            hook: Callable[["CliqueEngine"], None]) -> None:
        """Run ``hook(engine)`` when the session is closed/evicted —
        lets a pool flush per-session telemetry before dropping refs."""
        self._close_hooks.append(hook)

    def close(self) -> None:
        """End the session: run eviction hooks and drop the device CSR
        and every cache, so an LRU pool eviction actually releases the
        graph's device memory. Idempotent; further submits raise."""
        if self._closed:
            return
        self._closed = True
        for hook in self._close_hooks:
            hook(self)
        self._close_hooks.clear()
        for child in self._sparsify_children.values():
            child.close()
        self._sparsify_children.clear()
        self._plans.clear()
        self._backends.clear()
        self.executables = ExecutableCache()
        self.csr = None  # type: ignore[assignment]  # frees device buffers

    # -- caches ------------------------------------------------------------

    def _backend(self, name: str) -> Backend:
        b = self._backends.get(name)
        if b is None:
            if name == "local":
                b = LocalBackend("jnp", self._local_budget)
            elif name == "pallas":
                b = LocalBackend("pallas", self._local_budget)
            elif name == "shard_map":
                b = ShardMapBackend(self._mesh, self._axis,
                                    self._dist_budget)
            elif name == "ooc":
                from ..scheduler import OocBackend
                b = OocBackend(self._ooc_cfg)
            else:
                raise ValueError(f"unknown backend {name!r}")
            self._backends[name] = b
        return b

    def _plan_entry(self, req: CountRequest) -> tuple[PlanEntry, bool]:
        key = req.plan_key()
        entry = self._plans.get(key)
        if entry is not None:
            self._plan_hits += 1
            return entry, True
        self._plan_misses += 1
        # k-agnostic: one plan (k=3 eligibility — every k ≥ 3 query's
        # units are a subset, extra units count 0) serves the session;
        # the split structure depends only on the threshold
        plan = build_plan(self.og, 3, max_capacity=req.max_capacity)
        splits: tuple[SplitPlan, ...] = ()
        if req.split_threshold is not None:
            plan, sp = split_heavy(plan, self.og, 3, req.split_threshold)
            splits = tuple(sp)
        entry = PlanEntry(plan=plan, splits=splits)
        self._plans[key] = entry
        return entry, False

    def _sparsify_child(self, q: float, seed: int) -> "CliqueEngine":
        """The (q, seed)-sparsified child session: each edge of the
        session graph survives with probability q under a host-side
        counter-based mask that depends only on (seed, q, graph) — the
        same child on every backend, so sparsified estimates are
        bit-identical across local/pallas/shard_map/ooc. A tiny LRU
        keeps recent children's CSRs resident (one per replicate seed)."""
        key = (float(q), int(seed))
        child = self._sparsify_children.pop(key, None)
        if child is None:
            g = self.graph
            rng = np.random.default_rng([int(seed), 0x5BA12F])
            keep = rng.random(len(g.edges)) < float(q)
            from ..graphs.formats import from_edges
            child = CliqueEngine(
                from_edges(g.edges[keep], n=g.n,
                           name=f"{g.name}~sparsify(q={q:g},s={seed})"),
                backend=self.default_backend, mesh=self._mesh,
                axis=self._axis, local_tile_budget=self._local_budget,
                dist_tile_budget=self._dist_budget, ooc=self._ooc_cfg)
        self._sparsify_children[key] = child    # (re)insert most-recent
        while len(self._sparsify_children) > 4:
            oldest = next(iter(self._sparsify_children))
            self._sparsify_children.pop(oldest).close()
        return child

    def _run_sparsify(self, req: CountRequest, backend: Backend
                      ) -> tuple[float, Optional[np.ndarray], dict]:
        """One direct DOULION estimate: exact count on the (q, seed)
        child, rescaled by q^{−C(k,2)} (each of the C(k,2) clique edges
        survives independently with probability q)."""
        q = float(req.p)                   # slot-reuse: p carries q
        child = self._sparsify_child(q, req.seed)
        crep = child.submit(dataclasses.replace(req, method="exact",
                                                rel_error=None))
        scale = q ** -(req.k * (req.k - 1) / 2.0)
        per_node = (None if crep.per_node is None
                    else np.asarray(crep.per_node, np.float64) * scale)
        tel = {"q": q, "seed": req.seed, "scale": scale,
               "kept_edges": int(child.og.m),
               "total_edges": int(self.og.m),
               "child_count": crep.estimate}
        return crep.estimate * scale, per_node, tel

    def warm_plan(self, plan: Plan,
                  splits: Sequence[SplitPlan] = ()) -> None:
        """Seed the plan cache with an externally built plan (legacy
        ``count_cliques(..., plan=...)`` path)."""
        self._plans[(None, None)] = PlanEntry(plan=plan,
                                              splits=tuple(splits))

    # -- queries -----------------------------------------------------------

    def submit(self, req: CountRequest) -> CountReport:
        t0 = time.perf_counter()
        if self._closed:
            raise RuntimeError(
                "CliqueEngine session is closed (evicted from its pool); "
                "build a new session for this graph")
        req.validate()
        backend = self._backend(req.backend or self.default_backend)
        backend.validate(req)
        if req.return_per_node and backend.name == "shard_map":
            raise ValueError("per-node attribution is a local/pallas "
                             "backend feature (workers psum tile sums)")
        entry, plan_hit = self._plan_entry(req)
        t_plan = time.perf_counter() - t0

        h0, m0 = self.executables.snapshot()
        t1 = time.perf_counter()
        adaptive_info = sparsify_tel = None
        cliques = listing_stats = None
        profile = allk_tel = None
        if req.k == "all":
            profile, allk_tel = run_allk(self, entry, req, backend)
            estimate, per_node = float(profile.sum()), None
        elif req.mode == "list":
            from ..listing import collect_cliques
            cliques, listing_stats = collect_cliques(self, req)
            estimate, per_node = float(len(cliques)), None
        elif req.is_adaptive:
            from ..estimator import run_adaptive
            estimate, per_node, adaptive_info = run_adaptive(
                self, backend, entry, req, self.estimator_policy)
        elif req.effective_method == "sparsify":
            # DOULION: count exactly on a sparsified child session and
            # rescale — no tile kernel involvement, so any backend
            # (including bitset tiles and ooc) works unchanged
            estimate, per_node, sparsify_tel = self._run_sparsify(
                req, backend)
        else:
            key = jax.random.PRNGKey(req.seed)
            estimate, per_node = backend.run(self, entry, req, key)
        t_count = time.perf_counter() - t1
        h1, m1 = self.executables.snapshot()

        W = backend.n_workers
        # the all-k profile's MRC accounting is reported at the k=3
        # reference (one pass, triangle-round volumes dominate)
        stats = entry.stats(self.og, req.method, req.p, req.colors,
                            k=3 if req.k == "all" else req.k)
        csr_bytes = 4.0 * (self.og.n + 1 + 2 * self.og.m + self.og.n)
        self.n_queries += 1
        report = CountReport(
            k=req.k, method=req.method, backend=backend.name,
            estimate=estimate, per_node=per_node, mrc=stats,
            plan_summary=entry.plan.cost_summary(),
            # copy: the cached dict must survive callers mutating their
            # report in place
            balance=dict(entry.balance(self.og, W)),
            per_round_bytes={
                "csr_replication_allgather": csr_bytes * (W - 1),
                "count_allreduce": 4.0 * W,
                "paper_round2_shuffle_equiv": stats.round2_pairs * 8.0,
            },
            timings={"plan_s": t_plan, "count_s": t_count,
                     "total_s": time.perf_counter() - t0},
            cache={"plan": "hit" if plan_hit else "miss",
                   "exec_hits": h1 - h0, "exec_misses": m1 - m0},
            n_workers=W,
            params={"p": req.p, "colors": req.colors, "seed": req.seed,
                    "backend": backend.name})
        tel = backend.pop_telemetry()
        if tel is not None:
            report.cache["scheduler"] = tel
        if sparsify_tel is not None:
            report.cache["sparsify"] = sparsify_tel
        if profile is not None:
            report.profile = profile
            report.cache["allk"] = allk_tel
        if cliques is not None:
            report.cliques = cliques
            report.listing = dict(listing_stats,
                                  chunk_capacity=req.chunk,
                                  limit=req.limit)
        if adaptive_info is not None:
            report.ci_low = adaptive_info["ci_low"]
            report.ci_high = adaptive_info["ci_high"]
            report.achieved_rel_error = adaptive_info["achieved_rel_error"]
            report.escalations = adaptive_info["escalations"]
            report.estimator = adaptive_info
            report.params.update(rel_error=adaptive_info["rel_error_target"],
                                 confidence=req.confidence,
                                 resolved=adaptive_info["resolved"])
        return report

    def stream(self, req: CountRequest):
        """Stream a listing query as :class:`repro.listing.CliqueBatch`
        chunks — the bounded-memory consumption path (host memory stays
        O(``req.chunk``) no matter how many cliques the graph holds).
        ``submit`` on the same request instead materializes the full
        array on the report. See ``docs/listing.md``.

        Validation and the closed-session check run *here*, not at first
        iteration, so a bad request fails at the call site like
        ``submit`` does (``stream_cliques`` itself is a generator).
        """
        from ..listing import stream_cliques
        if req.mode != "list":
            req = dataclasses.replace(req, mode="list")
        if self._closed:
            raise RuntimeError(
                "CliqueEngine session is closed (evicted from its pool); "
                "build a new session for this graph")
        req.validate()
        self.n_queries += 1
        return stream_cliques(self, req)

    def submit_many(self, reqs: Iterable[CountRequest], *,
                    decorrelate: bool = True,
                    coalesce_sweeps: bool = True) -> list[CountReport]:
        """Batched sweep over one session — e.g. k=3..7 exact+color in
        one call; every query reuses the device CSR, and repeat
        (capacity, r, method) combinations hit the executable cache.

        Exact k-sweeps coalesce: when every entry is a plain exact count
        (no listing/adaptive/per-node/split, same backend and knobs),
        the batch routes through ONE ``k="all"`` profile execution with
        ``max_k = max(k)`` and each report reads its q_k off the profile
        — N tile passes become 1. Pass ``coalesce_sweeps=False`` to run
        each entry separately (the benchmark baseline does).

        Sampled entries get per-request seeds derived by folding the
        sweep index into their seed (``jax.random.fold_in``): a sweep of
        R sampled replicates built from one request template would
        otherwise silently reuse one seed — identical masks, perfectly
        correlated "replicates". Exact entries are untouched (the seed
        is not answer-defining there). Pass ``decorrelate=False`` to
        submit requests verbatim.
        """
        reqs = list(reqs)
        if coalesce_sweeps and len(reqs) >= 2 and all(
                isinstance(r.k, int) and not isinstance(r.k, bool)
                and r.mode == "count" and r.method == "exact"
                and not r.return_per_node and r.split_threshold is None
                for r in reqs) and len(
                    {(r.backend, r.engine, r.max_capacity)
                     for r in reqs}) == 1:
            allreq = dataclasses.replace(
                reqs[0], k="all", method="exact",
                max_k=max(r.k for r in reqs))
            rep = self.submit(allreq)
            prof = (rep.profile if rep.profile is not None
                    else np.zeros(0, np.int64))
            out = []
            for r in reqs:
                j = r.k - 3
                est = float(prof[j]) if 0 <= j < prof.size else 0.0
                out.append(dataclasses.replace(
                    rep, k=r.k, method=r.method, estimate=est,
                    profile=None, timings=dict(rep.timings),
                    cache=dict(rep.cache, sweep_coalesced=len(reqs)),
                    params=dict(rep.params)))
            return out
        out = []
        for i, req in enumerate(reqs):
            if decorrelate and req.effective_method != "exact":
                req = dataclasses.replace(
                    req, seed=derive_sweep_seed(req.seed, i))
            out.append(self.submit(req))
        return out

    # -- telemetry ---------------------------------------------------------

    def session_stats(self) -> dict:
        return {
            "n_queries": self.n_queries,
            "closed": self._closed,
            "graph": {"n": self.og.n, "m": self.og.m,
                      "name": self.graph.name,
                      "fingerprint": self.fingerprint},
            "plans": {"hits": self._plan_hits,
                      "misses": self._plan_misses,
                      "cached": len(self._plans)},
            "executables": {"hits": self.executables.hits,
                            "misses": self.executables.misses,
                            "cached": len(self.executables)},
            "estimator": dict(self.adaptive_stats),
            "timings": dict(self.timings),
        }
