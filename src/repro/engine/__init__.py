"""Session-style clique-counting engine: one query API over the
single-host jnp, Pallas-kernel, and shard_map execution backends.

    from repro.engine import CliqueEngine, CountRequest

    eng = CliqueEngine(graph)                 # orient + upload CSR once
    rep = eng.submit(CountRequest(k=4))       # exact q_4
    sweep = eng.submit_many([CountRequest(k=k) for k in (3, 4, 5)])

The legacy ``repro.core.count_cliques`` / ``count_cliques_distributed``
entry points are thin deprecated wrappers over this engine.
"""
from .backends import Backend, ExecutableCache, LocalBackend, ShardMapBackend
from .engine import (CliqueEngine, PlanEntry, derive_sweep_seed,
                     graph_fingerprint)
from .report import (ADAPTIVE_METHODS, BACKENDS, LISTING_BACKENDS,
                     METHODS, MODES, TILE_ENGINES, CountReport,
                     CountRequest, report_from_json, report_to_json)

__all__ = [
    "CliqueEngine", "CountRequest", "CountReport", "PlanEntry",
    "Backend", "LocalBackend", "ShardMapBackend", "ExecutableCache",
    "ADAPTIVE_METHODS", "BACKENDS", "LISTING_BACKENDS", "METHODS",
    "MODES", "TILE_ENGINES", "derive_sweep_seed", "graph_fingerprint",
    "report_from_json", "report_to_json",
]
