"""Mamba-2 SSD (state-space duality) mixer.

Training uses the chunked SSD algorithm: the sequence is split into
chunks of length Q; within a chunk the recurrence is materialized as a
masked decay-weighted (Q, Q) matmul (MXU work), and a `lax.scan` carries
the (H, P, N) state across chunks. This is the matmul-rich form the SSD
paper derives — O(S·Q) instead of O(S²) attention, and O(S·N·P) state
math. Decode is the pure recurrence: one state update per token,
independent of context length — the reason long_500k is cheap for SSM
architectures.

Conventions (ngroups = 1):
  in_proj  : D → [z(di) | x(di) | B(N) | C(N) | dt(H)]
  conv1d   : causal depthwise width-w over [x|B|C]
  per head : h_t = exp(A·dt_t)·h_{t−1} + dt_t·B_t ⊗ x_t ;  y_t = C_t·h_t + D·x_t
  gate     : y ← rmsnorm(y) * silu(z), then out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import ShardCtx, dense, rms_norm, vzeros


def ssm_params(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    di, N, H = cfg.dinner, cfg.ssm_state, cfg.n_ssm_heads
    w = cfg.conv_width
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense(ks[0], (D, 2 * di + 2 * N + H)),
        "conv_w": dense(ks[1], (w, conv_ch), scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": dense(ks[3], (di, D)),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, N, H = cfg.dinner, cfg.ssm_state, cfg.n_ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, conv_w: jax.Array,
                 conv_b: jax.Array) -> jax.Array:
    """(B, S, C) causal depthwise conv, width w (stacked shifts)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(pad[:, i:i + S, :] * conv_w[i].astype(xBC.dtype)
              for i in range(w))
    return jax.nn.silu(out + conv_b.astype(xBC.dtype))


def ssd_train(cfg: ModelConfig, p: dict, x: jax.Array,
              ctx: ShardCtx) -> jax.Array:
    """Chunked SSD over the full sequence. x: (B, S, D) → (B, S, D)."""
    y, _, _ = _ssd_full(cfg, p, x, ctx)
    return y


def ssd_prefill(cfg: ModelConfig, p: dict, x: jax.Array, ctx: ShardCtx):
    """Full-sequence SSD that also returns (final_state, conv_cache) so
    decode can continue the recurrence."""
    return _ssd_full(cfg, p, x, ctx)


def _ssd_full(cfg: ModelConfig, p: dict, x: jax.Array,
              ctx: ShardCtx):
    B, S, D = x.shape
    di, N, H = cfg.dinner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    Q = cfg.ssd_chunk
    while S % Q:
        Q //= 2
    nc = S // Q
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)
    # conv cache for decode continuation: last w−1 *pre-conv* channels
    w = cfg.conv_width
    conv_cache = jnp.pad(xBC_raw, ((0, 0), (w - 1, 0), (0, 0)))[:, S:, :] \
        if S < w - 1 else xBC_raw[:, S - (w - 1):, :]
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])               # (B, S, H)
    A = -jnp.exp(p["A_log"])                           # (H,)
    a = dt * A[None, None, :]                          # log-decay ≤ 0
    # chunk views
    xs_c = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    B_c = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nc, Q, H)
    a_c = a.reshape(B, nc, Q, H)
    L = jnp.cumsum(a_c, axis=2)                        # (B, nc, Q, H)
    # intra-chunk kernel: M[b,h,q,s] = (C_q·B_s)·exp(L_q−L_s)·dt_s, s ≤ q
    G = jnp.einsum("bnqk,bnsk->bnqs", C_c, B_c)        # (B, nc, Q, Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Wd = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :]) \
        * dt_c[:, :, None, :, :]                       # (B,nc,Q,Q,H)
    Wd = jnp.where(mask[None, None, :, :, None], Wd, 0.0)
    M = G[..., None] * Wd                              # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnqsh,bnshp->bnqhp", M, xs_c)
    # inter-chunk state scan
    decay_out = jnp.exp(L)                                  # exp(L_q)
    decay_in = jnp.exp(L[:, :, -1:, :] - L) * dt_c          # (B,nc,Q,H)

    def chunk_step(state, xs_chunk):
        xc, bc, cc, dout, din, lend = xs_chunk
        # y_state[q] = C_q · (exp(L_q) * state)
        y_state = jnp.einsum("bqk,bqh,bhpk->bqhp", cc, dout, state)
        new_state = state * jnp.exp(lend)[:, :, None, None] + \
            jnp.einsum("bqh,bqhp,bqk->bhpk", din, xc, bc)
        return new_state, y_state

    state0 = vzeros((B, H, P, N), x)
    xs_scan = (xs_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
               C_c.transpose(1, 0, 2, 3), decay_out.transpose(1, 0, 2, 3),
               decay_in.transpose(1, 0, 2, 3),
               L[:, :, -1, :].transpose(1, 0, 2))
    final_state, y_state = jax.lax.scan(chunk_step, state0, xs_scan)
    y = y_intra + y_state.transpose(1, 0, 2, 3, 4)     # (B, nc, Q, H, P)
    y = y + xs_c * p["D"][None, None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y, p["norm_scale"]) * jax.nn.silu(z.astype(jnp.float32))
    y = ctx.batch_feature(y.astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    return out, final_state, conv_cache


def ssd_decode(cfg: ModelConfig, p: dict, x: jax.Array,
               state: jax.Array, conv_cache: jax.Array,
               ctx: ShardCtx):
    """One-token recurrence. x: (B, 1, D); state: (B, H, P, N) f32;
    conv_cache: (B, w−1, di+2N). Returns (y, state, conv_cache)."""
    B = x.shape[0]
    di, N, H = cfg.dinner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // H
    w = cfg.conv_width
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    z, xBC, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_cache, xBC.astype(conv_cache.dtype)], 1)
    conv_cache = window[:, 1:, :]
    conv = sum(window[:, i, :] * p["conv_w"][i].astype(x.dtype)
               for i in range(w))
    xBC1 = jax.nn.silu(conv + p["conv_b"].astype(x.dtype))   # (B, C)
    xt = xBC1[:, :di].reshape(B, H, P).astype(jnp.float32)
    Bt = xBC1[:, di:di + N].astype(jnp.float32)
    Ct = xBC1[:, di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    alpha = jnp.exp(dt * A[None, :])                         # (B, H)
    state = state * alpha[:, :, None, None] + \
        jnp.einsum("bh,bhp,bk->bhpk", dt, xt, Bt)
    y = jnp.einsum("bk,bhpk->bhp", Ct, state) + \
        xt * p["D"][None, :, None]
    y = y.reshape(B, 1, di)
    y = rms_norm(y, p["norm_scale"]) * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                     p["w_out"].astype(x.dtype))
    return out, state, conv_cache
