"""Flash attention in pure JAX: chunked online-softmax forward + a
custom_vjp backward that *recomputes* scores per KV chunk instead of
letting `lax.scan` checkpoint O(S²/chunk) residuals.

Memory: forward saves only (q, k, v, o, L) — O(B·S·H·dh); backward
streams KV chunks twice (dq pass fused with dk/dv pass). FLOPs: +1
recompute of QKᵀ in backward, the standard flash trade. This is the
TPU-idiomatic answer to the same problem the paper's §6 split round
solves for clique counting: bound the *local* working set, keep global
work asymptotically unchanged.

Handles GQA grouping (H = Hkv·g), MLA's dv ≠ dh, causal and
sliding-window masks, and a query-position offset.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .layers import dot_f32, vzeros

NEG_INF = -2.0e38


def _mask(q_pos, kv_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    return m


def _fwd_scan(q, k, v, causal, window, chunk, q_offset):
    B, Sq, Hkv, g, dh = q.shape
    Skv = k.shape[1]
    dv = v.shape[-1]
    nc = Skv // chunk
    kc = k.reshape(B, nc, chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Hkv, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        acc, m, lsum = carry
        kj, vj, j = xs
        kv_pos = j * chunk + jnp.arange(chunk)
        s = dot_f32("bqhgd,bkhd->bqhgk", q, kj)
        msk = _mask(q_pos, kv_pos, causal, window)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk[None, :, None, None, :], p, 0.0)
        corr = jnp.exp(m - m_new)
        acc = acc * corr[..., None] + dot_f32(
            "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj)
        lsum = lsum * corr + jnp.sum(p, axis=-1)
        return (acc, m_new, lsum), ()

    acc0 = vzeros((B, Sq, Hkv, g, dv), q)
    m0 = vzeros((B, Sq, Hkv, g), q) + NEG_INF / 2
    l0 = vzeros((B, Sq, Hkv, g), q)
    (acc, m, lsum), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(nc)))
    lsum = jnp.maximum(lsum, 1e-30)
    out = acc / lsum[..., None]
    lse = m + jnp.log(lsum)         # logsumexp per (b, q, hkv, g)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_grouped(q, k, v, causal: bool, window: int,
                            chunk: int, q_offset: int):
    """q: (B,Sq,Hkv,g,dh) pre-scaled (any float dtype; dots accumulate
    f32 via preferred_element_type); k: (B,Skv,Hkv,dh);
    v: (B,Skv,Hkv,dv). Returns (B,Sq,Hkv,g,dv) f32."""
    out, _ = _fwd_scan(q, k, v, causal, window, chunk, q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _fwd_scan(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hkv, g, dh = q.shape
    Skv = k.shape[1]
    dv = v.shape[-1]
    nc = Skv // chunk
    dout = dout.astype(jnp.float32)
    delta = jnp.sum(dout * out, axis=-1)          # (B,Sq,Hkv,g)
    kc = k.reshape(B, nc, chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Hkv, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def step(dq_acc, xs):
        kj, vj, j = xs
        kv_pos = j * chunk + jnp.arange(chunk)
        s = dot_f32("bqhgd,bkhd->bqhgk", q, kj)
        msk = _mask(q_pos, kv_pos, causal, window)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])           # normalized probs
        p = jnp.where(msk[None, :, None, None, :], p, 0.0)
        dp = dot_f32("bqhgd,bkhd->bqhgk", dout.astype(vj.dtype), vj)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + dot_f32("bqhgk,bkhd->bqhgd", ds.astype(kj.dtype), kj)
        dkj = dot_f32("bqhgk,bqhgd->bkhd", ds.astype(q.dtype), q)
        dvj = dot_f32("bqhgk,bqhgd->bkhd", p.astype(q.dtype), dout.astype(q.dtype))
        return dq_acc, (dkj, dvj)

    dq0 = vzeros(q.shape, q)
    dq, (dks, dvs) = jax.lax.scan(step, dq0,
                                  (kc, vc, jnp.arange(nc)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, dh)
    dvv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dvv.astype(v.dtype)


flash_attention_grouped.defvjp(_flash_fwd, _flash_bwd)
