"""Attention: GQA (opt. bias / sliding window), MLA, cross-attention.

Long-context paths never materialize (S, S) score matrices: training and
prefill use a flash-attention-style scan over KV chunks with an online
softmax (running max + normalizer), so per-device memory is
O(S·chunk) — this is what lets prefill_32k compile inside a 16 GB HBM
budget. Decode uses a single-token path; sliding-window caches are ring
buffers of size `window`, which is why long_500k costs O(window) not
O(S) for SWA architectures.

MLA (deepseek) caches only the 512-d latent + shared rope key. Decode
uses the *absorbed* form (q projected into latent space) so the cache is
never expanded; train/prefill expand K/V per KV-chunk inside the scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import flash
from .layers import ShardCtx, dense, dot_f32, rope

NEG_INF = -2.0e38


# --------------------------------------------------------------------------
# flash-style chunked attention (train / prefill)
# --------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool, window: int = 0,
                      chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh) with H % Hkv == 0.
    Returns (B, Sq, H, dh). Mask: causal (kv ≤ q) and, if window > 0,
    kv > q − window. q_offset shifts query positions (decode prefill
    continuation).
    """
    B, Sq, H, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from dh (MLA)
    g = H // Hkv
    scale = dh ** -0.5
    while Skv % chunk:
        chunk //= 2
    qg = (q.reshape(B, Sq, Hkv, g, dh) * scale).astype(q.dtype)
    out = flash.flash_attention_grouped(qg, k, v, causal, window, chunk,
                                        q_offset)
    return out.reshape(B, Sq, H, dv).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def gqa_params(key, cfg: ModelConfig) -> dict:
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense(ks[0], (D, H * dh)),
         "wk": dense(ks[1], (D, Hkv * dh)),
         "wv": dense(ks[2], (D, Hkv * dh)),
         "wo": dense(ks[3], (H * dh, D))}
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * dh,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, ctx: ShardCtx):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = ctx.batch_feature(q).reshape(B, S, H, dh)
    k = ctx.batch_feature(k).reshape(B, S, Hkv, dh)
    v = ctx.batch_feature(v).reshape(B, S, Hkv, dh)
    return q, k, v


def gqa_train(cfg: ModelConfig, p: dict, x: jax.Array,
              positions: jax.Array, ctx: ShardCtx,
              kv_override: Optional[tuple] = None,
              causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill). Returns (B, S, D)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, ctx)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    out = chunked_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache_k: jax.Array, cache_v: jax.Array,
               ctx: ShardCtx) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); cache_k/v: (B, C, Hkv, dh) where
    C = full seq capacity, or the window size for SWA (ring buffer).
    Returns (out (B,1,D), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    C = cache_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x, ctx)
    pos_b = jnp.broadcast_to(pos, (B, 1))
    q = rope(q, pos_b, cfg.rope_theta)
    k = rope(k, pos_b, cfg.rope_theta)
    slot = (pos % C) if cfg.sliding_window > 0 else jnp.minimum(pos, C - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    # position of each slot: ring for SWA, identity otherwise
    idx = jnp.arange(C)
    if cfg.sliding_window > 0:
        kv_pos = pos - (pos % C - idx) % C
    else:
        kv_pos = idx
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    g = H // Hkv
    qg = (q.reshape(B, Hkv, g, dh) * dh ** -0.5).astype(cache_k.dtype)
    s = dot_f32("bhgd,bchd->bhgc", qg, cache_k)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = dot_f32("bhgc,bchd->bhgd", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, H * dh).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype)), \
        cache_k, cache_v


# --------------------------------------------------------------------------
# MLA (deepseek-v2)
# --------------------------------------------------------------------------

def mla_params(key, cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    ks = jax.random.split(key, 5)
    return {"wq": dense(ks[0], (D, H * (dn + dr))),
            "wdkv": dense(ks[1], (D, r + dr)),
            "wuk": dense(ks[2], (r, H * dn)),
            "wuv": dense(ks[3], (r, H * dv)),
            "wo": dense(ks[4], (H * dv, D))}


def mla_train(cfg: ModelConfig, p: dict, x: jax.Array,
              positions: jax.Array, ctx: ShardCtx) -> jax.Array:
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = ctx.batch_feature(q).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(x.dtype))
    c, k_pe = ckv[..., :r], ckv[..., r:]
    k_pe = rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    # MLA's point: move the LATENT across shards, not the expansion.
    # Under seq-sharded activations the attention path needs full-seq
    # K/V; pinning c/k_pe to seq-replicated here makes the collective
    # carry (r + dr) = 576 dims instead of H·(dn+dv) — ~5× less wire
    # (§Perf cell B iteration 3).
    if ctx.mesh is not None:
        from jax.sharding import PartitionSpec as _P
        c = ctx.constrain(c, _P(ctx._dp(), None, None))
        k_pe = ctx.constrain(k_pe, _P(ctx._dp(), None, None))
    k_nope = jnp.einsum("bsr,rh->bsh", c, p["wuk"].astype(x.dtype)) \
        .reshape(B, S, H, dn)
    v = jnp.einsum("bsr,rh->bsh", c, p["wuv"].astype(x.dtype)) \
        .reshape(B, S, H, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dr))], -1)
    qf = jnp.concatenate([q_nope, q_pe], -1)
    out = chunked_attention(qf, k, v, causal=True,
                            window=cfg.sliding_window)
    out = out.reshape(B, S, H * dv)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
               cache_c: jax.Array, cache_pe: jax.Array,
               ctx: ShardCtx) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed MLA decode. cache_c: (B, C, r) latents; cache_pe:
    (B, C, dr) shared rope keys."""
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)
    C = cache_c.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype)) \
        .reshape(B, 1, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    pos_b = jnp.broadcast_to(pos, (B, 1))
    q_pe = rope(q_pe, pos_b, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(x.dtype))
    c, k_pe = ckv[..., :r], ckv[..., r:]
    k_pe = rope(k_pe[:, :, None, :], pos_b, cfg.rope_theta)[:, :, 0]
    slot = jnp.minimum(pos, C - 1)
    cache_c = jax.lax.dynamic_update_slice_in_dim(
        cache_c, c.astype(cache_c.dtype), slot, axis=1)
    cache_pe = jax.lax.dynamic_update_slice_in_dim(
        cache_pe, k_pe.astype(cache_pe.dtype), slot, axis=1)
    # absorb: q ↦ latent space, score directly against the latent cache
    wuk = p["wuk"].reshape(r, H, dn).astype(x.dtype)
    qa = jnp.einsum("bqhd,rhd->bqhr", q_nope, wuk)
    s = dot_f32("bqhr,bcr->bhqc", qa.astype(cache_c.dtype), cache_c)
    s = s + dot_f32("bqhd,bcd->bhqc", q_pe.astype(cache_pe.dtype), cache_pe)
    s = s * (dn + dr) ** -0.5
    valid = jnp.arange(C) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctxv = dot_f32("bhqc,bcr->bqhr", w.astype(cache_c.dtype), cache_c)
    wuv = p["wuv"].reshape(r, H, dv).astype(x.dtype)
    o = jnp.einsum("bqhr,rhd->bqhd", ctxv.astype(x.dtype), wuv)
    o = o.reshape(B, 1, H * dv)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype)), \
        cache_c, cache_pe


# --------------------------------------------------------------------------
# cross-attention (whisper decoder)
# --------------------------------------------------------------------------

def cross_params(key, cfg: ModelConfig) -> dict:
    D, H, dh = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {"wq": dense(ks[0], (D, H * dh)),
            "wk": dense(ks[1], (D, H * dh)),
            "wv": dense(ks[2], (D, H * dh)),
            "wo": dense(ks[3], (H * dh, D))}


def cross_attend(cfg: ModelConfig, p: dict, x: jax.Array,
                 enc_k: jax.Array, enc_v: jax.Array,
                 ctx: ShardCtx) -> jax.Array:
    """x: (B, S, D); enc_k/v: (B, T, H, dh) precomputed from encoder."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype)) \
        .reshape(B, S, H, dh)
    out = chunked_attention(q, enc_k, enc_v, causal=False, window=0)
    out = out.reshape(B, S, H * dh)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def encoder_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array) -> tuple:
    """Precompute cross K/V from encoder output (B, T, D)."""
    B, T, _ = enc_out.shape
    H, dh = cfg.n_heads, cfg.hd
    k = jnp.einsum("btd,dh->bth", enc_out, p["wk"].astype(enc_out.dtype)) \
        .reshape(B, T, H, dh)
    v = jnp.einsum("btd,dh->bth", enc_out, p["wv"].astype(enc_out.dtype)) \
        .reshape(B, T, H, dh)
    return k, v
