"""Mixture-of-Experts via shard_map: shard-local dispatch, tp-sharded
expert FFNs, exactly one psum.

Two generations of this module are recorded in EXPERIMENTS.md §Perf:
  v0 (global cumsum + (E, cap, D) buffer + pjit propagation): the
     position cumsum crossed dp shards and the dispatch scatter's global
     indices defeated GSPMD — buffers replicated (34 GB/device on
     mixtral), collectives 65 s.
  v1 (per-chunk cumsum, 3-index scatter): still unpartitionable —
     199 GiB on deepseek. General scatters do not shard.
  v2 (this): `shard_map` takes manual control. Tokens are sharded over
     dp only (identical across the tp group); each device dispatches its
     *local* tokens into a *local* (E, cap_local, D) buffer — the
     scatter never crosses a shard boundary by construction. Expert FFN
     weights put their hidden dim on tp, every device computes partial
     expert outputs for its F-slice, results combine back per token, and
     a single psum(tp) finishes the block. The only other collective is
     the input gather out of the seq-sharded residual stream.

This mirrors the clique engine's planner philosophy (§Arch-applicability
in DESIGN.md): make the ragged thing (tokens→experts, nodes→buckets)
static and LOCAL, then let the dense math shard.

Semantics: renormalized top-k gates, static capacity (drop fraction
reported), switch-style aux loss, optional shared experts (deepseek).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, round_up
from .layers import ShardCtx, dense


def moe_params(key, cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {"router": dense(ks[0], (D, E)),
         "w_gate": dense(ks[1], (E, D, F)),
         "w_up": dense(ks[2], (E, D, F)),
         "w_down": dense(ks[3], (E, F, D))}
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense(k1, (D, Fs)),
                       "w_up": dense(k2, (D, Fs)),
                       "w_down": dense(k3, (Fs, D))}
    return p


def _moe_local(cfg: ModelConfig, p: dict, x: jax.Array,
               psum_axes=(), pmean_axes=()) -> tuple[jax.Array, dict]:
    """Dense local dispatch on this shard's tokens. Weights may carry an
    F-dim slice (1/tp of the hidden dim); partial outputs are psum'd."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    T = B * S
    xf = x.reshape(T, D)
    # router in f32: numerically standard, and avoids the XLA:CPU
    # bf16-dot→f32-convert fusion that DotThunk cannot execute
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    cap = round_up(int(T * K / E * cfg.capacity_factor) + 1, 8)

    flat_e = idx.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
    keep = (pos < cap).astype(x.dtype)
    slot = jnp.clip(pos, 0, cap - 1)
    t_idx = jnp.repeat(jnp.arange(T), K)

    buf = jnp.zeros((E, cap, D), x.dtype)
    buf = buf.at[flat_e, slot].add(xf[t_idx] * keep[:, None])
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                    p["w_down"].astype(x.dtype))
    gathered = eo[flat_e, slot] * keep[:, None] \
        * gate_vals.reshape(T * K)[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[t_idx].add(gathered)

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xf @ sp["w_gate"].astype(x.dtype)) \
            * (xf @ sp["w_up"].astype(x.dtype))
        y = y + hs @ sp["w_down"].astype(x.dtype)

    if psum_axes:
        y = jax.lax.psum(y, psum_axes)       # combine F-slice partials

    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32),
                    axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    mets = {"moe_aux": aux, "moe_drop_frac": dropped}
    if pmean_axes:
        mets = {k: jax.lax.pmean(v, pmean_axes) for k, v in mets.items()}
    return y.reshape(B, S, D), mets


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array,
              ctx: ShardCtx) -> tuple[jax.Array, dict]:
    """x: (B, S, D) → (y, metrics)."""
    if ctx.mesh is None:
        return _moe_local(cfg, p, x)
    dp = ctx._dp()
    # zero3: every axis carries batch; expert weights replicate inside
    # the shard_map (the outer ZeRO gather pays for them once per layer)
    tp = None if ctx.mode == "zero3" else ctx._tp()
    B = x.shape[0]
    dp_used = tuple(a for a in (dp or ())) if dp else ()
    # batch must divide the dp extent for the local view; else drop axes
    ext = 1
    use = []
    for a in dp_used:
        if B % (ext * ctx.mesh.shape[a]) == 0:
            use.append(a)
            ext *= ctx.mesh.shape[a]
    dp_used = tuple(use)

    wspecs = {"router": P(), "w_gate": P(None, None, tp),
              "w_up": P(None, None, tp), "w_down": P(None, tp, None)}
    if "shared" in p:
        wspecs["shared"] = {"w_gate": P(None, tp), "w_up": P(None, tp),
                            "w_down": P(tp, None)}
    psum_axes = (tp,) if tp else ()
    # metrics are invarying over tp (same tokens across the tp group);
    # only the dp axes carry different tokens → only they get pmean'd
    pmean_axes = dp_used
    body = functools.partial(_moe_local, cfg, psum_axes=psum_axes,
                             pmean_axes=pmean_axes)
    from ..core.compat import shard_map
    y, mets = shard_map(
        lambda pl, xl: body(pl, xl),
        mesh=ctx.mesh,
        in_specs=(wspecs, P(dp_used if dp_used else None, None, None)),
        out_specs=(P(dp_used if dp_used else None, None, None), P()),
    )(p, x)
    return y, mets
