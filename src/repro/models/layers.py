"""Shared layer primitives + the sharding context.

Sharding philosophy: parameters get explicit NamedShardings from
``repro.distributed.sharding``; inside the model we only pin a handful of
*activation* constraints through a :class:`ShardCtx` (batch→dp axes,
model-parallel dim→tp axis, optional sequence sharding of the layer-scan
carry). Everything else is left to GSPMD propagation, and the roofline
extractor reads back what XLA actually inserted.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


# --------------------------------------------------------------------------
# sharding context
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding hints. ``None`` mesh → no constraints (smoke
    tests on one device).

    mode selects the parallelism layout (measured head-to-head in
    EXPERIMENTS.md §Perf):
      "zero3"    — batch over dp (ideally every mesh axis), activations
          unsharded per example; params/optimizer stay 2-D sharded and
          are gathered per layer (ZeRO-3). Zero activation collectives —
          measured best for train_4k where tokens/chip is small.
      "fsdp_seq" — batch over dp, *sequence* over tp, features full:
          weights gathered for compute; attention gathers KV per layer.
          Needed when batch < chips (32k prefill) so memory still shards.
      "tp_sp"    — batch over dp, sequence over tp between blocks AND
          features over tp inside blocks (Megatron-SP-style mixture).
      "megatron" — batch over dp, sequence full, features over tp
          (classic tensor parallelism: per-layer activation all-reduce).
    """
    mesh: Optional[Mesh] = None
    dp: tuple[str, ...] = ("pod", "data")
    tp: str = "model"
    mode: str = "fsdp_seq"

    def axes(self) -> tuple:
        return tuple(self.mesh.axis_names) if self.mesh else ()

    def _dp(self, batch: Optional[int] = None):
        """dp axes present in the mesh; with ``batch`` given, greedily
        keep only a prefix whose extent divides the batch (zero3 uses
        three axes on a 256-batch — the non-dividing tail is dropped)."""
        present = [a for a in self.dp
                   if self.mesh and a in self.mesh.axis_names]
        if batch is not None:
            keep, ext = [], 1
            for a in present:
                if batch % (ext * self.mesh.shape[a]) == 0:
                    keep.append(a)
                    ext *= self.mesh.shape[a]
            present = keep
        return tuple(present) if present else None

    def _tp(self):
        return self.tp if (self.mesh and self.tp in self.mesh.axis_names) \
            else None

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def batch(self, x: jax.Array) -> jax.Array:
        """(B, ...) — batch over dp axes."""
        return self.constrain(
            x, P(self._dp(x.shape[0]), *([None] * (x.ndim - 1))))

    def batch_seq(self, x: jax.Array) -> jax.Array:
        """(B, S, ...) — the layer-boundary residual stream."""
        tp = self._tp() if self.mode in ("fsdp_seq", "tp_sp") else None
        return self.constrain(
            x, P(self._dp(x.shape[0]), tp, *([None] * (x.ndim - 2))))

    def batch_feature(self, x: jax.Array) -> jax.Array:
        """(B, S, F) — wide intermediates (ffn hidden, qkv concat)."""
        if self.mode in ("fsdp_seq", "zero3"):
            return self.batch_seq(x)
        tp = self._tp()
        return self.constrain(
            x, P(self._dp(), *([None] * (x.ndim - 2)), tp))


NO_SHARD = ShardCtx(mesh=None)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def norm_params(cfg: ModelConfig, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def gated_mlp(p: dict, x: jax.Array, ctx: ShardCtx) -> jax.Array:
    """SiLU-gated MLP (llama-style)."""
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = ctx.batch_feature(jax.nn.silu(h) * g)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


def mlp_params(key, d: int, f: int, scale: float = 0.02) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": jax.random.normal(k1, (d, f), jnp.float32) * scale,
            "w_up": jax.random.normal(k2, (d, f), jnp.float32) * scale,
            "w_down": jax.random.normal(k3, (f, d), jnp.float32) * scale}


def chunked_softmax_xent(h: jax.Array, w_vocab: jax.Array,
                         labels: jax.Array, mask: jax.Array,
                         n_chunks: int = 16) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks: per chunk compute logits, logsumexp, and
    the label logit. Keeps the memory term at (B, S/chunks, V) — the
    difference between fitting 256k-vocab training in HBM or not.
    """
    B, S, D = h.shape
    while S % n_chunks:
        n_chunks //= 2
    hs = h.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)
    ms = mask.reshape(B, n_chunks, S // n_chunks).transpose(1, 0, 2)

    def chunk(acc, xs):
        hc, lc, mc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc,
                            w_vocab.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - lab) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), ()

    zero = (mask.reshape(-1)[0] * 0).astype(jnp.float32)
    (tot, cnt), _ = jax.lax.scan(chunk, (zero, zero), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def dense(key, shape, scale: float = 0.02):
    return jax.random.normal(key, shape, jnp.float32) * scale


def vzeros(shape, like: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Zeros that inherit ``like``'s varying-manual-axes type.

    Scan carries created with plain jnp.zeros are 'unvarying' under
    shard_map and JAX ≥0.8 rejects the carry-type mismatch; deriving the
    init from a data operand fixes the type at negligible cost."""
    return jnp.zeros(shape, dtype) + \
        (like.reshape(-1)[0] * 0).astype(dtype)


def dot_f32(spec: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """Einsum with f32 accumulation.

    TPU: bf16 operands + preferred_element_type=f32 (MXU-native, narrow
    gathers). CPU (this container): explicit f32 casts — XLA:CPU's
    DotThunk rejects some bf16×bf16→f32 shapes at runtime, and the HLO
    analyzer's bf16 correction keeps the roofline faithful either way.
    """
    if jax.default_backend() == "cpu":
        return jnp.einsum(spec, a.astype(jnp.float32),
                          b.astype(jnp.float32))
    return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
