"""Model assembly: one scan-based stack covering all assigned families.

Layers are stored *stacked* (every leaf has a leading L axis) and folded
with `lax.scan`, so the compiled HLO contains one layer body regardless
of depth — this is what keeps 80-layer × 512-device dry-runs compilable
in seconds, and what the roofline extractor multiplies back by the trip
count.

Entry points:
  init_params(cfg, key)                       — real weights (smoke scale)
  abstract_params(cfg)                        — ShapeDtypeStructs (dry-run)
  forward_train(cfg, params, batch, ctx)      — loss + metrics
  init_cache(cfg, batch, cache_len)           — decode-cache pytree
  prefill(cfg, params, batch, ctx)            — cache fill + last logits
  decode_step(cfg, params, cache, token, pos, ctx) — one-token serve step
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (NO_SHARD, ShardCtx, apply_norm, chunked_softmax_xent,
                     dense, gated_mlp, mlp_params, norm_params)


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, cross: bool = False,
                self_causal: bool = True) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"ln1": norm_params(cfg, cfg.d_model)}
    if not cfg.attention_free:
        p["attn"] = attn.mla_params(ks[0], cfg) if cfg.use_mla \
            else attn.gqa_params(ks[0], cfg)
    if cfg.family == "ssm" or cfg.hybrid:
        p["ssm"] = ssm_mod.ssm_params(ks[1], cfg)
    if cross:
        p["cross_ln"] = norm_params(cfg, cfg.d_model)
        p["cross"] = attn.cross_params(ks[2], cfg)
    if cfg.n_experts:
        p["ln2"] = norm_params(cfg, cfg.d_model)
        p["moe"] = moe_mod.moe_params(ks[3], cfg)
    elif cfg.d_ff > 0 and not cfg.parallel_block:
        p["ln2"] = norm_params(cfg, cfg.d_model)
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff)
    elif cfg.parallel_block:
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff)
    return p


def _enc_layer_cfg(cfg: ModelConfig) -> ModelConfig:
    """Encoder layers: dense self-attention, MHA, no experts/ssm."""
    import dataclasses
    return dataclasses.replace(
        cfg, family="dense", hybrid=False, n_experts=0, use_mla=False,
        sliding_window=0, parallel_block=False,
        n_kv_heads=cfg.n_heads)


def init_params(cfg: ModelConfig, key) -> dict:
    kemb, klyr, kenc, khead, kproj = jax.random.split(key, 5)
    V, D = cfg.padded_vocab, cfg.d_model
    params: dict = {"embed": dense(kemb, (V, D), scale=0.01),
                    "final_ln": norm_params(cfg, D)}
    lkeys = jax.random.split(klyr, cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(cfg, k, cross=cfg.cross_attention))(lkeys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense(khead, (D, V), scale=0.01)
    if cfg.encoder_layers:
        ecfg = _enc_layer_cfg(cfg)
        ekeys = jax.random.split(kenc, cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_layer(ecfg, k))(ekeys),
            "final_ln": norm_params(cfg, D)}
    if cfg.family == "vlm":
        k1, k2 = jax.random.split(kproj)
        params["projector"] = {
            "w1": dense(k1, (cfg.vision_embed_dim, D)),
            "b1": jnp.zeros((D,), jnp.float32),
            "w2": dense(k2, (D, D)),
            "b2": jnp.zeros((D,), jnp.float32)}
    # ≥2-D weights live in the compute dtype (bf16); the optimizer holds
    # f32 masters. FSDP gathers and grad reductions move half the bytes.
    return compute_cast(cfg, params)


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, p: dict, h: jax.Array,
                 positions: jax.Array, ctx: ShardCtx,
                 enc_out: Optional[jax.Array] = None,
                 causal: bool = True) -> tuple[jax.Array, dict]:
    metrics: dict = {}
    hn = apply_norm(cfg, p["ln1"], h)
    mix = None
    if not cfg.attention_free:
        if cfg.use_mla:
            mix = attn.mla_train(cfg, p["attn"], hn, positions, ctx)
        else:
            mix = attn.gqa_train(cfg, p["attn"], hn, positions, ctx,
                                 causal=causal)
    if cfg.family == "ssm" or cfg.hybrid:
        s = ssm_mod.ssd_train(cfg, p["ssm"], hn, ctx)
        mix = s if mix is None else 0.5 * (mix + s)
    if cfg.parallel_block:
        ff = gated_mlp(p["mlp"], hn, ctx)
        return h + mix + ff, metrics
    h = h + mix
    if "cross" in p and enc_out is not None:
        cn = apply_norm(cfg, p["cross_ln"], h)
        ek, ev = attn.encoder_kv(cfg, p["cross"], enc_out)
        h = h + attn.cross_attend(cfg, p["cross"], cn, ek, ev, ctx)
    if cfg.n_experts:
        ff, metrics = moe_mod.moe_apply(
            cfg, p["moe"], apply_norm(cfg, p["ln2"], h), ctx)
        h = h + ff
    elif cfg.d_ff > 0:
        h = h + gated_mlp(p["mlp"], apply_norm(cfg, p["ln2"], h), ctx)
    return h, metrics


def compute_cast(cfg: ModelConfig, layers: dict) -> dict:
    """Cast ≥2-D float32 weights to the compute dtype *outside* the layer
    scan, while still sharded — so FSDP all-gathers inside the loop move
    bf16, not f32 (halves weight-gather wire bytes; §Perf iteration 3).
    Norm scales / biases (1-D) stay f32 for stability."""
    dt = jnp.dtype(cfg.dtype)

    def leaf(x):
        if hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 2:
            return x.astype(dt)
        return x

    return jax.tree.map(leaf, layers)


def _stack_scan(cfg: ModelConfig, layers: dict, h: jax.Array,
                positions: jax.Array, ctx: ShardCtx,
                enc_out: Optional[jax.Array] = None,
                causal: bool = True, remat: str = "full") -> tuple:
    def layer_fn(carry, lp):
        out, met = _block_train(cfg, lp, carry, positions, ctx,
                                enc_out=enc_out, causal=causal)
        return ctx.batch_seq(out), met

    if remat == "full":
        layer_fn = jax.checkpoint(layer_fn)
    elif remat == "dots":
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.checkpoint_dots)
    h, mets = jax.lax.scan(layer_fn, h, compute_cast(cfg, layers))
    return h, mets


# --------------------------------------------------------------------------
# training forward
# --------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array,
           ctx: ShardCtx) -> jax.Array:
    h = jnp.take(params["embed"], tokens, axis=0)
    return ctx.batch_seq(h.astype(jnp.dtype(cfg.dtype)))


def _vocab_matrix(cfg: ModelConfig, params: dict) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _run_encoder(cfg: ModelConfig, params: dict, frames: jax.Array,
                 ctx: ShardCtx, remat: str) -> jax.Array:
    ecfg = _enc_layer_cfg(cfg)
    B, T, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    h = ctx.batch_seq(frames.astype(jnp.dtype(cfg.dtype)))
    h, _ = _stack_scan(ecfg, params["encoder"]["layers"], h, pos, ctx,
                       causal=False, remat=remat)
    return apply_norm(cfg, params["encoder"]["final_ln"], h)


def _project_patches(cfg: ModelConfig, params: dict,
                     patches: jax.Array) -> jax.Array:
    pj = params["projector"]
    dt = jnp.dtype(cfg.dtype)
    h = patches.astype(dt) @ pj["w1"].astype(dt) + pj["b1"].astype(dt)
    return jax.nn.gelu(h) @ pj["w2"].astype(dt) + pj["b2"].astype(dt)


def forward_train(cfg: ModelConfig, params: dict, batch: dict,
                  ctx: ShardCtx = NO_SHARD, remat: str = "full",
                  aux_coef: float = 0.01) -> tuple[jax.Array, dict]:
    """batch: tokens (B,S) targets (B,S) mask (B,S) [+frames|+patches]."""
    tokens, targets = batch["tokens"], batch["targets"]
    mask = batch["mask"].astype(jnp.float32)
    B, S = tokens.shape
    h = _embed(cfg, params, tokens, ctx)
    enc_out = None
    n_prefix = 0
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, batch["frames"], ctx, remat)
    if cfg.family == "vlm":
        vis = _project_patches(cfg, params, batch["patches"])
        h = jnp.concatenate([ctx.batch_seq(vis), h], axis=1)
        n_prefix = vis.shape[1]
    positions = jnp.broadcast_to(jnp.arange(n_prefix + S), (B, n_prefix + S))
    h, mets = _stack_scan(cfg, params["layers"], h, positions, ctx,
                          enc_out=enc_out, remat=remat)
    h = apply_norm(cfg, params["final_ln"], h)
    if n_prefix:
        h = h[:, n_prefix:, :]
    loss = chunked_softmax_xent(h, _vocab_matrix(cfg, params).astype(h.dtype),
                                targets, mask)
    metrics = {"loss": loss}
    if cfg.n_experts:
        aux = jnp.mean(mets["moe_aux"])
        metrics["moe_aux"] = aux
        metrics["moe_drop_frac"] = jnp.mean(mets["moe_drop_frac"])
        loss = loss + aux_coef * aux
    metrics["total_loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------------
# serving: cache, prefill, decode
# --------------------------------------------------------------------------

def kv_capacity(cfg: ModelConfig, cache_len: int) -> int:
    return min(cache_len, cfg.sliding_window) if cfg.sliding_window \
        else cache_len


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16) -> dict:
    L, B = cfg.n_layers, batch
    cache: dict = {}
    if not cfg.attention_free:
        C = kv_capacity(cfg, cache_len)
        if cfg.use_mla:
            cache["c"] = jnp.zeros((L, B, C, cfg.kv_lora_rank), dtype)
            cache["pe"] = jnp.zeros((L, B, C, cfg.qk_rope_dim), dtype)
        else:
            cache["k"] = jnp.zeros((L, B, C, cfg.n_kv_heads, cfg.hd), dtype)
            cache["v"] = jnp.zeros((L, B, C, cfg.n_kv_heads, cfg.hd), dtype)
    if cfg.family == "ssm" or cfg.hybrid:
        H, P, N = cfg.n_ssm_heads, cfg.dinner // cfg.n_ssm_heads, \
            cfg.ssm_state
        cache["state"] = jnp.zeros((L, B, H, P, N), jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, B, cfg.conv_width - 1, cfg.dinner + 2 * cfg.ssm_state),
            dtype)
    if cfg.cross_attention:
        T = cfg.max_source_positions
        cache["cross_k"] = jnp.zeros((L, B, T, cfg.n_heads, cfg.hd), dtype)
        cache["cross_v"] = jnp.zeros((L, B, T, cfg.n_heads, cfg.hd), dtype)
    return cache


def _block_decode(cfg: ModelConfig, p: dict, h: jax.Array,
                  pos: jax.Array, cache_l: dict, ctx: ShardCtx) -> tuple:
    new_cache = dict(cache_l)
    hn = apply_norm(cfg, p["ln1"], h)
    mix = None
    if not cfg.attention_free:
        if cfg.use_mla:
            mix, new_cache["c"], new_cache["pe"] = attn.mla_decode(
                cfg, p["attn"], hn, pos, cache_l["c"], cache_l["pe"], ctx)
        else:
            mix, new_cache["k"], new_cache["v"] = attn.gqa_decode(
                cfg, p["attn"], hn, pos, cache_l["k"], cache_l["v"], ctx)
    if cfg.family == "ssm" or cfg.hybrid:
        s, new_cache["state"], new_cache["conv"] = ssm_mod.ssd_decode(
            cfg, p["ssm"], hn, cache_l["state"], cache_l["conv"], ctx)
        mix = s if mix is None else 0.5 * (mix + s)
    if cfg.parallel_block:
        return h + mix + gated_mlp(p["mlp"], hn, ctx), new_cache
    h = h + mix
    if "cross" in p:
        cn = apply_norm(cfg, p["cross_ln"], h)
        h = h + attn.cross_attend(cfg, p["cross"], cn,
                                  cache_l["cross_k"], cache_l["cross_v"],
                                  ctx)
    if cfg.n_experts:
        ff, _ = moe_mod.moe_apply(cfg, p["moe"],
                                  apply_norm(cfg, p["ln2"], h), ctx)
        h = h + ff
    elif cfg.d_ff > 0:
        h = h + gated_mlp(p["mlp"], apply_norm(cfg, p["ln2"], h), ctx)
    return h, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos: jax.Array,
                ctx: ShardCtx = NO_SHARD) -> tuple[jax.Array, dict]:
    """One serve step: token (B,) int32, pos scalar int32 (current index).
    Returns (logits (B, V), new cache)."""
    h = _embed(cfg, params, token[:, None], ctx)

    def layer_fn(carry, xs):
        lp, cache_l = xs
        out, new_cache_l = _block_decode(cfg, lp, carry, pos, cache_l, ctx)
        return out, new_cache_l

    h, new_cache = jax.lax.scan(layer_fn, h,
                                (compute_cast(cfg, params["layers"]), cache))
    h = apply_norm(cfg, params["final_ln"], h)
    logits = (h[:, 0, :] @ _vocab_matrix(cfg, params).astype(h.dtype)) \
        .astype(jnp.float32)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            ctx: ShardCtx = NO_SHARD, remat: str = "none",
            cache_len: int = 0) -> tuple:
    """Fill the cache from a full prompt; returns (cache, last_logits).

    ``cache_len`` sets the cache capacity (≥ prompt length incl. any
    vision prefix; default exactly prompt length). Sliding-window caches
    keep only the last `window` entries, ring-indexed by position % C so
    decode can continue seamlessly.

    The per-layer K/V (or SSD states) produced by the train-path forward
    are re-derived here layer-by-layer so everything stays inside one
    scan (compiled once, like training)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(cfg, params, tokens, ctx)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, batch["frames"], ctx, remat)
    n_prefix = 0
    if cfg.family == "vlm":
        vis = _project_patches(cfg, params, batch["patches"])
        h = jnp.concatenate([ctx.batch_seq(vis), h], axis=1)
        n_prefix = vis.shape[1]
    St = n_prefix + S
    C = kv_capacity(cfg, max(cache_len, St))
    positions = jnp.broadcast_to(jnp.arange(St), (B, St))
    start = max(0, St - C)
    ring = jnp.arange(start, St) % C

    def layer_fn(carry, lp):
        hh = carry
        hn = apply_norm(cfg, lp["ln1"], hh)
        saved: dict = {}
        mix = None
        if not cfg.attention_free:
            if cfg.use_mla:
                mix = attn.mla_train(cfg, lp["attn"], hn, positions, ctx)
                ckv = jnp.einsum("bsd,dr->bsr", hn,
                                 lp["attn"]["wdkv"].astype(hn.dtype))
                c, k_pe = ckv[..., :cfg.kv_lora_rank], \
                    ckv[..., cfg.kv_lora_rank:]
                from .layers import rope as _rope
                k_pe = _rope(k_pe[:, :, None, :], positions,
                             cfg.rope_theta)[:, :, 0]
                cc = jnp.zeros((B, C, cfg.kv_lora_rank), jnp.bfloat16)
                pc = jnp.zeros((B, C, cfg.qk_rope_dim), jnp.bfloat16)
                saved["c"] = cc.at[:, ring].set(
                    c[:, start:].astype(jnp.bfloat16))
                saved["pe"] = pc.at[:, ring].set(
                    k_pe[:, start:].astype(jnp.bfloat16))
            else:
                q, k, v = attn._project_qkv(cfg, lp["attn"], hn, ctx)
                from .layers import rope as _rope
                q = _rope(q, positions, cfg.rope_theta)
                k = _rope(k, positions, cfg.rope_theta)
                o = attn.chunked_attention(q, k, v, causal=True,
                                           window=cfg.sliding_window)
                o = o.reshape(B, St, cfg.n_heads * cfg.hd)
                mix = jnp.einsum("bsh,hd->bsd", o,
                                 lp["attn"]["wo"].astype(hh.dtype))
                kc = jnp.zeros((B, C) + k.shape[2:], jnp.bfloat16)
                vc = jnp.zeros((B, C) + v.shape[2:], jnp.bfloat16)
                saved["k"] = kc.at[:, ring].set(
                    k[:, start:].astype(jnp.bfloat16))
                saved["v"] = vc.at[:, ring].set(
                    v[:, start:].astype(jnp.bfloat16))
        if cfg.family == "ssm" or cfg.hybrid:
            s, fstate, fconv = ssm_mod.ssd_prefill(cfg, lp["ssm"], hn, ctx)
            saved["state"], saved["conv"] = fstate, fconv
            mix = s if mix is None else 0.5 * (mix + s)
        if cfg.parallel_block:
            hh = hh + mix + gated_mlp(lp["mlp"], hn, ctx)
            return ctx.batch_seq(hh), saved
        hh = hh + mix
        if "cross" in lp and enc_out is not None:
            cn = apply_norm(cfg, lp["cross_ln"], hh)
            ek, ev = attn.encoder_kv(cfg, lp["cross"], enc_out)
            saved["cross_k"] = ek.astype(jnp.bfloat16)
            saved["cross_v"] = ev.astype(jnp.bfloat16)
            hh = hh + attn.cross_attend(cfg, lp["cross"], cn, ek, ev, ctx)
        if cfg.n_experts:
            ff, _ = moe_mod.moe_apply(cfg, lp["moe"],
                                      apply_norm(cfg, lp["ln2"], hh), ctx)
            hh = hh + ff
        elif cfg.d_ff > 0:
            hh = hh + gated_mlp(lp["mlp"],
                                apply_norm(cfg, lp["ln2"], hh), ctx)
        return ctx.batch_seq(hh), saved

    h, cache = jax.lax.scan(layer_fn, h,
                            compute_cast(cfg, params["layers"]))
    h = apply_norm(cfg, params["final_ln"], h)
    logits = (h[:, -1, :] @ _vocab_matrix(cfg, params).astype(h.dtype)) \
        .astype(jnp.float32)
    return cache, logits
