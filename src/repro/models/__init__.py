"""LM substrate: scan-based stacks for all assigned architecture families."""
from .layers import NO_SHARD, ShardCtx
from .transformer import (abstract_params, decode_step, forward_train,
                          init_cache, init_params, kv_capacity, prefill)

__all__ = ["NO_SHARD", "ShardCtx", "abstract_params", "decode_step",
           "forward_train", "init_cache", "init_params", "kv_capacity",
           "prefill"]
