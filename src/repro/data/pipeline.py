"""Deterministic, resumable synthetic data pipeline.

Real pods stream tokenized shards; this container has no corpus, so the
pipeline synthesizes token streams from a counter-based PRNG: batch i of
shard s is a pure function of (seed, s, i). That gives the two properties
the fault-tolerance story needs and tests assert:

  1. *Resumability* — the pipeline state is one integer (next_step); a
     restored checkpoint replays the exact same batches.
  2. *Shard independence* — each dp shard draws from its own stream, so
     elastic re-sharding changes nothing about what any shard sees.

The synthetic distribution is Zipfian over the vocab with a repeated-
n-gram structure so cross-entropy actually decreases during the example
training runs (a uniform stream would pin loss at log V).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    next_step: int

    def to_dict(self) -> dict:
        return {"seed": self.seed, "next_step": self.next_step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(seed=int(d["seed"]),
                             next_step=int(d["next_step"]))


class SyntheticLM:
    """Zipf-with-motifs token stream."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_prefix: int = 0, prefix_dim: int = 0,
                 prefix_key: str = ""):
        self.V = vocab_size
        self.S = seq_len
        self.B = global_batch
        self.state = PipelineState(seed=seed, next_step=0)
        self.n_prefix = n_prefix
        self.prefix_dim = prefix_dim
        self.prefix_key = prefix_key

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step]))
        # zipf-ish ranks capped at vocab; motif: repeat a sampled 8-gram
        r = rng.zipf(1.3, size=(self.B, self.S + 1))
        toks = (r % self.V).astype(np.int32)
        motif = (rng.zipf(1.3, size=(self.B, 8)) % self.V).astype(np.int32)
        reps = self.S // 32
        for i in range(reps):
            pos = 8 + i * 32
            toks[:, pos:pos + 8] = motif
        return toks

    def batch_at(self, step: int) -> dict:
        toks = self._tokens(step)
        batch = {"tokens": toks[:, :-1],
                 "targets": toks[:, 1:],
                 "mask": np.ones((self.B, self.S), np.float32)}
        if self.n_prefix:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.state.seed, step, 7]))
            batch[self.prefix_key] = rng.normal(
                0, 1, (self.B, self.n_prefix, self.prefix_dim)) \
                .astype(np.float32)
        return batch

    def __next__(self) -> dict:
        b = self.batch_at(self.state.next_step)
        self.state.next_step += 1
        return b

    def __iter__(self):
        return self


def make_pipeline(cfg, shape, seed: int = 0) -> SyntheticLM:
    """Family-aware pipeline (adds frames/patches stubs per the brief)."""
    kw: dict = {}
    if cfg.family == "encdec":
        kw = dict(n_prefix=cfg.max_source_positions,
                  prefix_dim=cfg.d_model, prefix_key="frames")
    elif cfg.family == "vlm":
        kw = dict(n_prefix=cfg.n_vision_tokens,
                  prefix_dim=cfg.vision_embed_dim, prefix_key="patches")
    return SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                       seed=seed, **kw)
