"""Checkpointing: atomic, async, elastic.

Layout:  <dir>/step_%08d/  arrays.npz + manifest.json ;  a checkpoint is
visible only after an atomic directory rename, so a preempted save can
never be mistaken for a complete one. Restore maps arrays back onto
*whatever mesh the current process has* by device_put-ing each leaf with
freshly derived shardings — elastic rescale is a restore onto a
different mesh, nothing more (tested in tests/test_checkpoint.py).

On a real pod each host writes only the shards it owns; in this
container the single process owns everything, and the manifest records
the mesh signature it was saved under for audit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}.{k}" if prefix else k))
        return out
    if isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}.{i}" if prefix else str(i)))
        return out
    out[prefix] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat,
                                   f"{prefix}.{k}" if prefix else k)
                for k, v in template.items()}
    if isinstance(template, (tuple, list)):
        vals = [_unflatten_into(v, flat, f"{prefix}.{i}" if prefix else str(i))
                for i, v in enumerate(template)]
        return type(template)(vals) if not hasattr(template, "_fields") \
            else type(template)(*vals)
    leaf = flat[prefix]
    # narrow dtypes (bf16) are serialized widened; restore the template's
    # dtype exactly
    want = getattr(template, "dtype", None)
    if want is not None and leaf.dtype != want:
        leaf = leaf.astype(want)
    return leaf


def _to_serializable(x: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bfloat16) portably — widen to f32
    (lossless); restore casts back via the template dtype."""
    if x.dtype not in (np.float64, np.float32, np.float16, np.int64,
                       np.int32, np.int16, np.int8, np.uint8, np.uint16,
                       np.uint32, np.uint64, np.bool_):
        return x.astype(np.float32)
    return x


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False) -> None:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(host)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: _to_serializable(np.asarray(v))
                        for k, v in flat.items()})
            manifest = {"step": step, "time": time.time(),
                        "n_arrays": len(flat),
                        "mesh": _mesh_signature(),
                        "extra": extra or {}}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=2)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template``; if ``shardings``
        (a matching pytree of NamedSharding) is given, leaves are placed
        onto the current mesh — this is the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest


def _mesh_signature() -> dict:
    return {"n_devices": jax.device_count(),
            "backend": jax.default_backend()}
